"""Fused LayerNorm as a Pallas kernel.

LayerNorm appears twice per transformer block (8× per token for the
4-layer generator); fusing mean/variance/normalize/affine into one VMEM
pass avoids three HBM round-trips of the ``[rows, d]`` activation. Tiled
over rows; the feature dimension stays whole inside a block (d = 128 —
one VPU lane-width worth of f32 per row on TPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, eps):
    x = x_ref[...]  # [block_rows, d]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centered = x - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (centered * inv * gamma_ref[...] + beta_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def fused_layernorm(x, gamma, beta, *, block_rows=64, eps=1e-5):
    """LayerNorm over the last dim of ``x: [rows, d]``.

    rows % block_rows == 0 is required; callers flatten ``[B, L, d]`` to
    ``[B·L, d]`` (always bucket-padded, hence divisible).
    """
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    # shrink to the nearest divisor (length buckets include 96 = 3·32)
    while rows % block_rows != 0:
        block_rows -= 1
    grid = (rows // block_rows,)
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
