"""Fused probe-MLP forward as a Pallas kernel.

The accuracy probe (paper appendix A.1: MLP 200–200–1 with GELU) sits on
the router's request path — it is evaluated for *every* (query, strategy)
pair before any generation happens, so its forward is a genuine hot spot
for the coordinator. Fusing the three matmuls + activations into one
kernel keeps the intermediates in VMEM instead of round-tripping
``[B, 200]`` activations through HBM three times.

Tiled over rows: each grid cell computes a ``block_b``-row slab end to
end. Weights are small (F×200 + 200×200 + 200×1 ≈ 70k params) and are
broadcast to every grid cell — they fit VMEM comfortably alongside the
slab (see DESIGN.md §Perf for the footprint table).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    x = x_ref[...]                    # [bb, F]
    h1 = jax.nn.gelu(
        jax.lax.dot_general(x, w1_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32) + b1_ref[...]
    )
    h2 = jax.nn.gelu(
        jax.lax.dot_general(h1, w2_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32) + b2_ref[...]
    )
    logit = jax.lax.dot_general(h2, w3_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) + b3_ref[...]
    o_ref[...] = logit[:, 0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def fused_mlp(x, w1, b1, w2, b2, w3, b3, *, block_b=32):
    """Probe forward: ``gelu(gelu(x·W1+b1)·W2+b2)·W3+b3`` → [B] logits.

    x: [B, F]; w1: [F, H]; b1: [H]; w2: [H, H]; b2: [H]; w3: [H, 1]; b3: [1].
    B % block_b == 0 is required (callers pad to bucket shapes).
    """
    bsz, f = x.shape
    h = w1.shape[1]
    block_b = min(block_b, bsz)
    if bsz % block_b != 0:
        raise ValueError(f"B={bsz} not divisible by block_b={block_b}")
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2, w3, b3)
