"""Tiled causal flash attention as a Pallas kernel.

The paper's serving substrate (vLLM on an A100) spends its FLOPs in the
generator's attention; this kernel is the TPU re-think of that hot spot
(DESIGN.md §Hardware-Adaptation):

* Q is tiled into ``(block_q, d_head)`` VMEM blocks via ``BlockSpec`` —
  the HBM→VMEM schedule a CUDA kernel would express with threadblocks.
* K/V stream through the kernel in ``block_k``-sized chunks loaded with
  ``pl.dynamic_slice``; the ``L×L`` score matrix is never materialized.
* Softmax is computed *online* (running max ``m``, running normalizer
  ``l``, renormalized accumulator) — the flash-attention recurrence.
* Contractions are ``(block_q, d) × (d, block_k)`` matmuls with f32
  accumulation — MXU-shaped on real hardware.

One kernel serves both phases of generation:

* **prefill**: ``Lq = prompt length``, ``q_offset = 0`` — full causal
  self-attention;
* **decode**: ``Lq = 1`` with the query at absolute position
  ``q_offset[b]`` attending to a ``Lk = max_seq`` KV cache. Cache slots
  beyond ``q_offset`` hold garbage (functional cache update writes ahead);
  the position mask excludes them.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode pallas lowers to plain HLO under jit.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, qoff_ref, o_ref, *, block_k, scale):
    """One (batch, head, q-block) grid cell.

    Ref shapes (leading singleton dims come from the BlockSpecs):
      q_ref:    [1, 1, block_q, d]
      k_ref:    [1, 1, Lk, d]      (full K rows for this batch-head)
      v_ref:    [1, 1, Lk, d]
      qoff_ref: [1]                (absolute position of q row 0)
      o_ref:    [1, 1, block_q, d]
    """
    q = q_ref[0, 0, :, :]  # [bq, d]
    block_q, d = q.shape
    lk = k_ref.shape[2]
    n_kv_blocks = lk // block_k

    q_block_idx = pl.program_id(2)
    q_pos = qoff_ref[0] + q_block_idx * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k_chunk = pl.load(k_ref, (0, 0, pl.dslice(i * block_k, block_k), slice(None)))
        v_chunk = pl.load(v_ref, (0, 0, pl.dslice(i * block_k, block_k), slice(None)))
        # [bq, bk] scores with f32 accumulation (MXU-shaped contraction).
        s = jax.lax.dot_general(
            q, k_chunk,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        kv_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = kv_pos[None, :] <= q_pos[:, None]
        s = jnp.where(causal, s, NEG_INF)

        # online softmax update
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
            p, v_chunk,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))

    # Fully-masked rows (padding queries) have l == 0; emit zeros for them.
    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[0, 0, :, :] = (acc / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(q, k, v, q_offset, *, block_q=16, block_k=32):
    """Causal multi-head attention, flash-style.

    Args:
      q: [B, H, Lq, d] queries.
      k: [B, H, Lk, d] keys (Lk may exceed Lq, e.g. a KV cache).
      v: [B, H, Lk, d] values.
      q_offset: [B] int32 — absolute position of q row 0 per sequence
        (0 for prefill; the decode position for single-token decode).
      block_q / block_k: VMEM tile sizes; Lq % block_q == 0 and
        Lk % block_k == 0 are required (callers pad to bucket shapes).

    Returns:
      [B, H, Lq, d] attention outputs.
    """
    b, h, lq, d = q.shape
    lk = k.shape[2]
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    # shrink blocks to the nearest divisor (bucket shapes are powers of
    # two, so this only triggers for oddly-shaped test configs)
    while lq % block_q != 0:
        block_q //= 2
    while lk % block_k != 0:
        block_k //= 2
    scale = 1.0 / (d ** 0.5)

    grid = (b, h, lq // block_q)
    kernel = functools.partial(_attention_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda i, j, qi: (i, j, qi, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda i, j, qi: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda i, j, qi: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j, qi: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda i, j, qi: (i, j, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        interpret=True,
    )(q, k, v, q_offset)
