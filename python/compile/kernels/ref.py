"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contract: pytest (and hypothesis sweeps) assert
``allclose(kernel(x), ref(x))`` across shapes and dtypes. The references
are written for clarity, not speed — materialized masks, full softmax.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, q_offset):
    """Materialized-mask causal attention.

    Same signature as :func:`compile.kernels.attention.flash_attention`.
    q: [B,H,Lq,d], k/v: [B,H,Lk,d], q_offset: [B] int32.
    """
    b, h, lq, d = q.shape
    lk = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    q_pos = q_offset[:, None] + jnp.arange(lq)[None, :]          # [B, Lq]
    kv_pos = jnp.arange(lk)                                       # [Lk]
    mask = kv_pos[None, None, :] <= q_pos[:, :, None]             # [B, Lq, Lk]
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    # rows that are entirely masked (padding queries) -> output zeros
    any_valid = jnp.any(mask, axis=-1)[:, None, :, None]          # [B,1,Lq,1]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return jnp.where(any_valid, out, 0.0)


def ref_mlp(x, w1, b1, w2, b2, w3, b3):
    """Two-hidden-layer GELU MLP with scalar head: the probe architecture
    (paper appendix A.1: 200–200–1, GELU)."""
    h1 = jax.nn.gelu(x @ w1 + b1)
    h2 = jax.nn.gelu(h1 @ w2 + b2)
    return (h2 @ w3 + b3)[..., 0]


def ref_layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last dimension."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
