"""L1 Pallas kernels (build-time).

All kernels lower with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls, and under ``jax.jit`` tracing interpret-mode pallas
emits plain HLO ops, so the kernels ship inside the AOT artifacts.

The kernel structure targets TPU idioms (see DESIGN.md §Hardware-Adaptation):
VMEM-sized blocks via BlockSpec, MXU-friendly contraction shapes, online
softmax instead of materialized score matrices.
"""

from compile.kernels.attention import flash_attention
from compile.kernels.fused_mlp import fused_mlp
from compile.kernels.layernorm import fused_layernorm

__all__ = ["flash_attention", "fused_mlp", "fused_layernorm"]
