# Build-time compile path: JAX model (L2) + Pallas kernels (L1) + AOT lowering.
# Nothing in this package is imported at serving time — the rust coordinator
# consumes only the artifacts this package emits.
