"""L2: the JAX compute graph — generator LM, PRM, embedders, probe.

Everything here is traced once at build time by ``aot.py`` and lowered to
HLO text; the rust engine executes the artifacts via PJRT. The forward
passes call the L1 Pallas kernels (``use_pallas=True``, the default for
AOT) or the pure-jnp references (``use_pallas=False``, used for fast
build-time *training* — numerics are asserted identical by pytest).

Models
------
* **Generator LM** — decoder-only transformer (4L, d=128, 4 heads,
  char-level vocab) standing in for Qwen2.5-1.5B-Instruct. Exposes
  ``lm_prefill`` (prompt → logits + KV cache) and ``lm_decode`` (one
  token, functional KV-cache update) — the two engine entry points.
* **PRM** — smaller transformer (2L, d=96) scoring CoT *prefixes* with a
  correct-so-far probability, standing in for Qwen2.5-Math-PRM-7B.
* **Embedders** — ``embed_pool`` (max-pooled final hidden states; the
  "Qwen embeddings" of appendix A.1) and ``embed_small`` (mean-pooled
  token embeddings; the compact "BERT" variant of appendix A.3).
* **Probe** — the paper's 200–200–1 GELU MLP over
  ``[embedding ⊕ strategy features ⊕ query length]``, plus its Adam
  train step (lowered so the *rust* side trains the probe).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels.attention import flash_attention
from compile.kernels.fused_mlp import fused_mlp
from compile.kernels.layernorm import fused_layernorm
from compile.kernels import ref
from compile import optim


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab_size: int = 22
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = 160

    @property
    def d_head(self):
        return self.d_model // self.n_heads


# Generator: the "policy" model the strategies sample from. Sized for the
# single-core CPU testbed (see DESIGN.md §2 — the substitution preserves
# the difficulty gradient, not the parameter count).
LM_CONFIG = TransformerConfig(d_model=96, n_heads=4, n_layers=3, d_ff=384)
# PRM: same architecture as the generator — it is initialized from the
# trained LM weights (the LM already encodes the arithmetic; verification
# is a cheap fine-tune, whereas a small cold-start classifier gets no
# gradient signal from 1-bit labels on this budget).
PRM_CONFIG = LM_CONFIG

PROBE_HIDDEN = 200
# probe features: 96-d embedding ⊕ 4 strategy scalars ⊕ 6 method one-hot
# (the rust decoding-method registry: majority_vote, bon_naive,
# bon_weighted, beam, mv_early, beam_latency — append-only order)
# ⊕ 1 query length  (see rust/src/probe/features.rs — must match!)
PROBE_FEATURES = LM_CONFIG.d_model + 4 + 6 + 1


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def transformer_init(key, cfg: TransformerConfig, with_prm_head=False):
    """Initialize a transformer pytree. Dict keys sort deterministically,
    which fixes the tree-flatten order shared with the rust runtime."""
    keys = iter(jax.random.split(key, 8 + 12 * cfg.n_layers))

    def dense(k, fan_in, fan_out):
        scale = (2.0 / (fan_in + fan_out)) ** 0.5
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) * scale

    d = cfg.d_model
    params = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab_size, d), jnp.float32) * 0.02,
        "pos_emb": jax.random.normal(next(keys), (cfg.max_seq, d), jnp.float32) * 0.02,
        "layers": [
            {
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "wq": dense(next(keys), d, d),
                "wk": dense(next(keys), d, d),
                "wv": dense(next(keys), d, d),
                "wo": dense(next(keys), d, d),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
                "w1": dense(next(keys), d, cfg.d_ff),
                "b1": jnp.zeros((cfg.d_ff,), jnp.float32),
                "w2": dense(next(keys), cfg.d_ff, d),
                "b2": jnp.zeros((d,), jnp.float32),
            }
            for _ in range(cfg.n_layers)
        ],
        "ln_f_g": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
        "head": dense(next(keys), d, cfg.vocab_size),
    }
    if with_prm_head:
        params["prm_head"] = dense(next(keys), d, 1)
        params["prm_head_b"] = jnp.zeros((1,), jnp.float32)
    return params


def probe_init(key, f_dim=PROBE_FEATURES, hidden=PROBE_HIDDEN):
    """The paper's probe: MLP f_dim→200→200→1 with GELU (appendix A.1)."""
    k1, k2, k3 = jax.random.split(key, 3)

    def dense(k, fan_in, fan_out):
        scale = (2.0 / (fan_in + fan_out)) ** 0.5
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) * scale

    return {
        "w1": dense(k1, f_dim, hidden),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": dense(k2, hidden, hidden),
        "b2": jnp.zeros((hidden,), jnp.float32),
        "w3": dense(k3, hidden, 1),
        "b3": jnp.zeros((1,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# transformer body
# ---------------------------------------------------------------------------


def _layernorm(x, g, b, use_pallas):
    """LayerNorm over the last dim of [..., d]."""
    if not use_pallas:
        return ref.ref_layernorm(x, g, b)
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    out = fused_layernorm(x.reshape(rows, shape[-1]), g, b)
    return out.reshape(shape)


def _attention(q, k, v, q_offset, use_pallas):
    if use_pallas:
        return flash_attention(q, k, v, q_offset)
    return ref.ref_attention(q, k, v, q_offset)


def _split_heads(x, cfg):
    # [B, L, d] -> [B, H, L, dh]
    b, l, _ = x.shape
    return x.reshape(b, l, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    # [B, H, L, dh] -> [B, L, d]
    b, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def transformer_hidden(params, tokens, cfg: TransformerConfig, use_pallas):
    """Full causal forward over a padded token block.

    tokens: [B, L] int32 (pad = 0). Returns final hidden states [B, L, d]
    (pre-head, post-final-layernorm) and the per-layer K/V used — the
    latter feeds the prefill cache.
    """
    b, l = tokens.shape
    pos = jnp.arange(l)
    x = params["tok_emb"][tokens] + params["pos_emb"][pos][None, :, :]
    zeros = jnp.zeros((b,), jnp.int32)
    ks, vs = [], []
    for layer in params["layers"]:
        h = _layernorm(x, layer["ln1_g"], layer["ln1_b"], use_pallas)
        q = _split_heads(h @ layer["wq"], cfg)
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        a = _attention(q, k, v, zeros, use_pallas)
        x = x + _merge_heads(a) @ layer["wo"]
        h = _layernorm(x, layer["ln2_g"], layer["ln2_b"], use_pallas)
        x = x + jax.nn.gelu(h @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
        ks.append(k)
        vs.append(v)
    hidden = _layernorm(x, params["ln_f_g"], params["ln_f_b"], use_pallas)
    return hidden, ks, vs


def lm_logits(params, tokens, cfg=LM_CONFIG, use_pallas=False):
    """All-position logits [B, L, V] — the training objective's forward."""
    hidden, _, _ = transformer_hidden(params, tokens, cfg, use_pallas)
    return hidden @ params["head"]


# ---------------------------------------------------------------------------
# engine entry points (AOT'd)
# ---------------------------------------------------------------------------


def lm_prefill(params, tokens, lens, cfg=LM_CONFIG, use_pallas=True):
    """Prompt ingestion.

    tokens: [B, Lp] int32 padded prompts; lens: [B] int32 true lengths.
    Returns (last_logits [B, V], k_cache, v_cache) where the caches are
    [n_layers, B, H, max_seq, dh] with positions >= Lp zero-filled.
    """
    b, lp = tokens.shape
    hidden, ks, vs = transformer_hidden(params, tokens, cfg, use_pallas)
    last = hidden[jnp.arange(b), lens - 1]  # [B, d]
    last_logits = last @ params["head"]

    pad = cfg.max_seq - lp
    k_cache = jnp.stack([jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) for k in ks])
    v_cache = jnp.stack([jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) for v in vs])
    return last_logits, k_cache, v_cache


def lm_decode(params, k_cache, v_cache, tok, pos, cfg=LM_CONFIG, use_pallas=True):
    """One decode step with a functional KV-cache update.

    k_cache/v_cache: [n_layers, B, H, max_seq, dh]; tok: [B] int32 (the
    token just produced); pos: [B] int32 (its absolute position). Returns
    (next_logits [B, V], new_k_cache, new_v_cache).
    """
    b = tok.shape[0]
    x = params["tok_emb"][tok] + params["pos_emb"][pos]  # [B, d]
    onehot = (jnp.arange(cfg.max_seq)[None, :] == pos[:, None])  # [B, max_seq]
    write_mask = onehot[None, :, None, :, None]  # [1, B, 1, max_seq, 1] — bool

    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = _layernorm(x, layer["ln1_g"], layer["ln1_b"], use_pallas)  # [B, d]
        q = (h @ layer["wq"]).reshape(b, cfg.n_heads, 1, cfg.d_head)
        k_new = (h @ layer["wk"]).reshape(b, cfg.n_heads, cfg.d_head)
        v_new = (h @ layer["wv"]).reshape(b, cfg.n_heads, cfg.d_head)
        k_l = jnp.where(write_mask[0], k_new[:, :, None, :], k_cache[li])
        v_l = jnp.where(write_mask[0], v_new[:, :, None, :], v_cache[li])
        a = _attention(q, k_l, v_l, pos, use_pallas)  # [B, H, 1, dh]
        x = x + a.reshape(b, cfg.d_model) @ layer["wo"]
        h = _layernorm(x, layer["ln2_g"], layer["ln2_b"], use_pallas)
        x = x + jax.nn.gelu(h @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
        new_k.append(k_l)
        new_v.append(v_l)

    hidden = _layernorm(x, params["ln_f_g"], params["ln_f_b"], use_pallas)
    logits = hidden @ params["head"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


RESULT_SEP_EQ = 15  # '='
RESULT_SEP_COLON = 18  # ':'
ANSWER_CHAR = 21  # 'A'


def prm_score(params, tokens, lens, cfg=LM_CONFIG, use_pallas=True):
    """Process-reward score for CoT prefixes — **likelihood-based**.

    The PRM is the trained generator itself scoring its own arithmetic: a
    prefix's reward is the geometric-mean probability the LM assigns to
    every *step-result digit* (the token after each `=`, and the final
    answer digit after `A:`). An arithmetic slip makes its result digit
    very unlikely under a model that has learned the step function, so
    corrupted prefixes score near zero (measured separation: ~0.6–0.9 vs
    0.04–0.4 — see DESIGN.md §2). A discriminative PRM head trained on
    1-bit prefix labels found no gradient signal at this model scale.

    tokens: [B, L] int32 (query + partial solution); lens: [B] true
    lengths. Returns [B] score in (0, 1]; prefixes with no completed
    result digit yet score a neutral 0.5.
    """
    hidden, _, _ = transformer_hidden(params, tokens, cfg, use_pallas)
    logits = hidden @ params["head"]  # [B, L, V]
    logp = jax.nn.log_softmax(logits, axis=-1)

    cur = tokens[:, :-1]  # position i
    nxt = tokens[:, 1:]   # its target
    prev = jnp.pad(tokens, ((0, 0), (1, 0)))[:, :-2]  # position i-1
    # the target must be a digit: this excludes the query's own `=?`
    is_digit = (nxt >= 2) & (nxt <= 11)
    is_result = is_digit & (
        (cur == RESULT_SEP_EQ)
        | ((cur == RESULT_SEP_COLON) & (prev == ANSWER_CHAR))
    )
    # only positions whose target is inside the true prefix
    valid = (jnp.arange(cur.shape[1])[None, :] + 1) < lens[:, None]
    mask = (is_result & valid).astype(jnp.float32)

    tok_logp = jnp.take_along_axis(logp[:, :-1, :], nxt[:, :, None], axis=-1)[:, :, 0]
    total = jnp.sum(tok_logp * mask, axis=1)
    count = jnp.sum(mask, axis=1)
    geo_mean = jnp.exp(total / jnp.maximum(count, 1.0))
    return jnp.where(count > 0, geo_mean, 0.5)


def embed_pool(params, tokens, lens, cfg=LM_CONFIG, use_pallas=True):
    """Query embedding: max-pooled final hidden states (the paper's
    "Qwen2.5 embeddings", appendix A.1, scaled to this generator)."""
    hidden, _, _ = transformer_hidden(params, tokens, cfg, use_pallas)
    valid = jnp.arange(tokens.shape[1])[None, :] < lens[:, None]  # [B, L]
    masked = jnp.where(valid[:, :, None], hidden, -1e30)
    return jnp.max(masked, axis=1)  # [B, d]


def embed_small(params, tokens, lens, cfg=LM_CONFIG):
    """Compact query embedding: mean-pooled *token embeddings* (no
    transformer body) — the cheap "BERT-like" variant of appendix A.3."""
    emb = params["tok_emb"][tokens]  # [B, L, d]
    valid = (jnp.arange(tokens.shape[1])[None, :] < lens[:, None]).astype(jnp.float32)
    summed = jnp.sum(emb * valid[:, :, None], axis=1)
    return summed / jnp.maximum(lens[:, None].astype(jnp.float32), 1.0)


# ---------------------------------------------------------------------------
# in-graph generation (the engine entry points for decoding)
# ---------------------------------------------------------------------------
#
# The xla crate's `execute` returns outputs as a single *tuple buffer*
# (ExecuteOptions.untuple_result = false), so a rust-side per-token decode
# loop would have to round-trip the whole KV cache through host literals
# every step (~67 MB/step at B=32). Instead the generation loop lives
# in-graph: prefill + lax.while_loop over decode steps with in-graph
# temperature sampling and per-sequence stopping. The KV cache never
# leaves the device; rust sends (prompt, rng key, temperature) and gets
# back (tokens [B, T], gen_len [B]).

EOS_ID = 1
SEP_ID = 17  # ';' — beam-search step boundary


def lm_generate(params, tokens, lens, key, temperature, *, max_new=96,
                stop_at_sep=False, cfg=LM_CONFIG, use_pallas=True):
    """Sample up to ``max_new`` tokens per sequence.

    tokens: [B, L] int32 padded prompts; lens: [B] int32; key: [2] uint32
    (threefry key data, supplied by the rust RNG); temperature: f32 scalar
    (0 → greedy).

    Stops each sequence at EOS (``\\n``), and additionally at ``;`` when
    ``stop_at_sep`` — the beam-search chunk variant, which generates one
    CoT step then yields to the PRM for scoring.

    Returns (gen [B, max_new] int32 — 0-padded after stop, gen_len [B]).
    """
    b = tokens.shape[0]
    key = jax.random.wrap_key_data(key, impl="threefry2x32")
    last_logits, k_cache, v_cache = lm_prefill(params, tokens, lens, cfg, use_pallas)

    def cond(state):
        step, _, _, _, _, done, _, _, _ = state
        return (step < max_new) & ~jnp.all(done)

    def body(state):
        step, logits, k_c, v_c, pos, done, out, gen_len, key = state
        key, sub = jax.random.split(key)
        safe_t = jnp.maximum(temperature, 1e-4)
        sampled = jax.random.categorical(sub, logits / safe_t, axis=-1)
        greedy = jnp.argmax(logits, axis=-1)
        tok = jnp.where(temperature > 0.0, sampled, greedy).astype(jnp.int32)
        tok = jnp.where(done, 0, tok)
        out = jax.lax.dynamic_update_slice(out, tok[:, None], (0, step))
        gen_len = gen_len + (~done).astype(jnp.int32)
        stop = (tok == EOS_ID) | (stop_at_sep & (tok == SEP_ID))
        logits, k_c, v_c = lm_decode(params, k_c, v_c, tok, pos, cfg, use_pallas)
        return (step + 1, logits, k_c, v_c, pos + 1, done | stop, out, gen_len, key)

    out0 = jnp.zeros((b, max_new), jnp.int32)
    len0 = jnp.zeros((b,), jnp.int32)
    state = (0, last_logits, k_cache, v_cache, lens, jnp.zeros((b,), bool), out0, len0, key)
    state = jax.lax.while_loop(cond, body, state)
    return state[6], state[7]


# ---------------------------------------------------------------------------
# probe forward + train step
# ---------------------------------------------------------------------------


def probe_fwd(params, feats, use_pallas=True):
    """Probe logits [B] for feature rows [B, F]."""
    if use_pallas:
        return fused_mlp(
            feats,
            params["w1"], params["b1"],
            params["w2"], params["b2"],
            params["w3"], params["b3"],
        )
    return ref.ref_mlp(
        feats,
        params["w1"], params["b1"],
        params["w2"], params["b2"],
        params["w3"], params["b3"],
    )


def probe_loss(params, feats, labels):
    """BCE-with-logits against soft labels (paper appendix A.1).

    The pallas fused_mlp is forward-only (the AOT'd train step must be
    differentiable), so the loss uses the reference forward — pytest
    asserts the two forwards agree to float tolerance.
    """
    z = probe_fwd(params, feats, use_pallas=False)
    # stable BCE with logits
    per = jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return jnp.mean(per)


def probe_train_step(params, m, v, step, feats, labels, lr=1e-3):
    """One Adam step on the probe — AOT'd and driven from rust.

    step: f32 scalar (1-based). Returns (params', m', v', loss).
    """
    loss, grads = jax.value_and_grad(probe_loss)(params, feats, labels)
    params, m, v = optim.adam_update(grads, params, m, v, step, lr)
    return params, m, v, loss


# ---------------------------------------------------------------------------
# build-time sampling (used by train_lm.py to calibrate difficulty)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "max_new"))
def greedy_generate(params, tokens, lens, cfg=LM_CONFIG, max_new=96):
    """Greedy decoding used only for build-time sanity evaluation."""
    last_logits, k_cache, v_cache = lm_prefill(params, tokens, lens, cfg, use_pallas=False)

    def body(carry, _):
        logits, k_c, v_c, pos, done = carry
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = jnp.where(done, 0, tok)
        logits, k_c, v_c = lm_decode(params, k_c, v_c, tok, pos, cfg, use_pallas=False)
        done = done | (tok == 1)  # EOS
        return (logits, k_c, v_c, pos + 1, done), tok

    b = tokens.shape[0]
    init = (last_logits, k_cache, v_cache, lens, jnp.zeros((b,), bool))
    _, toks = jax.lax.scan(body, init, None, length=max_new)
    return toks.T  # [B, max_new]
