"""AOT lowering: every engine entry point → HLO text artifacts.

This is the only bridge between the python build path and the rust
request path. For each (function × batch-bucket × length-bucket) we:

1. ``jax.jit(fn).lower(*example_args)`` with the *trained* weight pytree
   as the first argument — weights stay runtime parameters, fed once by
   rust and kept device-resident;
2. convert the StableHLO module to an XlaComputation and dump **HLO
   text** (NOT a serialized proto: jax ≥ 0.5 emits 64-bit instruction
   ids that the crate's xla_extension 0.5.1 rejects; the text parser
   reassigns ids — see /opt/xla-example/README.md);
3. record the call signature in ``hlo_index.json`` so the rust runtime
   can type-check buffers before execution.

The generation loop lives **in-graph** (``model.lm_generate``): the xla
crate returns executable outputs as one tuple buffer, so a rust-side
per-token loop would round-trip the whole KV cache through host literals
each step. With in-graph generation the cache never leaves the device.

Entry points per batch bucket B ∈ {1, 4, 8, 16, 32}:
  ``lm_generate_b{B}``       — full candidate generation (T=96, stop \\n)
  ``lm_chunk_b{B}_l{L}``     — one beam-search step (T=16, stop \\n or ;)
                               for prefix length buckets L ∈ {32,64,96,128}
  ``prm_score_b{B}``         — PRM prefix scoring (length 128)
  ``embed_pool_b{B}``        — max-pooled hidden-state query embedding
  ``embed_small_b{B}``       — mean-pooled token-embedding variant
plus ``probe_fwd_b32`` and ``probe_train_b64``.

Usage: python -m compile.aot --out ../artifacts [--report]
"""

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.weights_io import flatten_with_names, load_weights

BATCH_BUCKETS = [1, 4, 8, 16, 32]
CHUNK_LENS = [32, 64, 96, 128]
QUERY_LEN = 32
PRM_LEN = 128
GEN_MAX_NEW = 96
CHUNK_MAX_NEW = 16
PROBE_FWD_BATCH = 32
PROBE_TRAIN_BATCH = 64


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def arg_sig(name, s):
    return {"name": name, "dtype": str(s.dtype), "shape": list(s.shape)}


class Lowerer:
    def __init__(self, out_dir, report=False):
        self.out_dir = out_dir
        self.index = []
        self.report = report
        self.op_counts = {}

    def lower(self, name, fn, weights, weight_set, args):
        """Lower fn(weights, *args) and record its signature."""
        t0 = time.time()
        arg_specs = [spec(a["shape"], a["dtype"]) for a in args]
        # keep_unused: the engine feeds the FULL weight list positionally,
        # so entry points that don't touch every tensor (e.g. embed_pool
        # never reads the LM head) must keep the unused parameters.
        if weights is not None:
            lowered = jax.jit(fn, keep_unused=True).lower(weights, *arg_specs)
        else:
            lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = f"hlo/{name}.hlo.txt"
        with open(f"{self.out_dir}/{path}", "w") as f:
            f.write(text)
        out_tree = jax.tree_util.tree_map(
            lambda x: {"dtype": str(x.dtype), "shape": list(x.shape)},
            lowered.out_info,
        )
        out_flat = jax.tree_util.tree_leaves(
            out_tree, is_leaf=lambda x: isinstance(x, dict) and "dtype" in x
        )
        self.index.append(
            {
                "name": name,
                "file": path,
                "weights": weight_set,
                "args": [
                    {"name": a["name"], "dtype": _dt(a["dtype"]), "shape": list(a["shape"])}
                    for a in args
                ],
                "outputs": [
                    {"dtype": _dt(o["dtype"]), "shape": o["shape"]} for o in out_flat
                ],
            }
        )
        if self.report:
            self.op_counts[name] = text.count("\n")
        print(f"[aot] {name}: {len(text) / 1e3:.0f} kB HLO ({time.time() - t0:.1f}s)")


def _dt(dtype):
    s = str(dtype)
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}.get(s, s)


def a(name, shape, dtype="float32"):
    return {"name": name, "shape": shape, "dtype": dtype}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--report", action="store_true", help="print HLO op-count table")
    ap.add_argument(
        "--pallas-decode",
        action="store_true",
        help="lower the generation loop with the pallas attention kernel "
        "(ablation; default uses the XLA-fused reference formulation — "
        "interpret-mode pallas costs 5.7x on the crate's XLA 0.5.1 CPU "
        "backend, see EXPERIMENTS.md §Perf)",
    )
    args = ap.parse_args()
    decode_pallas = bool(args.pallas_decode)

    # --- load trained weights (shapes must match the manifests) ---
    lm_like = M.transformer_init(jax.random.PRNGKey(0), M.LM_CONFIG)
    lm_params, lm_manifest = load_weights(args.out, "lm", lm_like)
    lm_params = jax.tree_util.tree_map(jnp.asarray, lm_params)

    cfg = M.LM_CONFIG
    nl, h, dh, vsz = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.vocab_size
    lmax = cfg.max_seq

    lw = Lowerer(args.out, report=args.report)

    _ = (nl, h, dh, vsz)  # dims recorded in meta below

    for b in BATCH_BUCKETS:
        lw.lower(
            f"lm_generate_b{b}",
            functools.partial(
                M.lm_generate, max_new=GEN_MAX_NEW, stop_at_sep=False,
                cfg=cfg, use_pallas=decode_pallas,
            ),
            lm_params,
            "lm",
            [
                a("tokens", [b, QUERY_LEN], "int32"),
                a("lens", [b], "int32"),
                a("key", [2], "uint32"),
                a("temperature", []),
            ],
        )
        # beam-search chunk: re-prefills the (query + steps-so-far) prefix
        # at the smallest length bucket that fits, generates one CoT step
        for lp in CHUNK_LENS:
            lw.lower(
                f"lm_chunk_b{b}_l{lp}",
                functools.partial(
                    M.lm_generate, max_new=CHUNK_MAX_NEW, stop_at_sep=True,
                    cfg=cfg, use_pallas=decode_pallas,
                ),
                lm_params,
                "lm",
                [
                    a("tokens", [b, lp], "int32"),
                    a("lens", [b], "int32"),
                    a("key", [2], "uint32"),
                    a("temperature", []),
                ],
            )
        # the PRM is likelihood-based over the generator's own weights
        lw.lower(
            f"prm_score_b{b}",
            functools.partial(M.prm_score, cfg=M.LM_CONFIG, use_pallas=decode_pallas),
            lm_params,
            "lm",
            [a("tokens", [b, PRM_LEN], "int32"), a("lens", [b], "int32")],
        )
        lw.lower(
            f"embed_pool_b{b}",
            functools.partial(M.embed_pool, cfg=cfg, use_pallas=True),
            lm_params,
            "lm",
            [a("tokens", [b, QUERY_LEN], "int32"), a("lens", [b], "int32")],
        )
        lw.lower(
            f"embed_small_b{b}",
            functools.partial(M.embed_small, cfg=cfg),
            lm_params,
            "lm",
            [a("tokens", [b, QUERY_LEN], "int32"), a("lens", [b], "int32")],
        )

    # --- probe: forward + train step (trained from rust) ---
    probe_like = M.probe_init(jax.random.PRNGKey(7))
    lw.lower(
        f"probe_fwd_b{PROBE_FWD_BATCH}",
        functools.partial(M.probe_fwd, use_pallas=True),
        probe_like,
        "probe",
        [a("feats", [PROBE_FWD_BATCH, M.PROBE_FEATURES])],
    )

    def train_step(params, m, v, step, feats, labels):
        return M.probe_train_step(params, m, v, step, feats, labels)

    probe_m = jax.tree_util.tree_map(jnp.zeros_like, probe_like)
    lowered = jax.jit(train_step, keep_unused=True).lower(
        probe_like,
        probe_m,
        probe_m,
        spec([], jnp.float32),
        spec([PROBE_TRAIN_BATCH, M.PROBE_FEATURES]),
        spec([PROBE_TRAIN_BATCH]),
    )
    text = to_hlo_text(lowered)
    with open(f"{args.out}/hlo/probe_train_b{PROBE_TRAIN_BATCH}.hlo.txt", "w") as f:
        f.write(text)
    lw.index.append(
        {
            "name": f"probe_train_b{PROBE_TRAIN_BATCH}",
            "file": f"hlo/probe_train_b{PROBE_TRAIN_BATCH}.hlo.txt",
            "weights": "probe_train",  # probe params + m + v as leading args
            "args": [
                {"name": "step", "dtype": "f32", "shape": []},
                {"name": "feats", "dtype": "f32", "shape": [PROBE_TRAIN_BATCH, M.PROBE_FEATURES]},
                {"name": "labels", "dtype": "f32", "shape": [PROBE_TRAIN_BATCH]},
            ],
            "outputs": [],  # params', m', v', loss — structured like inputs
        }
    )
    print(f"[aot] probe_train_b{PROBE_TRAIN_BATCH}: {len(text) / 1e3:.0f} kB HLO")

    # --- probe initial weights (rust trains from this init) ---
    from compile.weights_io import save_weights

    save_weights(
        probe_like,
        args.out,
        "probe",
        config={"features": M.PROBE_FEATURES, "hidden": M.PROBE_HIDDEN},
    )

    # --- index + metadata ---
    meta = {
        "lm": lm_manifest["config"],
        "prm": {"kind": "lm_likelihood", **lm_manifest["config"]},
        "probe": {"features": M.PROBE_FEATURES, "hidden": M.PROBE_HIDDEN},
        "batch_buckets": BATCH_BUCKETS,
        "chunk_lens": CHUNK_LENS,
        "query_len": QUERY_LEN,
        "prm_len": PRM_LEN,
        "gen_max_new": GEN_MAX_NEW,
        "chunk_max_new": CHUNK_MAX_NEW,
        "probe_fwd_batch": PROBE_FWD_BATCH,
        "probe_train_batch": PROBE_TRAIN_BATCH,
        "max_seq": lmax,
    }
    with open(f"{args.out}/hlo_index.json", "w") as f:
        json.dump({"meta": meta, "executables": lw.index}, f, indent=1)
    print(f"[aot] wrote {len(lw.index)} executables to {args.out}/hlo_index.json")

    if args.report:
        print("\n[aot] HLO line counts (proxy for op count):")
        for name, n in sorted(lw.op_counts.items(), key=lambda kv: -kv[1]):
            print(f"  {name:28s} {n:7d}")


if __name__ == "__main__":
    main()
