"""Corpus loading + tokenization for the build-time trainers.

The rust side is the system of record for both the task distribution and
the vocabulary: ``ttc taskgen`` emits ``vocab.json`` and the JSONL corpora
this module reads. Tokenization here must agree byte-for-byte with
``rust/src/tokenizer.rs`` — enforced by loading the emitted vocab rather
than redefining it.
"""

import json

import numpy as np


class Vocab:
    """Char-level vocab loaded from the rust-emitted ``vocab.json``."""

    def __init__(self, path):
        with open(path) as f:
            spec = json.load(f)
        self.vocab_size = spec["vocab_size"]
        self.pad_id = spec["pad_id"]
        self.eos_id = spec["eos_id"]
        tokens = spec["tokens"]
        self.to_char = tokens
        self.to_id = {}
        for i, t in enumerate(tokens):
            if i == self.pad_id:
                continue
            assert len(t) == 1, f"non-char token {t!r}"
            self.to_id[t] = i

    def encode(self, text):
        return [self.to_id[c] for c in text]

    def decode(self, ids):
        return "".join(self.to_char[i] for i in ids if i != self.pad_id)


def read_jsonl(path):
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def pad_to(ids, length, pad_id):
    """Right-pad (or verify fit) to a fixed length."""
    assert len(ids) <= length, f"sequence of {len(ids)} exceeds padded length {length}"
    return ids + [pad_id] * (length - len(ids))


def lm_batches(records, vocab, seq_len, batch_size, rng):
    """Yield (tokens [B, L] int32) batches from lm_corpus records,
    shuffled each epoch. Documents longer than seq_len are skipped
    (none exist for the default task config — asserted by taskgen tests)."""
    idx = np.arange(len(records))
    rng.shuffle(idx)
    batch = []
    for i in idx:
        ids = vocab.encode(records[i]["text"])
        if len(ids) > seq_len:
            continue
        batch.append(pad_to(ids, seq_len, vocab.pad_id))
        if len(batch) == batch_size:
            yield np.asarray(batch, np.int32)
            batch = []
    # drop remainder (static-shape training)


def prm_batches(records, vocab, seq_len, batch_size, rng):
    """Yield (tokens [B, L] int32, lens [B] int32, labels [B] f32)."""
    idx = np.arange(len(records))
    rng.shuffle(idx)
    toks, lens, labels = [], [], []
    for i in idx:
        ids = vocab.encode(records[i]["text"])
        if len(ids) > seq_len:
            continue
        toks.append(pad_to(ids, seq_len, vocab.pad_id))
        lens.append(len(ids))
        labels.append(float(records[i]["label"]))
        if len(toks) == batch_size:
            yield (
                np.asarray(toks, np.int32),
                np.asarray(lens, np.int32),
                np.asarray(labels, np.float32),
            )
            toks, lens, labels = [], [], []
