"""Build-time training of the generator LM on the rust-emitted corpus.

Stand-in for the paper's Qwen2.5-1.5B-Instruct (DESIGN.md §2): a small
decoder-only transformer trained on modular-arithmetic CoT documents. The
training recipe is deliberately tuned so that, under temperature sampling,
per-step error rates are non-trivial and compound with chain length —
giving the difficulty gradient the paper's adaptive router exploits.

Usage: python -m compile.train_lm --data ../artifacts/data --out ../artifacts
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile import optim
from compile.weights_io import save_weights

TRAIN_LEN = 80  # max document length is ~70 chars for k=8


@jax.jit
def lm_train_step(params, m, v, step, tokens, lr):
    """Next-token cross-entropy with pad masking; one Adam step."""

    def loss_fn(p):
        inputs = tokens[:, :-1]
        targets = tokens[:, 1:]
        logits = M.lm_logits(p, inputs, M.LM_CONFIG, use_pallas=False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[:, :, None], axis=-1)[:, :, 0]
        mask = (targets != 0).astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, m, v = optim.adam_update(grads, params, m, v, step, lr=lr)
    return params, m, v, loss


def sampled_generate(params, tokens, lens, key, temperature, max_new=96):
    """Temperature sampling — build-time difficulty calibration only (the
    serving-path sampler lives in rust/src/engine/sampler.rs)."""

    @jax.jit
    def run(params, tokens, lens, key):
        last_logits, k_c, v_c = M.lm_prefill(
            params, tokens, lens, M.LM_CONFIG, use_pallas=False
        )

        def body(carry, step_key):
            logits, k_c, v_c, pos, done = carry
            tok = jax.random.categorical(step_key, logits / temperature, axis=-1)
            tok = jnp.where(done, 0, tok.astype(jnp.int32))
            logits, k_c, v_c = M.lm_decode(
                params, k_c, v_c, tok, pos, M.LM_CONFIG, use_pallas=False
            )
            done = done | (tok == 1)
            return (logits, k_c, v_c, pos + 1, done), tok

        b = tokens.shape[0]
        init = (last_logits, k_c, v_c, lens, jnp.zeros((b,), bool))
        _, toks = jax.lax.scan(body, init, jax.random.split(key, max_new))
        return toks.T

    return run(params, tokens, lens, key)


def difficulty_eval(params, vocab, queries, key, temperature=0.8, samples=4):
    """Per-difficulty sampled accuracy — the calibration signal that the
    task substitution preserves the paper's difficulty gradient."""
    by_k = {}
    for q in queries:
        by_k.setdefault(q["k"], []).append(q)
    report = {}
    for k, qs in sorted(by_k.items()):
        correct = total = 0
        for q in qs:
            prompt = q["query"] + "S:"
            ids = vocab.encode(prompt)
            toks = np.zeros((samples, 32), np.int32)
            toks[:, : len(ids)] = ids
            lens = np.full((samples,), len(ids), np.int32)
            key, sub = jax.random.split(key)
            out = np.asarray(
                sampled_generate(params, jnp.asarray(toks), jnp.asarray(lens), sub, temperature)
            )
            for row in out:
                text = vocab.decode(row[: int(np.argmax(row == 1)) + 1] if (row == 1).any() else row)
                idx = text.rfind("A:")
                ans = ""
                if idx >= 0:
                    ans = "".join(c for c in text[idx + 2 :] if c.isdigit())
                correct += ans == q["answer"]
                total += 1
        report[k] = correct / max(total, 1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-queries", type=int, default=36)
    args = ap.parse_args()

    vocab = D.Vocab(f"{args.data}/vocab.json")
    records = D.read_jsonl(f"{args.data}/lm_corpus.jsonl")
    print(f"[train_lm] {len(records)} documents, vocab {vocab.vocab_size}")

    key = jax.random.PRNGKey(args.seed)
    params = M.transformer_init(key, M.LM_CONFIG)
    m, v = optim.adam_init(params)
    rng = np.random.default_rng(args.seed)

    total_steps = args.epochs * (len(records) // args.batch)
    step = 0
    t0 = time.time()
    for epoch in range(args.epochs):
        for tokens in D.lm_batches(records, vocab, TRAIN_LEN, args.batch, rng):
            step += 1
            # cosine decay 1e-3 → 1e-4
            import math
            lr = 1e-4 + 0.5 * (1e-3 - 1e-4) * (1 + math.cos(math.pi * step / total_steps))
            params, m, v, loss = lm_train_step(
                params, m, v, float(step), jnp.asarray(tokens), lr
            )
            if step % 50 == 0:
                print(
                    f"[train_lm] epoch {epoch} step {step} loss {float(loss):.4f} "
                    f"({time.time() - t0:.0f}s)"
                )

    # difficulty calibration on held-out queries
    queries = D.read_jsonl(f"{args.data}/queries_train.jsonl")[: args.eval_queries]
    report = difficulty_eval(params, vocab, queries, jax.random.PRNGKey(args.seed + 1))
    print(f"[train_lm] sampled accuracy by difficulty k: {report}")

    cfg = dataclasses.asdict(M.LM_CONFIG)
    save_weights(params, args.out, "lm", config=cfg)
    with open(f"{args.out}/lm_train_report.json", "w") as f:
        json.dump(
            {"final_loss": float(loss), "steps": step, "difficulty_accuracy": report},
            f,
            indent=1,
        )
    print(f"[train_lm] saved weights to {args.out}/lm_weights.bin")


if __name__ == "__main__":
    main()
