"""Weight serialization shared with the rust runtime.

Format (see ``rust/src/runtime/weights.rs`` for the reader):

* ``<name>_weights.bin`` — raw little-endian f32, all tensors concatenated
  in **jax tree-flatten order** (dicts sorted by key — deterministic).
* ``<name>_manifest.json`` — ``{"params": [{"name", "shape", "offset",
  "size"}...], "config": {...}}`` where offsets/sizes are in elements.

The AOT'd executables take the same flattened tensor list as their leading
arguments, so the manifest order IS the call convention.
"""

import json

import jax
import numpy as np


def flatten_with_names(params):
    """Flatten a pytree to [(dotted_name, leaf)] in tree_leaves order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = ".".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(key):
    # DictKey(key='x') -> x ; SequenceKey(idx=3) -> 3
    if hasattr(key, "key"):
        return str(key.key)
    if hasattr(key, "idx"):
        return str(key.idx)
    return str(key)


def save_weights(params, out_dir, name, config=None):
    """Write ``<name>_weights.bin`` + ``<name>_manifest.json``."""
    named = flatten_with_names(params)
    entries = []
    offset = 0
    chunks = []
    for pname, leaf in named:
        arr = np.asarray(leaf, dtype=np.float32)
        size = int(arr.size)
        entries.append(
            {"name": pname, "shape": list(arr.shape), "offset": offset, "size": size}
        )
        chunks.append(arr.reshape(-1))
        offset += size
    blob = np.concatenate(chunks) if chunks else np.zeros((0,), np.float32)
    bin_path = f"{out_dir}/{name}_weights.bin"
    man_path = f"{out_dir}/{name}_manifest.json"
    blob.astype("<f4").tofile(bin_path)
    with open(man_path, "w") as f:
        json.dump(
            {"params": entries, "total_elems": offset, "config": config or {}},
            f,
            indent=1,
        )
    return bin_path, man_path


def load_weights(out_dir, name, treedef_like):
    """Load weights back into the structure of ``treedef_like`` (a pytree
    with arrays of the right shapes) — used by aot.py and tests."""
    with open(f"{out_dir}/{name}_manifest.json") as f:
        manifest = json.load(f)
    blob = np.fromfile(f"{out_dir}/{name}_weights.bin", dtype="<f4")
    assert blob.size == manifest["total_elems"], "weights blob size mismatch"
    leaves_like, treedef = jax.tree_util.tree_flatten(treedef_like)
    entries = manifest["params"]
    assert len(entries) == len(leaves_like), (
        f"manifest has {len(entries)} tensors, structure needs {len(leaves_like)}"
    )
    leaves = []
    for entry, like in zip(entries, leaves_like):
        arr = blob[entry["offset"] : entry["offset"] + entry["size"]]
        arr = arr.reshape(entry["shape"])
        assert tuple(arr.shape) == tuple(np.shape(like)), (
            f"shape mismatch for {entry['name']}: {arr.shape} vs {np.shape(like)}"
        )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
