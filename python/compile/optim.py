"""Hand-rolled Adam (optax is not installed in this environment).

Used both by the build-time trainers (LM, PRM) and — lowered to HLO via
``model.probe_train_step`` — by the *rust* probe trainer, so the update
rule here is exactly what runs on the request-path side of the system.
"""

import jax
import jax.numpy as jnp


def adam_init(params):
    """Zero first/second-moment state with the same structure as params."""
    zeros = lambda p: jnp.zeros_like(p)
    return jax.tree_util.tree_map(zeros, params), jax.tree_util.tree_map(zeros, params)


def adam_update(grads, params, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step.

    ``step`` is the 1-based step count (float scalar is fine — it is traced
    into the AOT'd probe train-step).
    Returns (new_params, new_m, new_v).
    """
    m = jax.tree_util.tree_map(lambda g, m_: b1 * m_ + (1 - b1) * g, grads, m)
    v = jax.tree_util.tree_map(lambda g, v_: b2 * v_ + (1 - b2) * g * g, grads, v)
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step

    def upd(p, m_, v_):
        m_hat = m_ / bc1
        v_hat = v_ / bc2
        return p - lr * m_hat / (jnp.sqrt(v_hat) + eps)

    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, m, v
