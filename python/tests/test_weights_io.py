"""Weight serialization: python writer ↔ (simulated) rust reader contract."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.weights_io import flatten_with_names, load_weights, save_weights


@pytest.fixture
def tmp_out(tmp_path):
    return str(tmp_path)


class TestWeightsIO:
    def test_roundtrip(self, tmp_out):
        cfg = M.TransformerConfig(d_model=16, n_heads=2, n_layers=2, d_ff=32, max_seq=24)
        params = M.transformer_init(jax.random.PRNGKey(0), cfg)
        save_weights(params, tmp_out, "toy", config={"d_model": 16})
        like = M.transformer_init(jax.random.PRNGKey(1), cfg)
        loaded, manifest = load_weights(tmp_out, "toy", like)
        flat_a = jax.tree_util.tree_leaves(params)
        flat_b = jax.tree_util.tree_leaves(loaded)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        assert manifest["config"]["d_model"] == 16

    def test_manifest_order_is_tree_leaves_order(self, tmp_out):
        """The rust engine feeds weights positionally — the manifest order
        MUST equal jax tree-flatten order."""
        params = M.probe_init(jax.random.PRNGKey(0), f_dim=8, hidden=4)
        save_weights(params, tmp_out, "probe_toy")
        with open(f"{tmp_out}/probe_toy_manifest.json") as f:
            manifest = json.load(f)
        names = [e["name"] for e in manifest["params"]]
        expected = [n for n, _ in flatten_with_names(params)]
        assert names == expected
        # dict keys sort: b1,b2,b3,w1,w2,w3
        assert names == ["b1", "b2", "b3", "w1", "w2", "w3"]

    def test_offsets_contiguous(self, tmp_out):
        params = M.probe_init(jax.random.PRNGKey(0), f_dim=8, hidden=4)
        save_weights(params, tmp_out, "p2")
        with open(f"{tmp_out}/p2_manifest.json") as f:
            manifest = json.load(f)
        offset = 0
        for e in manifest["params"]:
            assert e["offset"] == offset
            assert e["size"] == int(np.prod(e["shape"])) if e["shape"] else 1
            offset += e["size"]
        assert manifest["total_elems"] == offset
        blob = np.fromfile(f"{tmp_out}/p2_weights.bin", dtype="<f4")
        assert blob.size == offset

    def test_shape_mismatch_rejected(self, tmp_out):
        params = M.probe_init(jax.random.PRNGKey(0), f_dim=8, hidden=4)
        save_weights(params, tmp_out, "p3")
        wrong = M.probe_init(jax.random.PRNGKey(0), f_dim=9, hidden=4)
        with pytest.raises(AssertionError):
            load_weights(tmp_out, "p3", wrong)
