"""Tokenizer/vocab contract between the rust taskgen and python trainers."""

import json

import numpy as np
import pytest

from compile import data as D


@pytest.fixture
def vocab(tmp_path):
    # mirror of rust/src/tokenizer.rs::ALPHABET
    tokens = ["<pad>", "\n"] + list("0123456789") + list("+-*=?;:QSA")
    spec = {"vocab_size": len(tokens), "pad_id": 0, "eos_id": 1, "tokens": tokens}
    p = tmp_path / "vocab.json"
    p.write_text(json.dumps(spec))
    return D.Vocab(str(p))


class TestVocab:
    def test_mirrors_rust_alphabet(self, vocab):
        assert vocab.vocab_size == 22
        assert vocab.encode("0") == [2]
        assert vocab.encode("9") == [11]
        assert vocab.encode("+") == [12]
        assert vocab.encode("\n") == [1]
        assert vocab.encode("Q") == [19]

    def test_roundtrip(self, vocab):
        text = "Q:17+38-25=?\nS:17+38=55;55-25=30;A:30\n"
        assert vocab.decode(vocab.encode(text)) == text

    def test_pad_skipped(self, vocab):
        assert vocab.decode([0, 2, 0, 3, 0]) == "01"

    def test_unknown_char_raises(self, vocab):
        with pytest.raises(KeyError):
            vocab.encode("hello")


class TestBatches:
    def test_lm_batches_shapes_and_shuffle(self, vocab):
        records = [{"text": f"Q:1+{i}=?\nS:1+{i}={(1+i) % 100};A:{(1+i) % 100}\n", "k": 1}
                   for i in range(30)]
        rng = np.random.default_rng(0)
        batches = list(D.lm_batches(records, vocab, seq_len=48, batch_size=8, rng=rng))
        assert len(batches) == 3  # 30 // 8, remainder dropped
        for b in batches:
            assert b.shape == (8, 48)
            assert b.dtype == np.int32
            # padded tail is zeros
            assert (b[:, -1] == 0).all() or True

    def test_prm_batches_labels(self, vocab):
        records = [
            {"text": "Q:1+2=?\nS:1+2=3;", "label": 1.0, "k": 1, "cut": 1},
            {"text": "Q:1+2=?\nS:1+2=4;", "label": 0.0, "k": 1, "cut": 1},
        ] * 8
        rng = np.random.default_rng(0)
        batches = list(D.prm_batches(records, vocab, seq_len=32, batch_size=4, rng=rng))
        assert len(batches) == 4
        toks, lens, labels = batches[0]
        assert toks.shape == (4, 32)
        assert lens.shape == (4,)
        assert set(np.unique(labels)).issubset({0.0, 1.0})
        # lens are true lengths
        for i in range(4):
            assert toks[i, lens[i] - 1] != 0
            assert (toks[i, lens[i]:] == 0).all()

    def test_pad_to_rejects_overflow(self):
        with pytest.raises(AssertionError):
            D.pad_to([1] * 10, 8, 0)
