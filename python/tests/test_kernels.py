"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the CORE correctness signal for the compute layer — the same
kernels lower into the AOT artifacts the rust engine executes. Hypothesis
sweeps shapes; fixed cases pin the bucket shapes actually compiled.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention
from compile.kernels.fused_mlp import fused_mlp
from compile.kernels.layernorm import fused_layernorm

TOL = dict(rtol=2e-4, atol=2e-4)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class TestAttention:
    @pytest.mark.parametrize("b,h,lq,lk,d", [
        (1, 4, 32, 32, 24),    # prefill bucket (LM d_head=24... generic d)
        (4, 4, 32, 32, 24),
        (2, 4, 1, 160, 24),    # decode: single query over full cache
        (1, 2, 16, 64, 8),
        (3, 1, 8, 8, 4),
    ])
    def test_matches_ref_prefill_and_decode(self, b, h, lq, lk, d):
        q = rand(1, (b, h, lq, d))
        k = rand(2, (b, h, lk, d))
        v = rand(3, (b, h, lk, d))
        # decode-style offsets when lq == 1, zero otherwise
        if lq == 1:
            qoff = jnp.arange(b, dtype=jnp.int32) * 7 + 3
        else:
            qoff = jnp.zeros((b,), jnp.int32)
        out = flash_attention(q, k, v, qoff)
        want = ref.ref_attention(q, k, v, qoff)
        np.testing.assert_allclose(out, want, **TOL)

    def test_causality(self):
        """Changing future K/V must not change current outputs."""
        b, h, l, d = 1, 2, 16, 8
        q = rand(1, (b, h, l, d))
        k = rand(2, (b, h, l, d))
        v = rand(3, (b, h, l, d))
        qoff = jnp.zeros((b,), jnp.int32)
        out1 = flash_attention(q, k, v, qoff)
        k2 = k.at[:, :, 10:, :].set(99.0)
        v2 = v.at[:, :, 10:, :].set(-99.0)
        out2 = flash_attention(q, k2, v2, qoff)
        np.testing.assert_allclose(out1[:, :, :10, :], out2[:, :, :10, :], **TOL)
        assert not np.allclose(out1[:, :, 10:, :], out2[:, :, 10:, :])

    def test_decode_offset_masks_cache_tail(self):
        """Garbage beyond the decode position must not leak in."""
        b, h, d, lmax = 2, 2, 8, 64
        q = rand(1, (b, h, 1, d))
        k = rand(2, (b, h, lmax, d))
        v = rand(3, (b, h, lmax, d))
        pos = jnp.array([5, 20], jnp.int32)
        out1 = flash_attention(q, k, v, pos)
        # corrupt cache beyond each position
        k2 = k.at[0, :, 6:, :].set(1e3).at[1, :, 21:, :].set(1e3)
        v2 = v.at[0, :, 6:, :].set(-1e3).at[1, :, 21:, :].set(-1e3)
        out2 = flash_attention(q, k2, v2, pos)
        np.testing.assert_allclose(out1, out2, **TOL)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 3),
        lq_pow=st.integers(0, 3),
        d_pow=st.integers(2, 5),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, b, h, lq_pow, d_pow, seed):
        lq = 2 ** lq_pow * 4
        d = 2 ** d_pow
        q = rand(seed, (b, h, lq, d))
        k = rand(seed + 1, (b, h, lq, d))
        v = rand(seed + 2, (b, h, lq, d))
        qoff = jnp.zeros((b,), jnp.int32)
        out = flash_attention(q, k, v, qoff)
        want = ref.ref_attention(q, k, v, qoff)
        np.testing.assert_allclose(out, want, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# fused MLP (probe)
# ---------------------------------------------------------------------------


class TestFusedMlp:
    def make(self, f=105, hdim=200, b=64, seed=0):
        x = rand(seed, (b, f))
        w1 = rand(seed + 1, (f, hdim), 0.1)
        b1 = rand(seed + 2, (hdim,), 0.1)
        w2 = rand(seed + 3, (hdim, hdim), 0.1)
        b2 = rand(seed + 4, (hdim,), 0.1)
        w3 = rand(seed + 5, (hdim, 1), 0.1)
        b3 = jnp.zeros((1,))
        return x, w1, b1, w2, b2, w3, b3

    @pytest.mark.parametrize("b", [32, 64])
    def test_matches_ref(self, b):
        args = self.make(b=b)
        out = fused_mlp(*args)
        want = ref.ref_mlp(*args)
        np.testing.assert_allclose(out, want, **TOL)
        assert out.shape == (b,)

    @settings(max_examples=10, deadline=None)
    @given(
        f=st.integers(3, 128),
        hdim=st.sampled_from([16, 64, 200]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_dims(self, f, hdim, seed):
        args = self.make(f=f, hdim=hdim, b=32, seed=seed)
        out = fused_mlp(*args)
        want = ref.ref_mlp(*args)
        np.testing.assert_allclose(out, want, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


class TestLayerNorm:
    @pytest.mark.parametrize("rows,d", [(64, 96), (128, 96), (32, 64), (96, 128)])
    def test_matches_ref(self, rows, d):
        x = rand(0, (rows, d), 3.0)
        g = rand(1, (d,), 0.5) + 1.0
        b = rand(2, (d,), 0.5)
        out = fused_layernorm(x, g, b)
        want = ref.ref_layernorm(x, g, b)
        np.testing.assert_allclose(out, want, **TOL)

    def test_normalizes(self):
        x = rand(0, (64, 96), 10.0) + 5.0
        out = fused_layernorm(x, jnp.ones(96), jnp.zeros(96))
        np.testing.assert_allclose(np.mean(out, -1), 0.0, atol=1e-4)
        np.testing.assert_allclose(np.std(out, -1), 1.0, atol=1e-2)

    @settings(max_examples=10, deadline=None)
    @given(
        rows_pow=st.integers(0, 4),
        d=st.sampled_from([8, 32, 96, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, rows_pow, d, seed):
        rows = 2 ** rows_pow * 8
        x = rand(seed, (rows, d), 2.0)
        g = jnp.ones(d)
        b = jnp.zeros(d)
        np.testing.assert_allclose(
            fused_layernorm(x, g, b), ref.ref_layernorm(x, g, b), rtol=5e-4, atol=5e-4
        )
