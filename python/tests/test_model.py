"""L2 correctness: prefill/decode KV-cache equivalence, in-graph
generation semantics, PRM/embedding shapes, probe training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import optim

CFG = M.TransformerConfig(d_model=32, n_heads=2, n_layers=2, d_ff=64, max_seq=48)


@pytest.fixture(scope="module")
def params():
    return M.transformer_init(jax.random.PRNGKey(0), CFG)


def make_tokens(lens, lp=16, seed=1):
    b = len(lens)
    t = jax.random.randint(jax.random.PRNGKey(seed), (b, lp), 2, CFG.vocab_size)
    lens = jnp.asarray(lens, jnp.int32)
    return jnp.where(jnp.arange(lp)[None, :] < lens[:, None], t, 0), lens


class TestPrefillDecode:
    def test_prefill_matches_full_forward(self, params):
        tokens, lens = make_tokens([10, 16])
        full = M.lm_logits(params, tokens, CFG)
        last, _, _ = M.lm_prefill(params, tokens, lens, CFG, use_pallas=False)
        want = full[jnp.arange(2), lens - 1]
        np.testing.assert_allclose(last, want, rtol=1e-4, atol=1e-4)

    def test_decode_steps_match_full_forward(self, params):
        """Two decode steps == full forward over the extended sequence —
        the KV cache invariant everything else rests on."""
        tokens, lens = make_tokens([10, 13])
        _, kc, vc = M.lm_prefill(params, tokens, lens, CFG, use_pallas=False)
        ext = jnp.pad(tokens, ((0, 0), (0, 4)))
        new_toks = [jnp.array([5, 7], jnp.int32), jnp.array([3, 9], jnp.int32)]
        logits = None
        for step, tok in enumerate(new_toks):
            for b in range(2):
                ext = ext.at[b, int(lens[b]) + step].set(int(tok[b]))
            logits, kc, vc = M.lm_decode(params, kc, vc, tok, lens + step, CFG, use_pallas=False)
            want = M.lm_logits(params, ext, CFG)[jnp.arange(2), lens + step]
            np.testing.assert_allclose(logits, want, rtol=2e-4, atol=2e-4)

    def test_pallas_path_matches_ref_path(self, params):
        tokens, lens = make_tokens([9, 16])
        last_r, kc_r, vc_r = M.lm_prefill(params, tokens, lens, CFG, use_pallas=False)
        last_p, kc_p, vc_p = M.lm_prefill(params, tokens, lens, CFG, use_pallas=True)
        np.testing.assert_allclose(last_p, last_r, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(kc_p, kc_r, rtol=3e-4, atol=3e-4)
        tok = jnp.array([4, 6], jnp.int32)
        lr, _, _ = M.lm_decode(params, kc_r, vc_r, tok, lens, CFG, use_pallas=False)
        lp, _, _ = M.lm_decode(params, kc_r, vc_r, tok, lens, CFG, use_pallas=True)
        np.testing.assert_allclose(lp, lr, rtol=3e-4, atol=3e-4)


class TestGenerate:
    def run_gen(self, params, temperature, stop_at_sep=False, seed=0, max_new=24):
        tokens, lens = make_tokens([8, 12])
        key = jax.random.key_data(jax.random.PRNGKey(seed))
        return M.lm_generate(
            params, tokens, lens, key, jnp.float32(temperature),
            max_new=max_new, stop_at_sep=stop_at_sep, cfg=CFG, use_pallas=False,
        )

    def test_greedy_is_deterministic(self, params):
        g1, l1 = self.run_gen(params, 0.0, seed=1)
        g2, l2 = self.run_gen(params, 0.0, seed=2)  # different key, temp=0
        np.testing.assert_array_equal(g1, g2)
        np.testing.assert_array_equal(l1, l2)

    def test_sampling_varies_with_key(self, params):
        g1, _ = self.run_gen(params, 1.0, seed=1)
        g2, _ = self.run_gen(params, 1.0, seed=2)
        assert not np.array_equal(np.asarray(g1), np.asarray(g2))

    def test_greedy_matches_manual_loop(self, params):
        """In-graph generation == manual prefill+decode greedy loop."""
        tokens, lens = make_tokens([8, 12])
        gen, gen_len = self.run_gen(params, 0.0, max_new=8)
        last, kc, vc = M.lm_prefill(params, tokens, lens, CFG, use_pallas=False)
        b = tokens.shape[0]
        done = np.zeros(b, bool)
        pos = np.asarray(lens).copy()
        logits = last
        for step in range(8):
            tok = np.asarray(jnp.argmax(logits, -1), np.int32)
            tok = np.where(done, 0, tok)
            for i in range(b):
                if not done[i]:
                    assert gen[i, step] == tok[i], f"row {i} step {step}"
            done |= tok == M.EOS_ID
            logits, kc, vc = M.lm_decode(
                params, kc, vc, jnp.asarray(tok), jnp.asarray(pos), CFG, use_pallas=False
            )
            pos += 1

    def test_gen_len_counts_emitted_tokens(self, params):
        gen, gen_len = self.run_gen(params, 0.9, seed=3)
        gen = np.asarray(gen)
        gen_len = np.asarray(gen_len)
        for i in range(gen.shape[0]):
            # tokens beyond gen_len are zeros
            assert (gen[i, gen_len[i]:] == 0).all()

    def test_stop_at_sep(self, params):
        """With stop_at_sep, nothing is generated past the first ';'/EOS."""
        gen, gen_len = self.run_gen(params, 1.0, stop_at_sep=True, seed=5)
        gen = np.asarray(gen)
        gen_len = np.asarray(gen_len)
        for i in range(gen.shape[0]):
            row = gen[i, : gen_len[i]]
            stops = np.isin(row, [M.EOS_ID, M.SEP_ID])
            if stops.any():
                # the stop token is the last emitted token
                assert stops.argmax() == gen_len[i] - 1


class TestPrmAndEmbeds:
    def _encode(self, text, lp=48):
        table = {"\n": 1, "+": 12, "-": 13, "*": 14, "=": 15, "?": 16,
                 ";": 17, ":": 18, "Q": 19, "S": 20, "A": 21}
        ids = [table[c] if c in table else 2 + int(c) for c in text]
        toks = np.zeros((1, lp), np.int32)
        toks[0, : len(ids)] = ids
        return jnp.asarray(toks), jnp.asarray([len(ids)], jnp.int32)

    def test_prm_score_range_and_neutral_when_no_results(self, params):
        # prefix with no '=' yet → neutral 0.5
        t, l = self._encode("Q:7+8-2=?\nS:7")
        s = M.prm_score(params, t, l, CFG, use_pallas=False)
        assert s.shape == (1,)
        np.testing.assert_allclose(np.asarray(s), [0.5], atol=1e-6)
        # with a result digit → in (0, 1]
        t, l = self._encode("Q:7+8-2=?\nS:7+8=5;")
        s = M.prm_score(params, t, l, CFG, use_pallas=False)
        assert 0.0 < float(s[0]) <= 1.0

    def test_prm_score_ignores_tokens_beyond_len(self, params):
        """Result digits past `lens` must not affect the score."""
        t, l = self._encode("Q:7+8-2=?\nS:7+8=5;5-2=3;")
        full = M.prm_score(params, t, l, CFG, use_pallas=False)
        # same tokens, len cut before the second step's result
        short_len = jnp.asarray([int(l[0]) - 3], jnp.int32)
        cut = M.prm_score(params, t, short_len, CFG, use_pallas=False)
        # scores differ because the second result digit is excluded
        t2, l2 = self._encode("Q:7+8-2=?\nS:7+8=5;5-2")
        manual = M.prm_score(params, t2, l2, CFG, use_pallas=False)
        np.testing.assert_allclose(np.asarray(cut), np.asarray(manual), rtol=1e-5)
        assert full.shape == cut.shape

    def test_embed_pool_ignores_padding(self, params):
        tokens, lens = make_tokens([10, 16])
        e1 = M.embed_pool(params, tokens, lens, CFG, use_pallas=False)
        # corrupt padding region of row 0
        corrupted = tokens.at[0, 12:].set(9)
        e2 = M.embed_pool(params, corrupted, lens, CFG, use_pallas=False)
        np.testing.assert_allclose(e1[0], e2[0], rtol=1e-4, atol=1e-4)
        assert e1.shape == (2, CFG.d_model)

    def test_embed_small_is_masked_mean(self, params):
        tokens, lens = make_tokens([4, 16])
        e = M.embed_small(params, tokens, lens, CFG)
        manual = np.zeros((2, CFG.d_model), np.float32)
        emb = np.asarray(params["tok_emb"])
        for b in range(2):
            ids = np.asarray(tokens[b, : int(lens[b])])
            manual[b] = emb[ids].mean(0)
        np.testing.assert_allclose(e, manual, rtol=1e-5, atol=1e-5)


class TestProbe:
    def test_train_step_reduces_loss_and_matches_pallas(self):
        pp = M.probe_init(jax.random.PRNGKey(4), f_dim=M.PROBE_FEATURES)
        m, v = optim.adam_init(pp)
        feats = jax.random.normal(jax.random.PRNGKey(5), (64, M.PROBE_FEATURES))
        labels = (feats[:, 0] > 0).astype(jnp.float32)
        step_fn = jax.jit(M.probe_train_step)
        losses = []
        for step in range(1, 50):
            pp, m, v, loss = step_fn(pp, m, v, float(step), feats, labels)
            losses.append(float(loss))
        assert losses[-1] < 0.5 * losses[0], f"{losses[0]} -> {losses[-1]}"
        zp = M.probe_fwd(pp, feats, use_pallas=True)
        zr = M.probe_fwd(pp, feats, use_pallas=False)
        np.testing.assert_allclose(zp, zr, rtol=5e-4, atol=5e-4)

    def test_soft_labels_supported(self):
        """BCE against fractional labels (the paper's soft labels)."""
        pp = M.probe_init(jax.random.PRNGKey(6), f_dim=8)
        m, v = optim.adam_init(pp)
        feats = jnp.eye(8, dtype=jnp.float32).repeat(8, 0)
        labels = jnp.linspace(0.0, 1.0, 8).repeat(8).astype(jnp.float32)
        step_fn = jax.jit(M.probe_train_step)
        for step in range(1, 600):
            pp, m, v, loss = step_fn(pp, m, v, float(step), feats, labels)
        # predictions approach the soft labels
        probs = jax.nn.sigmoid(M.probe_fwd(pp, jnp.eye(8, dtype=jnp.float32), use_pallas=False))
        np.testing.assert_allclose(probs, jnp.linspace(0.0, 1.0, 8), atol=0.15)
