#!/usr/bin/env bash
# Tier-1 verification in one command (mirrors .github/workflows/ci.yml).
#
#   scripts/verify.sh          # build + test + clippy
#   scripts/verify.sh --quick  # build + test only (skip clippy)
#
# Integration tests that need AOT artifacts (`make artifacts`) self-skip
# when artifacts/hlo_index.json is absent, so this runs green on a fresh
# checkout.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

echo "verify: OK"
