#!/usr/bin/env bash
# Tier-1 verification in one command (mirrors .github/workflows/ci.yml).
#
#   scripts/verify.sh          # build + test + fmt + clippy + docs
#   scripts/verify.sh --quick  # build + test only (skip fmt/clippy/docs)
#
# Integration tests that need AOT artifacts (`make artifacts`) self-skip
# when artifacts/hlo_index.json is absent, so this runs green on a fresh
# checkout.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings

    echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
fi

echo "verify: OK"
