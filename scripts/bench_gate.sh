#!/usr/bin/env bash
# Run the router + engine benches, emit BENCH_<sha>.json at the repo
# root, and gate on router-select p50 regression against the committed
# baseline (rust/benches/baseline.json).
#
#   scripts/bench_gate.sh                   # bench + emit + gate
#   scripts/bench_gate.sh --write-baseline  # bench + refresh the baseline
#
# The bench harness prints machine-parseable lines
# (`bench,<name>,<iters>,<mean_ns>,<p50_ns>,<p95_ns>`); engine benches
# self-skip without AOT artifacts, so the router benches always gate.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

SHA="${GITHUB_SHA:-$(git -C "$ROOT" rev-parse HEAD 2>/dev/null || echo local)}"
SHA="${SHA:0:12}"
OUT="$ROOT/BENCH_${SHA}.json"
RAW="$(mktemp)"
trap 'rm -f "$RAW"' EXIT

echo "==> cargo bench (router + engine)"
cargo bench --bench bench_router --bench bench_engine | tee "$RAW"

python3 - "$RAW" "$OUT" "$SHA" <<'PY'
import json, sys

raw, out, sha = sys.argv[1], sys.argv[2], sys.argv[3]
benches = {}
for line in open(raw):
    parts = line.strip().split(",")
    if len(parts) == 6 and parts[0] == "bench":
        _, name, iters, mean, p50, p95 = parts
        try:
            benches[name] = {
                "iters": int(iters),
                "mean_ns": float(mean),
                "p50_ns": float(p50),
                "p95_ns": float(p95),
            }
        except ValueError:
            pass
json.dump({"commit": sha, "benches": benches}, open(out, "w"), indent=2)
print(f"wrote {out} ({len(benches)} benches)")
PY

BASELINE="$ROOT/rust/benches/baseline.json"

if [[ "${1:-}" == "--write-baseline" ]]; then
    python3 - "$OUT" "$BASELINE" <<'PY'
import json, sys

cur = json.load(open(sys.argv[1]))["benches"]
base = json.load(open(sys.argv[2]))
for name, entry in base.get("benches", {}).items():
    if name in cur:
        entry["p50_ns"] = cur[name]["p50_ns"]
json.dump(base, open(sys.argv[2], "w"), indent=2)
print(f"baseline refreshed from {sys.argv[1]}")
PY
    exit 0
fi

echo "==> router-select regression gate"
python3 - "$OUT" "$BASELINE" <<'PY'
import json, sys

cur = json.load(open(sys.argv[1]))["benches"]
try:
    base = json.load(open(sys.argv[2]))
except FileNotFoundError:
    print("WARN: no committed baseline; gate skipped")
    sys.exit(0)

gate = base.get("gate", {})
name = gate.get("bench", "select_offline_full_space")
max_reg = float(gate.get("max_regression", 0.25))
ref = base.get("benches", {}).get(name, {}).get("p50_ns")
if ref is None:
    print(f"WARN: baseline has no p50_ns for '{name}'; gate skipped")
    sys.exit(0)
got = cur.get(name, {}).get("p50_ns")
if got is None:
    print(f"FAIL: bench '{name}' missing from this run")
    sys.exit(1)
limit = ref * (1.0 + max_reg)
ok = got <= limit
print(
    f"{'OK' if ok else 'FAIL'}: {name} p50 {got:.0f}ns "
    f"vs baseline {ref:.0f}ns (limit {limit:.0f}ns, +{max_reg:.0%})"
)
sys.exit(0 if ok else 1)
PY
