#!/usr/bin/env bash
# Run the router + engine + batcher + prm + net benches, emit BENCH_<sha>.json
# at the repo root, and gate on p50 regressions against the committed
# baseline (rust/benches/baseline.json).
#
#   scripts/bench_gate.sh                   # bench + emit + gate
#   scripts/bench_gate.sh --write-baseline  # bench + refresh the baseline
#
# The bench harness prints machine-parseable lines
# (`bench,<name>,<iters>,<mean_ns>,<p50_ns>,<p95_ns>`) plus padding /
# coalescing / pool-balance statistics (`stat,<name>,<value>`, e.g. the
# padded-row fraction under the concurrent mixed workload, or
# pool_balance_ratio = max/min per-engine rows served across the sim
# engine pool); both are captured into BENCH_<sha>.json. Gates are
# listed in the baseline's `gates` array (legacy single `gate` object
# still honored); device-backend engine benches self-skip without AOT
# artifacts, so their gates are `required: false`, while the router
# benches and the sim-backend pool bench always run.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

SHA="${GITHUB_SHA:-$(git -C "$ROOT" rev-parse HEAD 2>/dev/null || echo local)}"
SHA="${SHA:0:12}"
OUT="$ROOT/BENCH_${SHA}.json"
RAW="$(mktemp)"
trap 'rm -f "$RAW"' EXIT

echo "==> cargo bench (router + engine + batcher + prm + net)"
cargo bench --bench bench_router --bench bench_engine --bench bench_batcher --bench bench_prm --bench bench_net | tee "$RAW"

python3 - "$RAW" "$OUT" "$SHA" <<'PY'
import json, sys

raw, out, sha = sys.argv[1], sys.argv[2], sys.argv[3]
benches = {}
stats = {}
for line in open(raw):
    parts = line.strip().split(",")
    if len(parts) == 6 and parts[0] == "bench":
        _, name, iters, mean, p50, p95 = parts
        try:
            benches[name] = {
                "iters": int(iters),
                "mean_ns": float(mean),
                "p50_ns": float(p50),
                "p95_ns": float(p95),
            }
        except ValueError:
            pass
    elif len(parts) == 3 and parts[0] == "stat":
        try:
            stats[parts[1]] = float(parts[2])
        except ValueError:
            pass
json.dump({"commit": sha, "benches": benches, "stats": stats}, open(out, "w"), indent=2)
print(f"wrote {out} ({len(benches)} benches, {len(stats)} stats)")
for name, value in sorted(stats.items()):
    print(f"    stat {name} = {value:.4g}")
PY

BASELINE="$ROOT/rust/benches/baseline.json"

if [[ "${1:-}" == "--write-baseline" ]]; then
    python3 - "$OUT" "$BASELINE" <<'PY'
import json, sys

cur = json.load(open(sys.argv[1]))["benches"]
base = json.load(open(sys.argv[2]))
for name, entry in base.get("benches", {}).items():
    if name in cur:
        entry["p50_ns"] = cur[name]["p50_ns"]
json.dump(base, open(sys.argv[2], "w"), indent=2)
print(f"baseline refreshed from {sys.argv[1]}")
PY
    exit 0
fi

echo "==> p50 regression gates"
python3 - "$OUT" "$BASELINE" <<'PY'
import json, sys

run = json.load(open(sys.argv[1]))
cur = run["benches"]
try:
    base = json.load(open(sys.argv[2]))
except FileNotFoundError:
    print("WARN: no committed baseline; gates skipped")
    sys.exit(0)

gates = list(base.get("gates", []))
if not gates and "gate" in base:
    gates = [base["gate"]]

failed = False
for gate in gates:
    name = gate.get("bench", "select_offline_full_space")
    max_reg = float(gate.get("max_regression", 0.25))
    required = bool(gate.get("required", True))
    ref = base.get("benches", {}).get(name, {}).get("p50_ns")
    if ref is None:
        print(f"WARN: baseline has no p50_ns for '{name}'; gate skipped")
        continue
    got = cur.get(name, {}).get("p50_ns")
    if got is None:
        if required:
            print(f"FAIL: required bench '{name}' missing from this run")
            failed = True
        else:
            print(f"SKIP: bench '{name}' not in this run (no artifacts?)")
        continue
    limit = ref * (1.0 + max_reg)
    ok = got <= limit
    if not ok:
        failed = True
    print(
        f"{'OK' if ok else 'FAIL'}: {name} p50 {got:.0f}ns "
        f"vs baseline {ref:.0f}ns (limit {limit:.0f}ns, +{max_reg:.0%})"
    )

# padded-row fraction report + soft ceiling: with the coalescing
# scheduler the concurrent mixed workload must not regress padding
# waste past the baseline's recorded ceiling.
stats = run.get("stats", {})
for name, ceil in base.get("stat_ceilings", {}).items():
    got = stats.get(name)
    if got is None:
        print(f"SKIP: stat '{name}' not in this run (no artifacts?)")
        continue
    ok = got <= float(ceil)
    if not ok:
        failed = True
    print(f"{'OK' if ok else 'FAIL'}: stat {name} = {got:.4g} (ceiling {ceil})")

# stat floors: behaviors that must keep HAPPENING, not just stay cheap —
# e.g. the stepped concurrent beam workload must actually coalesce
# expansion rounds (stepper_coalesced_generates > 0). Floors skip like
# ceilings when the stat is absent (engine benches without artifacts).
for name, floor in base.get("stat_floors", {}).items():
    got = stats.get(name)
    if got is None:
        print(f"SKIP: stat '{name}' not in this run (no artifacts?)")
        continue
    ok = got >= float(floor)
    if not ok:
        failed = True
    print(f"{'OK' if ok else 'FAIL'}: stat {name} = {got:.4g} (floor {floor})")

sys.exit(1 if failed else 0)
PY
