//! Coalescing scheduler end-to-end: concurrent workers issuing
//! generate + PRM + embed traffic through one engine must get results
//! identical to serial per-message execution, while the scheduler
//! merges their messages into shared rounds.
//!
//! Determinism setup: greedy decoding (temperature 0) makes generation
//! a pure function of the prompt, and every worker submits exactly one
//! max-bucket's worth of rows — so bin-packing slices merged rounds
//! back into calls whose token blocks are bit-identical to the serial
//! calls (same executable, same inputs), and exact equality is sound
//! even across merge patterns. Needs `make artifacts`; skips otherwise.

use ttc::config::Config;
use ttc::engine::{EmbedKind, Engine, GenJob, GenKind};
use ttc::tokenizer::Tokenizer;

fn setup() -> Option<(Engine, usize)> {
    let mut cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    cfg.engine.sim_clock = true; // deterministic timing
    let engine = Engine::start(&cfg).unwrap();
    let info = engine.handle().info().unwrap();
    let max_bucket = info
        .req("shapes")
        .unwrap()
        .req_arr("batch_buckets")
        .unwrap()
        .iter()
        .filter_map(|v| v.as_usize())
        .max()
        .unwrap();
    Some((engine, max_bucket))
}

/// The per-worker request mix — the generate→score cadence of the beam
/// family plus the router's embed traffic, each one full max-bucket.
fn worker_inputs(
    tok: &Tokenizer,
    w: usize,
    batch: usize,
) -> (Vec<GenJob>, Vec<Vec<u32>>, Vec<Vec<u32>>) {
    let query = format!("Q:7+{w}-2+8=?\n");
    let prompt = tok.encode(&format!("{query}S:")).unwrap();
    let jobs: Vec<GenJob> = (0..batch)
        .map(|_| GenJob::new(prompt.clone(), GenKind::Full, 0.0))
        .collect();
    let prefix = tok.encode(&format!("{query}S:7+{w}=5;5-2=3;")).unwrap();
    let prefixes: Vec<Vec<u32>> = (0..batch).map(|_| prefix.clone()).collect();
    let queries: Vec<Vec<u32>> = (0..batch).map(|_| tok.encode(&query).unwrap()).collect();
    (jobs, prefixes, queries)
}

#[test]
fn concurrent_coalesced_results_equal_serial() {
    let Some((engine, batch)) = setup() else {
        return;
    };
    let handle = engine.handle();
    let tok = Tokenizer::new();
    const WORKERS: usize = 4;

    // Serial reference: each worker's messages executed one by one on
    // an otherwise idle engine.
    let mut serial = Vec::new();
    for w in 0..WORKERS {
        let (jobs, prefixes, queries) = worker_inputs(&tok, w, batch);
        let gen: Vec<Vec<u32>> = handle
            .generate(jobs)
            .unwrap()
            .into_iter()
            .map(|r| r.tokens)
            .collect();
        let scores = handle.prm_score(prefixes).unwrap();
        let embs = handle.embed(EmbedKind::Pool, queries).unwrap();
        serial.push((gen, scores, embs));
    }

    // Concurrent: the same traffic from four threads; the scheduler
    // coalesces whatever lands in the same round.
    let concurrent: Vec<(Vec<Vec<u32>>, Vec<f32>, Vec<Vec<f32>>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..WORKERS)
                .map(|w| {
                    let engine_handle = handle.clone();
                    let tok = &tok;
                    scope.spawn(move || {
                        let (jobs, prefixes, queries) = worker_inputs(tok, w, batch);
                        let gen: Vec<Vec<u32>> = engine_handle
                            .generate(jobs)
                            .unwrap()
                            .into_iter()
                            .map(|r| r.tokens)
                            .collect();
                        let scores = engine_handle.prm_score(prefixes).unwrap();
                        let embs = engine_handle.embed(EmbedKind::Pool, queries).unwrap();
                        (gen, scores, embs)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

    for (w, ((sg, ss, se), (cg, cs, ce))) in serial.iter().zip(&concurrent).enumerate() {
        assert_eq!(sg, cg, "worker {w}: generated tokens diverged");
        assert_eq!(ss, cs, "worker {w}: PRM scores diverged");
        assert_eq!(se, ce, "worker {w}: embeddings diverged");
    }

    // The scheduler served rounds, the PRM path scored every real row,
    // and full-bucket batches mean zero PRM padding no matter how the
    // rounds merged. (Whether messages actually coalesced is timing-
    // dependent, so merge counters are reported, not asserted.)
    let info = handle.info().unwrap();
    let metrics = info.req("metrics").unwrap();
    assert!(metrics.req_f64("sched_rounds").unwrap() > 0.0);
    assert!(metrics.req_f64("prm_rows").unwrap() >= (2 * WORKERS * batch) as f64);
    assert_eq!(metrics.req_f64("prm_padded_rows").unwrap(), 0.0);
    assert_eq!(metrics.req_f64("embed_padded_rows").unwrap(), 0.0);
    eprintln!(
        "coalesced_msgs={} coalesced_prm={} coalesced_generates={}",
        metrics.req_f64("coalesced_msgs").unwrap_or(0.0),
        metrics.req_f64("coalesced_prm").unwrap_or(0.0),
        metrics.req_f64("coalesced_generates").unwrap_or(0.0),
    );
}

#[test]
fn coalesced_error_reaches_every_requester() {
    let Some((engine, _)) = setup() else {
        return;
    };
    let handle = engine.handle();
    // An over-long query must fail embed cleanly, and the engine must
    // keep serving afterwards.
    let bad = vec![vec![2u32; 4096]];
    assert!(handle.embed(EmbedKind::Pool, bad).is_err());
    let tok = Tokenizer::new();
    let ok = vec![tok.encode("Q:1+1=?\n").unwrap()];
    assert!(handle.embed(EmbedKind::Pool, ok).is_ok());
}
