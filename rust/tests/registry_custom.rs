//! Registering a brand-new decoding method extends ids, probe features
//! and the strategy space without touching router, probe, cost-model or
//! figure code — the acceptance criterion of the trait/registry design.
//! Runs in its own process so the registry mutation cannot leak into
//! other test binaries.

use ttc::error::Result;
use ttc::probe::FeatureBuilder;
use ttc::strategies::{
    registry, DecodingMethod, Outcome, RunCtx, Strategy, StrategyParams,
};

/// A do-nothing method: enough to exercise the registry plumbing.
struct NullMethod;

impl DecodingMethod for NullMethod {
    fn name(&self) -> &'static str {
        "null_test"
    }
    fn describe(&self) -> &'static str {
        "test stub: returns an empty outcome"
    }
    fn run(&self, _ctx: &RunCtx<'_>, _params: &StrategyParams) -> Result<Outcome> {
        Ok(Outcome::empty(0.0))
    }
}

#[test]
fn custom_method_registers_and_roundtrips() {
    let before = registry::len();
    let m = registry::register(Box::new(NullMethod)).unwrap();
    assert_eq!(registry::len(), before + 1);
    assert_eq!(registry::feature_index("null_test"), Some(before));
    assert!(registry::get("null_test").is_some());

    // ids round-trip with zero changes to Strategy
    let s = Strategy::new(m.name(), m.default_params());
    assert_eq!(s.id(), "null_test@4");
    assert_eq!(
        Strategy::parse("null_test@7"),
        Some(Strategy::new("null_test", StrategyParams::parallel(7)))
    );

    // duplicate registration rejected
    assert!(registry::register(Box::new(NullMethod)).is_err());

    // probe features pick up the new method for builders constructed
    // after registration — no edits to FeatureBuilder
    let fb = FeatureBuilder::new(8, 10);
    assert_eq!(fb.dim(), 8 + 4 + registry::len() + 1);
    let row = fb.build(&[0.1f32; 8], &s, 4);
    assert_eq!(row.len(), fb.dim());
    // the new method's one-hot bit is set at its registry index
    assert_eq!(row[8 + 4 + before], 1.0);

    // cost-model keys are plain id strings — the new method needs no
    // cost-model changes either
    let mut cfg_space = ttc::config::SpaceConfig::default();
    cfg_space.extra.push("null_test@4".into());
    let all = Strategy::enumerate(&cfg_space);
    assert!(all.iter().any(|st| st.id() == "null_test@4"));
}
