//! The cross-request cache tier end-to-end, on the artifact-free sim
//! backend (so this suite runs engine-full on a fresh checkout):
//!
//! * cache-on == cache-off results at temperature 0, for every
//!   registered decoding method, across pool sizes 1, 2 and 4 — the
//!   cache is a pure speed multiplier, never a behavior change;
//! * the same equivalence through the loopback remote path (the cache
//!   sits client-side in front of `RemoteBackend`, so remote replies
//!   count as fills);
//! * a shared-stem workload actually hits: `cache_hits > 0` and
//!   `decode_steps_saved > 0` in the pool report;
//! * repeated PRM / embed batches are served from the score cache and
//!   the counters surface through `info()` and the pool report.

use ttc::config::{BackendKind, Config};
use ttc::engine::EnginePool;
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{registry, Budget, Executor, Outcome, Strategy, StrategyParams};
use ttc::util::rng::Rng;

fn pool_with_cache(engines: usize, cache: bool) -> (EnginePool, Executor) {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true; // deterministic modeled latencies
    cfg.engine.engines = engines;
    cfg.engine.cache.enabled = cache;
    let pool = EnginePool::start(&cfg).unwrap();
    // temperature 0: generation is a pure function of the prompt, so a
    // replayed row must be byte-identical to a fresh decode
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    (pool, executor)
}

/// Everything except latency must match (latencies differ because the
/// cache's whole purpose is to not advance the clock for cached rows).
fn assert_same_result(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.answer, b.answer, "{label}: answer diverged");
    assert_eq!(a.chosen, b.chosen, "{label}: chosen diverged");
    assert_eq!(a.tokens, b.tokens, "{label}: tokens diverged");
    assert_eq!(a.engine_calls, b.engine_calls, "{label}: engine calls diverged");
    assert_eq!(a.rounds, b.rounds, "{label}: rounds diverged");
    assert_eq!(
        a.budget_exhausted, b.budget_exhausted,
        "{label}: budget_exhausted diverged"
    );
    assert_eq!(a.stopped_early, b.stopped_early, "{label}: stopped_early diverged");
    // token-cap preemption is time-independent, so it must agree too
    assert_eq!(a.preempted, b.preempted, "{label}: preempted diverged");
}

/// Per-method cases with no deadlines, so outcomes are time-independent
/// and comparable between a cached and an uncached deployment.
fn cases() -> Vec<(Strategy, Budget, String)> {
    let mut rng = Rng::new(0xCACE, 0);
    let mut cases: Vec<(Strategy, Budget, String)> = Vec::new();
    for method in registry::all() {
        let params = if method.name() == "mv_early" {
            // wave shape where a unanimous vote can only cross the
            // decided margin once a full wave has been heard (n=6, w=2:
            // wave 2's trigger needs both rows) — so the mid-wave stop
            // flag never halts a live row and exact-token comparison
            // stays deterministic under any admission stagger
            StrategyParams::waves(6, 2)
        } else if method.uses_rounds() {
            StrategyParams::beam(
                rng.range(1, 4) as usize,
                rng.range(1, 3) as usize,
                rng.range(6, 16) as usize,
            )
        } else {
            StrategyParams::parallel(rng.range(1, 6) as usize)
        };
        let budget = if rng.below(2) == 0 {
            Budget::unlimited()
        } else {
            Budget::unlimited().with_max_tokens(rng.range(8, 64) as usize)
        };
        let query = format!("Q:7+{}-2+8=?\n", rng.range(0, 9));
        cases.push((Strategy::new(method.name(), params), budget, query));
    }
    cases
}

#[test]
fn cache_on_equals_cache_off_at_temp0_for_pool_sizes_1_2_4() {
    let cases = cases();

    // reference: cache OFF, one engine, blocking, one request at a time
    let (_p0, uncached) = pool_with_cache(1, false);
    let reference: Vec<Outcome> = cases
        .iter()
        .map(|(s, b, q)| uncached.run_budgeted(s, q, b.clone()).unwrap())
        .collect();

    for engines in [1usize, 2, 4] {
        let (pool, executor) = pool_with_cache(engines, true);
        let mut stepper = Stepper::new(executor.clone());
        // all cases in flight concurrently, and the query set repeats
        // prompts across requests — replayed rows must still reproduce
        // the uncached outcomes exactly
        for (i, (s, b, q)) in cases.iter().enumerate() {
            stepper
                .admit(Ticket {
                    query: q.clone(),
                    strategy: s.clone(),
                    budget: b.clone(),
                    tag: i as u64,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        let mut done = stepper.drain_completed();
        assert_eq!(done.len(), cases.len());
        done.sort_by_key(|c| c.tag);
        for (c, r) in done.iter().zip(&reference) {
            assert_same_result(
                &c.outcome,
                r,
                &format!("{} cached on {engines} engine(s)", c.strategy_id),
            );
        }
        // the cache must have been exercised, not just bypassed
        let report = pool.report();
        let cache = report.req("cache").expect("cache section in pool report");
        let lookups =
            cache.req_f64("hits").unwrap_or(0.0) + cache.req_f64("misses").unwrap_or(0.0);
        assert!(lookups > 0.0, "cache saw no lookups on {engines} engine(s)");
    }
}

#[test]
fn shared_stem_workload_reports_hits_and_decode_steps_saved() {
    let (pool, executor) = pool_with_cache(2, true);
    let mut stepper = Stepper::new(executor.clone());
    // 8 concurrent requests sharing one stem: the first decodes, the
    // rest dedup/replay
    for i in 0..8u64 {
        stepper
            .admit(Ticket {
                query: "Q:7+3-2+8=?\n".to_string(),
                strategy: Strategy::beam(4, 2, 12),
                budget: Budget::unlimited(),
                tag: i,
            })
            .unwrap();
    }
    stepper.run_to_completion().unwrap();
    let done = stepper.drain_completed();
    assert_eq!(done.len(), 8);
    // identical requests at temp 0 must all agree
    for c in &done[1..] {
        assert_same_result(&c.outcome, &done[0].outcome, "shared-stem request");
    }

    let report = pool.report();
    let cache = report.req("cache").expect("cache section in pool report");
    assert!(
        cache.req_f64("hits").unwrap() > 0.0,
        "shared-stem workload produced no cache hits: {report:?}"
    );
    assert!(
        cache.req_f64("decode_steps_saved").unwrap() > 0.0,
        "shared-stem workload saved no decode steps: {report:?}"
    );
    assert!(cache.req_f64("hit_fraction").unwrap() > 0.0);
}

#[test]
fn score_caches_serve_repeats_and_surface_in_info() {
    use ttc::engine::EmbedKind;

    let (pool, executor) = pool_with_cache(1, true);
    let handle = executor.engine.clone();
    let prefixes: Vec<Vec<u32>> = (0..5).map(|i| vec![1u32, 2, 3, 4, i as u32]).collect();
    let first = handle.prm_score(prefixes.clone()).unwrap();
    let second = handle.prm_score(prefixes.clone()).unwrap();
    assert_eq!(first, second, "cached PRM scores must be byte-identical");

    let queries: Vec<Vec<u32>> = (0..3).map(|i| vec![7u32, 8, 9, i as u32]).collect();
    let e1 = handle.embed(EmbedKind::Pool, queries.clone()).unwrap();
    let e2 = handle.embed(EmbedKind::Pool, queries.clone()).unwrap();
    assert_eq!(e1, e2, "cached embeddings must be byte-identical");

    // the second passes were served from the score cache
    let report = pool.report();
    let cache = report.req("cache").expect("cache section in pool report");
    assert!(
        cache.req_f64("hits").unwrap() >= (prefixes.len() + queries.len()) as f64,
        "repeat batches should be all hits: {report:?}"
    );
    // the same counters surface on the engine's own info()
    let info = handle.info().unwrap();
    let info_cache = info.req("cache").expect("cache section in engine info");
    assert_eq!(
        info_cache.req_f64("hits").unwrap(),
        cache.req_f64("hits").unwrap()
    );
}

#[test]
fn loopback_remote_with_client_cache_matches_uncached() {
    use ttc::net::{LoopbackEngineServer, NetMetrics, RemoteBackend, RemoteConfig};
    use ttc::util::clock;

    // two identical client-pool-over-loopback deployments; only the
    // client-side cache differs. The cache wraps `RemoteBackend` inside
    // the client engine thread, so remote replies count as fills and no
    // wire change is involved.
    let deploy = |cache: bool| {
        let mut server_cfg = Config::default();
        server_cfg.engine.backend = BackendKind::Sim;
        server_cfg.engine.sim_clock = true;
        server_cfg.engine.engines = 1;
        // loopback-only exception (docs/remote.md): client and servers
        // live in one process, so all of them may share one sim clock
        let clock = clock::sim_clock();
        let (conn_a, server_a) =
            LoopbackEngineServer::spawn_with_clock(&server_cfg, clock.clone()).unwrap();
        let (conn_b, server_b) =
            LoopbackEngineServer::spawn_with_clock(&server_cfg, clock.clone()).unwrap();
        let connectors = [conn_a, conn_b];
        let metrics = NetMetrics::new();
        let remote_cfg = RemoteConfig {
            retries: 1,
            backoff_ms: 1.0,
            ..RemoteConfig::default()
        };
        let mut client_cfg = Config::default();
        client_cfg.engine.engines = 2;
        client_cfg.engine.cache.enabled = cache;
        let pool = EnginePool::start_with_factories(
            &client_cfg,
            clock.clone(),
            "remote backend",
            |i| {
                RemoteBackend::factory(
                    connectors[i % 2].clone(),
                    remote_cfg.clone(),
                    clock.clone(),
                    metrics.clone(),
                )
            },
        )
        .unwrap();
        let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
        (pool, executor, server_a, server_b)
    };

    let run = |executor: &Executor| -> Vec<Outcome> {
        let mut stepper = Stepper::new(executor.clone());
        // repeated queries so the cached deployment actually replays
        for i in 0..6u64 {
            stepper
                .admit(Ticket {
                    query: format!("Q:7+{}-2+8=?\n", i % 2),
                    strategy: Strategy::beam(3, 2, 10),
                    budget: Budget::unlimited(),
                    tag: i,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        let mut done = stepper.drain_completed();
        done.sort_by_key(|c| c.tag);
        done.into_iter().map(|c| c.outcome).collect()
    };

    let (_pool_off, uncached, _sa1, _sb1) = deploy(false);
    let reference = run(&uncached);

    let (pool_on, cached, _sa2, _sb2) = deploy(true);
    let got = run(&cached);

    assert_eq!(reference.len(), got.len());
    for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
        assert_same_result(a, b, &format!("remote request {i}"));
    }
    let report = pool_on.report();
    let cache = report.req("cache").expect("cache section in remote pool report");
    assert!(
        cache.req_f64("hits").unwrap() > 0.0,
        "remote client cache saw no hits: {report:?}"
    );
}
