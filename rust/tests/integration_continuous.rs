//! Continuous batching end-to-end, on the artifact-free sim backend:
//!
//! * temperature-0 equivalence — the continuous engine (persistent slot
//!   table, per-step retirement, mid-decode admission) returns results
//!   byte-identical to the round-based engine, for every registered
//!   decoding method, for pool sizes 1, 2 and 4, blocking and stepped;
//! * the cross-request cache tier keeps fronting the continuous path
//!   (leader/follower dedup and replay do not change results);
//! * the new slot-table metrics flow through the pool report.
//!
//! Straggler-join and deadline-cut slot reuse are covered at the unit
//! level in `engine::thread`; this suite pins the external contract.

use ttc::config::{BackendKind, Config};
use ttc::engine::EnginePool;
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{registry, Budget, Executor, Outcome, Strategy, StrategyParams};
use ttc::util::rng::Rng;

fn pool_with(engines: usize, continuous: bool, cache: bool) -> (EnginePool, Executor) {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true; // deterministic modeled latencies
    cfg.engine.engines = engines;
    cfg.engine.continuous = continuous;
    cfg.engine.cache.enabled = cache;
    let pool = EnginePool::start(&cfg).unwrap();
    // temperature 0: generation is a pure function of the prompt, so
    // results cannot depend on scheduling — round vs continuous, serial
    // vs pool, cached vs uncached
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    (pool, executor)
}

/// One deterministic case per registered method (no deadlines, so
/// outcomes are time-independent and comparable across schedulers).
fn method_cases() -> Vec<(Strategy, Budget, String)> {
    let mut rng = Rng::new(0xC0_17_11, 0);
    let mut cases: Vec<(Strategy, Budget, String)> = Vec::new();
    for method in registry::all() {
        let params = if method.name() == "mv_early" {
            // wave shape where a unanimous vote can only cross the
            // decided margin once a full wave has been heard (n=6, w=2:
            // wave 2's trigger needs both rows) — so the mid-wave stop
            // flag never halts a live row, and the comparison with the
            // round path stays byte-exact under any admission stagger
            StrategyParams::waves(6, 2)
        } else if method.uses_rounds() {
            StrategyParams::beam(
                rng.range(1, 4) as usize,
                rng.range(1, 3) as usize,
                rng.range(6, 16) as usize,
            )
        } else {
            StrategyParams::parallel(rng.range(2, 8) as usize)
        };
        let budget = if rng.below(2) == 0 {
            Budget::unlimited()
        } else {
            Budget::unlimited().with_max_tokens(rng.range(8, 64) as usize)
        };
        let query = format!("Q:9-{}*2+7=?\n", rng.range(0, 9));
        cases.push((Strategy::new(method.name(), params), budget, query));
    }
    cases
}

/// Everything except latency must match (latencies differ when
/// concurrent machines interleave their clock charges).
fn assert_same_result(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.answer, b.answer, "{label}: answer diverged");
    assert_eq!(a.chosen, b.chosen, "{label}: chosen diverged");
    assert_eq!(a.tokens, b.tokens, "{label}: tokens diverged");
    assert_eq!(a.engine_calls, b.engine_calls, "{label}: engine calls diverged");
    assert_eq!(a.rounds, b.rounds, "{label}: rounds diverged");
    assert_eq!(
        a.budget_exhausted, b.budget_exhausted,
        "{label}: budget_exhausted diverged"
    );
    assert_eq!(a.stopped_early, b.stopped_early, "{label}: stopped_early diverged");
    assert_eq!(a.preempted, b.preempted, "{label}: preempted diverged");
}

/// The round-based reference: one engine, blocking, one case at a time.
fn round_reference(cases: &[(Strategy, Budget, String)]) -> Vec<Outcome> {
    let (_p, round) = pool_with(1, false, false);
    cases
        .iter()
        .map(|(s, b, q)| round.run_budgeted(s, q, b.clone()).unwrap())
        .collect()
}

#[test]
fn continuous_matches_round_for_every_method_blocking() {
    let cases = method_cases();
    let reference = round_reference(&cases);
    let (_p, cont) = pool_with(1, true, false);
    for ((s, b, q), r) in cases.iter().zip(&reference) {
        let o = cont.run_budgeted(s, q, b.clone()).unwrap();
        assert_same_result(&o, r, &format!("{} continuous-blocking", s.id()));
    }
}

#[test]
fn continuous_matches_round_for_pool_sizes_1_2_4() {
    let cases = method_cases();
    let reference = round_reference(&cases);
    for engines in [1usize, 2, 4] {
        let (_pn, executor) = pool_with(engines, true, false);
        let mut stepper = Stepper::new(executor.clone());
        // all cases in flight concurrently: their jobs land mid-decode
        // in each other's sessions and must not care
        for (i, (s, b, q)) in cases.iter().enumerate() {
            stepper
                .admit(Ticket {
                    query: q.clone(),
                    strategy: s.clone(),
                    budget: b.clone(),
                    tag: i as u64,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        let mut done = stepper.drain_completed();
        assert_eq!(done.len(), cases.len());
        done.sort_by_key(|c| c.tag);
        for (c, r) in done.iter().zip(&reference) {
            assert_same_result(
                &c.outcome,
                r,
                &format!("{} continuous on {engines} engine(s)", c.strategy_id),
            );
        }
    }
}

#[test]
fn cache_front_keeps_continuous_results_identical() {
    let cases = method_cases();
    let reference = round_reference(&cases);
    let (_p, cont) = pool_with(1, true, true);
    // two passes: the first warms the generation/score stores, the
    // second replays through leader/follower dedup — both must match
    // the uncached round-based reference byte for byte
    for pass in 0..2 {
        for ((s, b, q), r) in cases.iter().zip(&reference) {
            let o = cont.run_budgeted(s, q, b.clone()).unwrap();
            assert_same_result(
                &o,
                r,
                &format!("{} continuous+cache pass {pass}", s.id()),
            );
        }
    }
}

#[test]
fn slot_metrics_flow_into_the_pool_report() {
    let (pool, executor) = pool_with(2, true, false);
    let mut stepper = Stepper::new(executor.clone());
    for i in 0..8u64 {
        stepper
            .admit(Ticket {
                query: format!("Q:7+{i}-2+8=?\n"),
                strategy: Strategy::mv(4),
                budget: Budget::unlimited(),
                tag: i,
            })
            .unwrap();
    }
    stepper.run_to_completion().unwrap();
    assert_eq!(stepper.drain_completed().len(), 8);

    let report = pool.report();
    let per_engine = report.req_arr("per_engine").unwrap();
    assert_eq!(per_engine.len(), 2);
    for e in per_engine {
        // the slot-table counters exist per engine; occupancy is a
        // ratio in (0, 1] wherever that engine decoded anything
        let occ = e.req_f64("slot_occupancy").unwrap();
        assert!((0.0..=1.0).contains(&occ), "slot_occupancy {occ}");
        if e.req_f64("rows_served").unwrap() > 0.0 {
            assert!(occ > 0.0, "engine decoded rows but reports zero occupancy");
            assert!(e.req_f64("retired_rows").unwrap() > 0.0);
        }
        assert!(e.req_f64("decode_steps_saved_live").unwrap() >= 0.0);
        assert!(e.req_f64("mid_decode_admits").unwrap() >= 0.0);
    }
}
