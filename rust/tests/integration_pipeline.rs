//! Integration: the full adaptive pipeline on a miniature budget —
//! strategies → matrix → probe training → calibration → figures.
//!
//! Needs `make artifacts`; skips otherwise.

use ttc::config::Config;
use ttc::data::Splits;
use ttc::engine::{EmbedKind, Engine};
use ttc::figures::{self, EvalTable};
use ttc::matrix;
use ttc::probe::{train_probe, FeatureBuilder};
use ttc::strategies::{Executor, Strategy};

fn mini_config() -> Config {
    let mut cfg = Config::default();
    cfg.space.mv_ns = vec![1, 4];
    cfg.space.bon_ns = vec![4];
    cfg.space.beam = vec![(2, 2, 12)];
    cfg.space.mv_early = vec![];
    // exercise a registry-registered method through the full pipeline
    cfg.space.extra = vec!["mv_early@4".into()];
    cfg.probe.epochs = 6;
    cfg
}

#[test]
fn matrix_probe_figures_end_to_end() {
    let cfg = mini_config();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();
    let strategies = Strategy::enumerate(&cfg.space);
    // mv@1, mv@4, bon_naive@4, bon_weighted@4, beam, mv_early@4
    assert_eq!(strategies.len(), 6);

    let tmp = std::env::temp_dir().join(format!("ttc_it_pipeline_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();

    // --- collect small matrices ---
    let train_q = &splits.train[..10];
    let calib_q = &splits.calib[..8];
    let test_q = &splits.test[..8];
    let train_m = matrix::collect(
        &executor, train_q, "train", &strategies, 2, &tmp.join("train.jsonl"),
    )
    .unwrap();
    let calib_m = matrix::collect(
        &executor, calib_q, "calib", &strategies, 1, &tmp.join("calib.jsonl"),
    )
    .unwrap();
    let test_m = matrix::collect(
        &executor, test_q, "test", &strategies, 1, &tmp.join("test.jsonl"),
    )
    .unwrap();
    assert_eq!(train_m.entries.len(), 10 * 6 * 2);

    // resume: a second collect call does zero new work (same file)
    let again = matrix::collect(
        &executor, train_q, "train", &strategies, 2, &tmp.join("train.jsonl"),
    )
    .unwrap();
    assert_eq!(again.entries.len(), train_m.entries.len());

    // --- probe training + calibration ---
    let info = engine.handle().info().unwrap();
    let features = info
        .req("shapes")
        .unwrap()
        .req_usize("probe_features")
        .unwrap();
    let fb = FeatureBuilder::new(features - FeatureBuilder::aux_dim(), cfg.space.beam_max_rounds);
    let (probe, report) = train_probe(
        &engine.handle(),
        &train_m,
        &calib_m,
        train_q,
        calib_q,
        &fb,
        EmbedKind::Pool,
        &cfg.probe,
        7,
    )
    .unwrap();
    assert!(report.req_f64("best_val_loss").unwrap().is_finite());
    assert!(probe.platt.a.is_finite());

    // --- eval table + a figure emitter ---
    let tokenizer = ttc::tokenizer::Tokenizer::new();
    let embs = ttc::probe::train::embed_queries(
        &engine.handle(),
        &tokenizer,
        EmbedKind::Pool,
        test_q,
    )
    .unwrap();
    let mut probs = Vec::new();
    for q in test_q {
        let qlen = tokenizer.encode(&q.query).unwrap().len();
        let feats: Vec<Vec<f32>> = strategies
            .iter()
            .map(|s| fb.build(&embs[&q.id], s, qlen))
            .collect();
        probs.push(probe.predict(&engine.handle(), feats).unwrap());
    }
    let costs = ttc::costmodel::CostModel::fit(&train_m);
    let table = EvalTable::new(test_q.to_vec(), strategies, &test_m, probs, &costs).unwrap();

    let sweep = cfg.sweep.clone();
    figures::sweeps::fig1(&table, &sweep, 'a', &tmp.join("fig1a.csv")).unwrap();
    figures::sweeps::fig2(&table, &sweep, &tmp.join("fig2.csv")).unwrap();
    figures::methods::fig4(&table, &tmp.join("fig4.csv")).unwrap();
    figures::beam::fig9(&table, &sweep, &tmp.join("fig9.csv")).unwrap();
    for f in ["fig1a.csv", "fig2.csv", "fig4.csv", "fig9.csv"] {
        let text = std::fs::read_to_string(tmp.join(f)).unwrap();
        assert!(text.lines().count() > 1, "{f} is empty");
    }

    // penalties push the adaptive policy toward cheaper selections
    let (_, t_free, _, _) = figures::adaptive_point(
        &table,
        ttc::router::Lambdas::new(0.0, 0.0),
        figures::CostSource::Model,
    );
    let (_, t_pen, _, _) = figures::adaptive_point(
        &table,
        ttc::router::Lambdas::new(1e-2, 0.0),
        figures::CostSource::Model,
    );
    assert!(t_pen <= t_free + 1e-9);

    std::fs::remove_dir_all(&tmp).ok();
}
