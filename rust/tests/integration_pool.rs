//! The sharded engine pool end-to-end, on the artifact-free sim
//! backend (so this suite runs engine-full on a fresh checkout):
//!
//! * stepped == blocking equivalence at temperature 0 holds for pool
//!   sizes 1, 2 and 4, for every registered decoding method;
//! * a pool of N engines returns per-request results identical to one
//!   engine (placement never changes outcomes);
//! * concurrent load actually lands on every engine (per-engine
//!   utilization), and the pool report exposes it;
//! * submitting through a handle whose pool has shut down yields a
//!   deterministic, descriptive error.

use ttc::config::{BackendKind, Config};
use ttc::engine::EnginePool;
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{registry, Budget, Executor, Outcome, Strategy, StrategyParams};
use ttc::util::rng::Rng;

fn pool(engines: usize) -> (EnginePool, Executor) {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true; // deterministic modeled latencies
    cfg.engine.engines = engines;
    let pool = EnginePool::start(&cfg).unwrap();
    // temperature 0: generation is a pure function of the prompt, so
    // results cannot depend on which engine a call lands on
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    (pool, executor)
}

/// Everything except latency must match (latencies differ across pool
/// sizes because concurrent machines interleave their clock charges).
fn assert_same_result(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.answer, b.answer, "{label}: answer diverged");
    assert_eq!(a.chosen, b.chosen, "{label}: chosen diverged");
    assert_eq!(a.tokens, b.tokens, "{label}: tokens diverged");
    assert_eq!(a.engine_calls, b.engine_calls, "{label}: engine calls diverged");
    assert_eq!(a.rounds, b.rounds, "{label}: rounds diverged");
    assert_eq!(
        a.budget_exhausted, b.budget_exhausted,
        "{label}: budget_exhausted diverged"
    );
    assert_eq!(a.stopped_early, b.stopped_early, "{label}: stopped_early diverged");
    // token-cap preemption is time-independent, so it must agree too
    assert_eq!(a.preempted, b.preempted, "{label}: preempted diverged");
}

#[test]
fn stepped_equals_blocking_for_pool_sizes_1_2_4() {
    let mut rng = Rng::new(0xBEEF, 0);
    // per-method cases: (strategy, budget, query) — no deadlines, so
    // outcomes are time-independent and comparable across pool sizes
    let mut cases: Vec<(Strategy, Budget, String)> = Vec::new();
    for method in registry::all() {
        let params = if method.name() == "mv_early" {
            // wave shape where a unanimous vote can only cross the
            // decided margin once a full wave has been heard (n=6, w=2:
            // wave 2's trigger needs both rows) — so the mid-wave stop
            // flag never halts a live row and exact-token comparison
            // stays deterministic under any admission stagger
            StrategyParams::waves(6, 2)
        } else if method.uses_rounds() {
            StrategyParams::beam(
                rng.range(1, 4) as usize,
                rng.range(1, 3) as usize,
                rng.range(6, 16) as usize,
            )
        } else {
            StrategyParams::parallel(rng.range(1, 6) as usize)
        };
        let budget = if rng.below(2) == 0 {
            Budget::unlimited()
        } else {
            Budget::unlimited().with_max_tokens(rng.range(8, 64) as usize)
        };
        let query = format!("Q:7+{}-2+8=?\n", rng.range(0, 9));
        cases.push((Strategy::new(method.name(), params), budget, query));
    }

    // reference: one engine, blocking path, one request at a time
    let (_p1, serial) = pool(1);
    let reference: Vec<Outcome> = cases
        .iter()
        .map(|(s, b, q)| serial.run_budgeted(s, q, b.clone()).unwrap())
        .collect();

    for engines in [1usize, 2, 4] {
        let (_pn, executor) = pool(engines);
        let mut stepper = Stepper::new(executor.clone());
        // all cases in flight concurrently: their rounds coalesce and
        // spread across the pool, results must not care
        for (i, (s, b, q)) in cases.iter().enumerate() {
            stepper
                .admit(Ticket {
                    query: q.clone(),
                    strategy: s.clone(),
                    budget: b.clone(),
                    tag: i as u64,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        let mut done = stepper.drain_completed();
        assert_eq!(done.len(), cases.len());
        done.sort_by_key(|c| c.tag);
        for (c, r) in done.iter().zip(&reference) {
            assert_same_result(
                &c.outcome,
                r,
                &format!("{} on {engines} engine(s)", c.strategy_id),
            );
        }
    }
}

#[test]
fn concurrent_load_lands_on_every_engine() {
    let (pool, executor) = pool(2);
    let mut stepper = Stepper::new(executor.clone());
    for i in 0..8u64 {
        stepper
            .admit(Ticket {
                query: format!("Q:7+{i}-2+8=?\n"),
                strategy: Strategy::beam(4, 2, 12),
                budget: Budget::unlimited(),
                tag: i,
            })
            .unwrap();
    }
    stepper.run_to_completion().unwrap();
    assert_eq!(stepper.drain_completed().len(), 8);

    for i in 0..2 {
        assert!(
            pool.engine_metrics(i).rows_served() > 0,
            "engine {i} served no rows"
        );
    }
    let report = pool.report();
    assert_eq!(report.req_f64("engines").unwrap(), 2.0);
    assert!(report.req_f64("placements").unwrap() > 0.0);
    let ratio = report.req_f64("balance_ratio").unwrap();
    assert!(ratio >= 1.0 && ratio.is_finite(), "balance ratio {ratio}");
    assert_eq!(report.req_arr("per_engine").unwrap().len(), 2);
}

#[test]
fn pool_report_flows_into_the_serve_driver() {
    use ttc::server::driver::{self, Mode};
    use ttc::server::loadgen::{self, Arrivals};

    let (_pool, executor) = pool(2);
    let splits = ttc::data::Splits::synthesize(3);
    let mut rng = Rng::new(3, 1);
    let mix = loadgen::parse_budget_mix("30:d500,30:d5000,40:unlimited").unwrap();
    let schedule =
        loadgen::schedule_mixed(&splits.test, 12, Arrivals::Closed, &mix, &mut rng);
    let report = driver::run(&executor, &Mode::Static(Strategy::mv(4)), schedule, 4).unwrap();
    assert_eq!(report.served.len(), 12);
    let v = report.to_json();
    let pool_json = v.req("pool").expect("pool section in serve report");
    assert_eq!(pool_json.req_f64("engines").unwrap(), 2.0);
    let per_engine = pool_json.req_arr("per_engine").unwrap();
    assert!(per_engine
        .iter()
        .all(|e| e.req_f64("rows_served").unwrap() > 0.0));
}

#[test]
fn single_engine_pool_keeps_the_classic_handle() {
    let (pool, executor) = pool(1);
    // pool of 1 bypasses placement entirely: no pool section anywhere,
    // exactly the historical single-engine serve shape
    assert!(executor.engine.pool_report().is_none());
    assert_eq!(pool.engines(), 1);
    let o = executor.run(&Strategy::mv(2), "Q:7+8-5=?\n").unwrap();
    assert_eq!(o.answer.as_deref(), Some("0"));
}

#[test]
fn submission_to_a_shut_down_pool_is_a_descriptive_error() {
    let (pool, executor) = pool(2);
    let handle = executor.engine.clone();
    drop(pool); // joins every engine thread
    // with failover, single-engine deaths reroute silently; only the
    // every-engine-down case surfaces, and it must say so
    let err = handle
        .prm_score(vec![vec![1u32, 2, 3]])
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("all 2 pool engines are down"),
        "error should say the whole pool is down: {err}"
    );
    assert!(err.contains("prm_score"), "error should name the op: {err}");
}

#[test]
fn killing_one_shard_mid_run_reroutes_and_completes_everything() {
    let (mut pool, executor) = pool(2);
    let mut stepper = Stepper::new(executor.clone());
    for i in 0..6u64 {
        stepper
            .admit(Ticket {
                query: format!("Q:7+{i}-2+8=?\n"),
                strategy: Strategy::beam(3, 2, 10),
                budget: Budget::unlimited(),
                tag: i,
            })
            .unwrap();
    }
    // progress a little, then lose a shard mid-flight
    for _ in 0..2 {
        stepper.advance(None).unwrap();
    }
    pool.kill_engine(0);
    stepper.run_to_completion().unwrap();
    let done = stepper.drain_completed();
    assert_eq!(done.len(), 6, "every request must complete despite the kill");

    let report = pool.report();
    assert!(
        report.req_f64("rerouted_submits").unwrap() >= 1.0,
        "failover must be visible in the pool report: {report:?}"
    );
    assert_eq!(report.req_f64("engines_marked_dead").unwrap(), 1.0);
    assert_eq!(report.req_f64("live_engines").unwrap(), 1.0);
}
