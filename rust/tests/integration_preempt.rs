//! Engine-level mid-call preemption under the simulated clock: a
//! deadline that expires *inside* one batched generate call must halt
//! decoding within one decode step, return partial results tagged
//! `preempted`, and surface through the strategy layer and the serving
//! driver. Needs `make artifacts`; skips otherwise.

use ttc::config::Config;
use ttc::data::Splits;
use ttc::engine::{Engine, GenJob, GenKind};
use ttc::server::driver::{self, Mode};
use ttc::server::loadgen::{self, Arrivals};
use ttc::strategies::{Budget, Executor, Strategy};
use ttc::tokenizer::Tokenizer;
use ttc::util::clock::{CostEvent, LatencyModel};
use ttc::util::rng::Rng;

fn sim_setup() -> Option<(Engine, Executor, String)> {
    let mut cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    cfg.engine.sim_clock = true; // deterministic per-step preemption
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();
    let query = splits.test[0].query.clone();
    Some((engine, executor, query))
}

/// One decode step at the largest batch bucket plus call overhead — the
/// epsilon by which a preempted call may overshoot its deadline.
fn decode_step_epsilon(engine: &Engine) -> f64 {
    let info = engine.handle().info().unwrap();
    let largest = info
        .req("shapes")
        .unwrap()
        .req_arr("batch_buckets")
        .unwrap()
        .iter()
        .filter_map(|v| v.as_usize())
        .max()
        .unwrap_or(32);
    let model = LatencyModel::default();
    model.cost_ms(CostEvent::DecodeStep { batch: largest }) + model.call_overhead_ms
}

#[test]
fn deadline_preempts_mid_batched_call() {
    let Some((engine, _executor, query)) = sim_setup() else {
        return;
    };
    let tok = Tokenizer::new();
    let prompt = tok.encode(&format!("{query}S:")).unwrap();
    let handle = engine.handle();
    // greedy so the unbudgeted and budgeted calls decode identically
    let jobs = || -> Vec<GenJob> {
        (0..4)
            .map(|_| GenJob::new(prompt.clone(), GenKind::Full, 0.0))
            .collect()
    };

    // Reference: one unpreempted batched call.
    let t0 = engine.clock.now_ms();
    let full = handle.generate(jobs()).unwrap();
    let full_ms = engine.clock.now_ms() - t0;
    assert!(full.iter().all(|r| !r.preempted));
    let natural_max = full.iter().map(|r| r.tokens.len()).max().unwrap();
    assert!(natural_max > 2, "need a multi-step call to preempt");
    assert!(full_ms > 0.0);

    // A deadline halfway through that same call.
    let t1 = engine.clock.now_ms();
    let deadline = t1 + 0.5 * full_ms;
    let cut = handle.generate_with_deadline(jobs(), Some(deadline)).unwrap();
    let t2 = engine.clock.now_ms();
    assert!(
        cut.iter().any(|r| r.preempted),
        "a mid-call deadline must preempt"
    );
    // the engine halted within one decode step of the deadline
    let eps = decode_step_epsilon(&engine);
    assert!(
        t2 <= deadline + eps,
        "call ran to {t2} against deadline {deadline} (+eps {eps})"
    );
    // partial results are prefixes of the unpreempted (greedy) outputs
    for (c, f) in cut.iter().zip(&full) {
        assert!(c.tokens.len() <= f.tokens.len());
        assert_eq!(c.tokens[..], f.tokens[..c.tokens.len()]);
    }
    assert!(engine.metrics.preempted_rows.get() > 0);
}

#[test]
fn strategy_deadline_yields_preempted_partial_outcome() {
    let Some((engine, executor, query)) = sim_setup() else {
        return;
    };
    let s = Strategy::mv(4);
    let full = executor.run(&s, &query).unwrap();
    assert!(!full.preempted && !full.budget_exhausted);
    assert!(full.latency_ms > 0.0);

    // Deadline shorter than the single unpreempted batched call.
    let deadline = 0.5 * full.latency_ms;
    let o = executor
        .run_budgeted(&s, &query, Budget::unlimited().with_deadline_ms(deadline))
        .unwrap();
    assert!(o.preempted, "engine-level preemption must be reported");
    assert!(o.budget_exhausted);
    assert!(o.tokens > 0, "partial results, not a zeroed request");
    let eps = decode_step_epsilon(&engine);
    assert!(
        o.latency_ms <= deadline + eps,
        "strategy latency {} exceeds deadline {deadline} + eps {eps}",
        o.latency_ms
    );
}

#[test]
fn driver_reports_preemption_counts_and_deadline_latency() {
    let Some((engine, executor, _query)) = sim_setup() else {
        return;
    };
    let splits = Splits::load(&Config::default().paths().data_dir()).unwrap();

    // Measure one natural run to place the deadline mid-call; schedule
    // the same query so every request's call shape matches.
    let s = Strategy::mv(4);
    let full = executor.run(&s, &splits.test[0].query).unwrap();
    let deadline = 0.5 * full.latency_ms;
    assert!(deadline > 0.0);

    let mut rng = Rng::new(7, 0);
    let schedule = loadgen::schedule_budgeted(
        &splits.test[..1],
        4,
        Arrivals::Closed,
        Budget::unlimited().with_deadline_ms(deadline),
        &mut rng,
    );
    let report = driver::run(&executor, &Mode::Static(s), schedule, 1).unwrap();
    assert_eq!(report.served.len(), 4);

    let eps = decode_step_epsilon(&engine);
    let mut preempted = 0;
    for srv in &report.served {
        // the service latency the system accounts (sim clock) respects
        // the deadline up to one decode step
        assert!(
            srv.service_ms <= deadline + eps,
            "{}: service {}ms vs deadline {deadline}ms",
            srv.query_id,
            srv.service_ms
        );
        if srv.preempted {
            preempted += 1;
            assert!(srv.budget_exhausted);
        }
    }
    assert!(preempted > 0, "a mid-call deadline must preempt some requests");
    let v = report.to_json();
    assert_eq!(v.req_f64("preempted_count").unwrap() as usize, preempted);
    assert!(v.req_f64("preempted_fraction").unwrap() > 0.0);
}
