//! Budget-observance property: *every* registered decoding method must
//! respect a tight per-request [`Budget`] — token accounting never
//! exceeds the cap, a spent deadline forbids any engine work, and a
//! pre-set cancel flag stops the method before generation. Needs
//! `make artifacts`; skips otherwise.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use ttc::config::Config;
use ttc::data::Splits;
use ttc::engine::Engine;
use ttc::strategies::{registry, Budget, Executor, Strategy};

fn setup() -> Option<(Engine, Executor, String)> {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();
    let query = splits.test[0].query.clone();
    Some((engine, executor, query))
}

#[test]
fn token_cap_binds_every_method() {
    let Some((_engine, executor, query)) = setup() else {
        return;
    };
    for m in registry::all() {
        let s = Strategy::new(m.name(), m.default_params());
        for cap in [1usize, 8, 32, 200] {
            let o = executor
                .run_budgeted(&s, &query, Budget::unlimited().with_max_tokens(cap))
                .unwrap();
            assert!(
                o.tokens <= cap,
                "{}: accounted {} tokens over cap {cap}",
                s.id(),
                o.tokens
            );
            // a 1-token cap cannot fit a real solution: it must be
            // reported as a budget hit (or the method gave up earlier)
            if cap == 1 && o.tokens == cap {
                assert!(o.budget_exhausted, "{}: cap hit unreported", s.id());
            }
            // contract: once the cap is spent, no further engine call —
            // for BoN that means the PRM scoring call must be skipped
            if cap == 1 && matches!(m.name(), "bon_naive" | "bon_weighted") {
                assert_eq!(
                    o.engine_calls, 1,
                    "{}: PRM call issued after the token cap was spent",
                    s.id()
                );
            }
        }
    }
}

#[test]
fn spent_deadline_forbids_engine_work() {
    let Some((_engine, executor, query)) = setup() else {
        return;
    };
    for m in registry::all() {
        let s = Strategy::new(m.name(), m.default_params());
        let o = executor
            .run_budgeted(&s, &query, Budget::unlimited().with_deadline_ms(0.0))
            .unwrap();
        assert_eq!(o.tokens, 0, "{}: spent deadline must forbid generation", s.id());
        assert_eq!(o.engine_calls, 0, "{}: engine call after spent deadline", s.id());
        assert!(
            o.budget_exhausted || o.stopped_early,
            "{}: spent deadline unreported",
            s.id()
        );
    }
}

#[test]
fn preset_cancel_stops_every_method() {
    let Some((_engine, executor, query)) = setup() else {
        return;
    };
    let flag = Arc::new(AtomicBool::new(true)); // cancelled before start
    for m in registry::all() {
        let s = Strategy::new(m.name(), m.default_params());
        let o = executor
            .run_budgeted(&s, &query, Budget::unlimited().with_cancel(flag.clone()))
            .unwrap();
        assert_eq!(o.tokens, 0, "{}: cancelled run generated tokens", s.id());
        assert_eq!(o.engine_calls, 0, "{}: engine call after cancel", s.id());
        assert!(o.budget_exhausted || o.stopped_early, "{}", s.id());
    }
}

#[test]
fn unlimited_budget_changes_nothing() {
    let Some((_engine, executor, query)) = setup() else {
        return;
    };
    // run() and run_budgeted(unlimited) are the same code path; flags
    // must stay clean for a generous budget on a parallel method
    let o = executor
        .run_budgeted(
            &Strategy::mv(2),
            &query,
            Budget::unlimited().with_max_tokens(1_000_000),
        )
        .unwrap();
    assert!(o.tokens > 0);
    assert!(!o.budget_exhausted);
    assert!(!o.stopped_early);
}
