//! The agentic chain tier end-to-end, on the artifact-free sim backend:
//!
//! * temp-0 chain results are identical across pool sizes 1, 2 and 4,
//!   and between the blocking reference runner and the stepped driver;
//! * one shared chain budget achieves ≥ the accuracy of the same steps
//!   under a static per-step split at equal total budget — including a
//!   crafted chain where cross-step banking strictly wins;
//! * a `ChainAllocator` grant makes a stronger strategy feasible for a
//!   later step (the router upgrade the re-split exists for);
//! * chain budget exhaustion mid-chain reports partial steps with
//!   `budget_exhausted` instead of hanging, on both execution paths;
//! * a stepped run with chains carries the `chain` section (goodput,
//!   realloc grants) in its serve report.

use ttc::config::{BackendKind, Config};
use ttc::costmodel::CostModel;
use ttc::data::Splits;
use ttc::engine::{EmbedKind, EnginePool};
use ttc::matrix::{Matrix, MatrixEntry};
use ttc::probe::{CalibratedProbe, FeatureBuilder, Platt};
use ttc::router::{Lambdas, Router};
use ttc::server::chain::{run_chain_blocking, sample_chains, ChainOutcome, ChainSpec};
use ttc::server::driver::{self, Mode};
use ttc::server::loadgen::{self, Arrivals};
use ttc::strategies::{Budget, Executor, Strategy};
use ttc::taskgen::ChainProblem;
use ttc::util::rng::Rng;

fn pool(engines: usize) -> (EnginePool, Executor) {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true; // deterministic modeled latencies
    cfg.engine.engines = engines;
    let pool = EnginePool::start(&cfg).unwrap();
    // temperature 0: generation is a pure function of the prompt
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    (pool, executor)
}

fn spec(id: &str, budget: Budget, exprs: &[&str]) -> ChainSpec {
    ChainSpec {
        id: id.to_string(),
        arrival_ms: 0.0,
        budget,
        steps: exprs
            .iter()
            .map(|e| ChainProblem::parse_expr(e).expect("valid step expr"))
            .collect(),
    }
}

/// Everything time-independent must match between two runs of the same
/// chain (latencies and ms-axis grant sums legitimately differ).
fn assert_same_chain(a: &ChainOutcome, b: &ChainOutcome, label: &str) {
    assert_eq!(a.id, b.id, "{label}: id diverged");
    assert_eq!(a.steps_total, b.steps_total, "{label}: steps_total diverged");
    assert_eq!(a.steps.len(), b.steps.len(), "{label}: step count diverged");
    assert_eq!(a.all_correct, b.all_correct, "{label}: all_correct diverged");
    assert_eq!(a.tokens, b.tokens, "{label}: tokens diverged");
    assert_eq!(
        a.budget_exhausted, b.budget_exhausted,
        "{label}: budget_exhausted diverged"
    );
    // token-axis banking is time-independent, so grant accounting on
    // that axis must agree exactly
    assert_eq!(
        a.granted_tokens, b.granted_tokens,
        "{label}: granted_tokens diverged"
    );
    for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(sa.strategy, sb.strategy, "{label} step {i}: strategy diverged");
        assert_eq!(sa.correct, sb.correct, "{label} step {i}: correct diverged");
        assert_eq!(sa.tokens, sb.tokens, "{label} step {i}: tokens diverged");
        assert_eq!(sa.answer, sb.answer, "{label} step {i}: answer diverged");
        assert_eq!(
            sa.budget_exhausted, sb.budget_exhausted,
            "{label} step {i}: budget_exhausted diverged"
        );
        assert_eq!(
            sa.grant.extra_tokens, sb.grant.extra_tokens,
            "{label} step {i}: token grant diverged"
        );
    }
}

#[test]
fn chain_results_identical_across_pool_sizes_and_blocking() {
    let mode = Mode::Static(Strategy::mv(2));
    // no deadlines, so outcomes are wall-clock-independent: unlimited
    // chains plus token-capped chains (the token axis of the allocator
    // is a pure function of spends, identical on every path)
    let specs = vec![
        spec("c0", Budget::unlimited(), &["7+8-5", "max(0,4,9)", "1*2+3"]),
        spec("c1", Budget::unlimited(), &["2+2", "9-4*2"]),
        spec("c2", Budget::unlimited().with_max_tokens(64), &["7+8-5*2", "max(3,8,5)"]),
        spec("c3", Budget::unlimited().with_max_tokens(48), &["1+2+3", "4*5-6"]),
    ];

    // reference: one engine, blocking path, one chain at a time
    let (_p1, serial) = pool(1);
    let reference: Vec<ChainOutcome> = specs
        .iter()
        .map(|s| run_chain_blocking(&serial, &mode, s.clone(), true).unwrap())
        .collect();
    assert!(
        reference.iter().all(|c| c.steps_completed() == c.steps_total),
        "reference chains must run all their steps"
    );

    for engines in [1usize, 2, 4] {
        // concurrency 1: chain steps run one at a time, so the stepper's
        // between-request reallocator has no running peers to grant to
        // and the token-capped chains stay exactly comparable
        let (_pn, executor) = pool(engines);
        let report = driver::run_traffic(&executor, &mode, Vec::new(), specs.clone(), 1).unwrap();
        assert_eq!(report.chains.len(), specs.len());
        for (got, want) in report.chains.iter().zip(&reference) {
            assert_same_chain(got, want, &format!("{} on {engines} engine(s)", want.id));
        }

        // interleaved: the unlimited chains in flight concurrently
        // (unlimited budgets take nothing from the reallocator, so
        // interleaving cannot change outcomes either)
        let report =
            driver::run_traffic(&executor, &mode, Vec::new(), specs[..2].to_vec(), 4).unwrap();
        for (got, want) in report.chains.iter().zip(&reference[..2]) {
            assert_same_chain(
                got,
                want,
                &format!("{} interleaved on {engines} engine(s)", want.id),
            );
        }
    }
}

/// An arith→max chain where the shared pool strictly beats the static
/// split at equal total: the max step's difficulty weight is half an
/// arithmetic step's (comparisons don't carry), so its *nominal* token
/// share undershoots its real cost — only the tokens banked by the
/// cheap first step let it finish. Sized from measured untruncated
/// runs, so the construction is exact rather than tuned.
fn crafted_banking_chain(executor: &Executor, strategy: &Strategy, id: &str) -> (ChainSpec, usize) {
    let easy = ChainProblem::parse_expr("7+8-5*2+6").unwrap(); // arith, weight 4.0
    let hard = ChainProblem::parse_expr("max(3,8,5,2,7)").unwrap(); // max, weight 2.0
    let o_easy = executor
        .run_budgeted(strategy, &easy.query_text(), Budget::unlimited())
        .unwrap();
    assert!(
        o_easy.is_correct(&easy.answer().to_string()),
        "temp-0 untruncated run of the easy step must be correct"
    );
    let e = o_easy.tokens;
    // step 2 actually runs re-seeded with step 1's answer
    let hard_actual = hard.with_first(easy.answer().rem_euclid(10));
    let o_hard = executor
        .run_budgeted(strategy, &hard_actual.query_text(), Budget::unlimited())
        .unwrap();
    assert!(
        o_hard.is_correct(&hard_actual.answer().to_string()),
        "temp-0 untruncated run of the hard step must be correct"
    );
    let h = o_hard.tokens;

    // weights 4:2 ⇒ static shares are floor(2T/3) and floor(T/3)
    let total = e + h + 8;
    let nominal_hard = total / 3;
    assert!(
        nominal_hard + 4 <= h,
        "static split must truncate the max step before its answer \
         (nominal {nominal_hard}, needs {h})"
    );
    assert!(
        e <= 2 * total / 3,
        "easy step must fit its own static share (needs {e}, share {})",
        2 * total / 3
    );
    (
        spec(id, Budget::unlimited().with_max_tokens(total), &["7+8-5*2+6", "max(3,8,5,2,7)"]),
        total,
    )
}

#[test]
fn shared_budget_beats_static_split_at_equal_total() {
    let (_pool, executor) = pool(1);
    let mode = Mode::Static(Strategy::mv(1));
    let (chain, total) = crafted_banking_chain(&executor, &Strategy::mv(1), "crafted");

    let shared = run_chain_blocking(&executor, &mode, chain.clone(), true).unwrap();
    let static_ = run_chain_blocking(&executor, &mode, chain, false).unwrap();

    // shared pool: the easy step banks its surplus, the max step's slice
    // is the whole remainder — a counted grant — and the chain is fully
    // correct under the same total budget
    assert!(shared.all_correct, "shared-pool chain must be fully correct");
    assert!(shared.goodput_ok);
    assert!(!shared.budget_exhausted);
    assert!(shared.tokens <= total, "shared run must respect the chain total");
    assert!(shared.realloc_grants >= 1, "banking must be counted as a grant");
    assert!(shared.granted_tokens > 0);
    assert!(
        shared.steps[1].grant.extra_tokens > 0,
        "the later step must receive the banked tokens"
    );

    // static split: same steps, same total, no banking — the max step is
    // cut off mid-chain-of-thought and the chain goes wrong
    assert!(static_.steps[0].correct, "static easy step fits its share");
    assert!(
        !static_.steps[1].correct,
        "static max step must be truncated into a wrong answer"
    );
    assert!(static_.steps[1].budget_exhausted);
    assert!(static_.budget_exhausted);
    assert!(!static_.all_correct);
    assert!(!static_.goodput_ok);
    assert_eq!(static_.realloc_grants, 0, "a static split never grants");
}

#[test]
fn shared_budget_accuracy_dominates_static_split_on_sampled_chains() {
    let (_pool, executor) = pool(1);
    let mode = Mode::Static(Strategy::mv(2));
    let mut rng = Rng::new(7, 0);
    let specs = sample_chains(
        12,
        &Budget::unlimited().with_max_tokens(120),
        Arrivals::Poisson { rate: 50.0 },
        &mut rng,
    );

    let mut shared_steps = 0usize;
    let mut static_steps = 0usize;
    let mut shared_chains = 0usize;
    let mut static_chains = 0usize;
    for s in specs {
        let shared = run_chain_blocking(&executor, &mode, s.clone(), true).unwrap();
        let static_ = run_chain_blocking(&executor, &mode, s, false).unwrap();
        shared_steps += shared.steps.iter().filter(|r| r.correct).count();
        static_steps += static_.steps.iter().filter(|r| r.correct).count();
        shared_chains += shared.all_correct as usize;
        static_chains += static_.all_correct as usize;
    }
    // the paper's chain-tier claim at temp 0: re-splitting one shared
    // budget never loses to freezing the same split up front
    assert!(
        shared_steps >= static_steps,
        "shared pool lost step accuracy: {shared_steps} < {static_steps}"
    );
    assert!(
        shared_chains >= static_chains,
        "shared pool lost chain accuracy: {shared_chains} < {static_chains}"
    );
}

/// A router whose probe predicts the same accuracy for every strategy
/// (Platt slope 0 ⇒ â ≡ 0.5) and whose negative λ_L *rewards* predicted
/// latency: it always picks the most expensive strategy the deadline
/// admits. Against a synthetic cost table (cheap mv@1 at 10ms, pricey
/// mv@4 at 900ms) that makes strategy choice a pure function of the
/// budget slice — the deterministic probe an upgrade test needs.
fn expensive_feasible_router(executor: &Executor) -> (Router, Lambdas) {
    let cheap = Strategy::mv(1);
    let pricey = Strategy::mv(4);
    let entries = |s: &Strategy, tokens: usize, latency_ms: f64| -> Vec<MatrixEntry> {
        (0..3)
            .map(|i| MatrixEntry {
                query_id: format!("q{i}"),
                split: "train".into(),
                strategy: s.id(),
                repeat: 0,
                k: 2,
                correct: true,
                tokens,
                latency_ms,
                rounds: 1,
            })
            .collect()
    };
    let mut matrix = Matrix::default();
    matrix.entries.extend(entries(&cheap, 10, 10.0));
    matrix.entries.extend(entries(&pricey, 40, 900.0));
    let costs = CostModel::fit_with_buckets(&matrix, &[400.0, 800.0, 1600.0, 3200.0]);

    let info = executor.engine.info().unwrap();
    let d_model = info
        .req("shapes")
        .unwrap()
        .req_usize("probe_features")
        .unwrap()
        - FeatureBuilder::aux_dim();
    let probe = CalibratedProbe {
        platt: Platt { a: 0.0, b: 0.0 },
        embed_kind: EmbedKind::Pool,
        params: Vec::new(),
    };
    let router = Router::new(
        vec![cheap, pricey],
        probe,
        costs,
        FeatureBuilder::new(d_model, 10),
    );
    (router, Lambdas::new(0.0, -1e-4))
}

#[test]
fn chain_grant_upgrades_later_step_strategy() {
    let (_pool, executor) = pool(1);
    let (router, lambdas) = expensive_feasible_router(&executor);
    let mode = Mode::Adaptive(router, lambdas);

    // two equal-weight steps under a 1700ms chain deadline: each nominal
    // slice is 850ms, which excludes the 900ms strategy. The first step
    // finishes in well under its slice on the modeled clock, so the
    // re-split hands the second step the whole remainder (> 900ms) and
    // the router upgrades it.
    let chain = spec(
        "upgrade",
        Budget::unlimited().with_deadline_ms(1700.0),
        &["7+8-5", "1+2-4"],
    );
    let out = run_chain_blocking(&executor, &mode, chain, true).unwrap();

    assert_eq!(out.steps_completed(), 2);
    assert!(out.steps.iter().all(|s| s.routed));
    assert_eq!(
        out.steps[0].strategy,
        Strategy::mv(1).id(),
        "step 1's nominal slice must exclude the expensive strategy"
    );
    assert!(
        out.steps[1].grant.extra_ms > 0.0,
        "the early finish must be re-granted to the later step"
    );
    assert!(out.realloc_grants >= 1);
    assert_eq!(
        out.steps[1].strategy,
        Strategy::mv(4).id(),
        "the widened slice must make the expensive strategy feasible"
    );
    assert!(!out.budget_exhausted);
}

#[test]
fn chain_exhaustion_reports_partial_steps_blocking() {
    let (_pool, executor) = pool(1);
    let mode = Mode::Static(Strategy::mv(2));
    // a chain deadline far below one modeled engine call: step 1 is
    // admitted (0 < deadline), runs out mid-call, and the charge it
    // leaves on the sim clock exhausts the pool before step 2
    let chain = spec(
        "exhausted",
        Budget::unlimited().with_deadline_ms(0.01),
        &["7+8-5", "2+2", "1+2-4"],
    );
    let out = run_chain_blocking(&executor, &mode, chain, true).unwrap();
    assert_eq!(out.steps_total, 3);
    assert_eq!(out.steps_completed(), 1, "only the first step may run");
    assert!(out.budget_exhausted);
    assert!(!out.all_correct);
    assert!(!out.goodput_ok);
    assert!(out.steps[0].budget_exhausted);
}

#[test]
fn chain_exhaustion_cannot_hang_the_stepped_driver() {
    let (_pool, executor) = pool(1);
    let mode = Mode::Static(Strategy::mv(2));
    let chain = spec(
        "exhausted",
        Budget::unlimited().with_deadline_ms(0.01),
        &["7+8-5", "2+2", "1+2-4"],
    );
    // must terminate (wall clock here, so 0 or 1 steps may have run
    // before the pool is spent) and report a partial, exhausted chain
    let report = driver::run_traffic(&executor, &mode, Vec::new(), vec![chain], 2).unwrap();
    assert_eq!(report.chains.len(), 1);
    let out = &report.chains[0];
    assert!(out.steps_completed() < out.steps_total);
    assert!(out.budget_exhausted);
    assert!(!out.goodput_ok);
    let chain_json = report.chain.as_ref().expect("chain section in serve report");
    assert_eq!(chain_json.req_f64("chains_admitted").unwrap(), 1.0);
    assert_eq!(chain_json.req_f64("chains_exhausted").unwrap(), 1.0);
    assert_eq!(chain_json.req_f64("chains_completed").unwrap(), 0.0);
    assert_eq!(chain_json.req_f64("goodput").unwrap(), 0.0);
}

#[test]
fn serve_report_carries_chain_goodput_and_grants() {
    let (_pool, executor) = pool(2);
    let mode = Mode::Static(Strategy::mv(1));
    let (c0, _) = crafted_banking_chain(&executor, &Strategy::mv(1), "g0");
    let (c1, _) = crafted_banking_chain(&executor, &Strategy::mv(1), "g1");

    let splits = Splits::synthesize(5);
    let mut rng = Rng::new(11, 0);
    let singles = loadgen::schedule(&splits.test, 3, Arrivals::Closed, &mut rng);

    let report = driver::run_traffic(&executor, &mode, singles, vec![c0, c1], 3).unwrap();
    assert_eq!(report.served.len(), 3, "singles serve alongside chains");
    assert_eq!(report.chains.len(), 2);
    assert!(report.chains.iter().all(|c| c.goodput_ok));

    let v = report.to_json();
    let chain = v.req("chain").expect("chain section in serve report json");
    assert_eq!(chain.req_f64("chains_admitted").unwrap(), 2.0);
    assert_eq!(chain.req_f64("chains_completed").unwrap(), 2.0);
    assert_eq!(chain.req_f64("chains_exhausted").unwrap(), 0.0);
    assert_eq!(chain.req_f64("goodput").unwrap(), 1.0);
    assert_eq!(chain.req_f64("steps_completed").unwrap(), 4.0);
    // each crafted chain banks its easy step's surplus into the max step
    assert!(
        chain.req_f64("realloc_grants").unwrap() >= 2.0,
        "both chains must report a cross-step grant: {chain:?}"
    );
    assert!(chain.req_f64("realloc_tokens_granted").unwrap() > 0.0);
    report.log_summary("chain-integration");
}
