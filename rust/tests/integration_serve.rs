//! Integration: the serving driver under closed-loop load with a static
//! strategy (adaptive serving is covered by integration_pipeline +
//! examples/serve_adaptive), plus per-request budget enforcement through
//! the driver. Needs `make artifacts`; skips otherwise.

use ttc::config::Config;
use ttc::data::Splits;
use ttc::engine::Engine;
use ttc::server::driver::{self, Mode};
use ttc::server::loadgen::{self, Arrivals};
use ttc::strategies::{Budget, Executor, Strategy};
use ttc::util::rng::Rng;

#[test]
fn static_serving_reports_sane_metrics() {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();

    let mut rng = Rng::new(1, 0);
    let schedule = loadgen::schedule(&splits.test, 6, Arrivals::Closed, &mut rng);
    let report = driver::run(&executor, &Mode::Static(Strategy::mv(2)), schedule, 2).unwrap();

    assert_eq!(report.served.len(), 6);
    let v = report.to_json();
    let acc = v.req_f64("accuracy").unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(v.req_f64("throughput_rps").unwrap() > 0.0);
    assert!(v.req_f64("avg_tokens").unwrap() > 0.0);
    // a static mode routes nothing adaptively, and unlimited budgets
    // never bite
    assert_eq!(v.req_f64("adaptive_fraction").unwrap(), 0.0);
    assert_eq!(v.req_f64("budget_exhausted_fraction").unwrap(), 0.0);
    for s in &report.served {
        assert_eq!(s.strategy, "majority_vote@2");
        assert!(!s.routed);
        // e2e (queue wait + execution, wall clock) must cover service
        assert!(
            s.e2e_ms >= s.service_ms - 1e-6,
            "e2e {} < service {}",
            s.e2e_ms,
            s.service_ms
        );
        assert!(s.tokens > 0);
    }
    // with 2 workers the engine batcher may merge concurrent requests
    // into shared calls — there must be at least ceil(6/2) = 3 calls and
    // real generated tokens
    assert!(engine.metrics.decode_calls.get() >= 3);
    assert!(engine.metrics.tokens_generated.get() > 0);
}

#[test]
fn poisson_schedule_respects_arrivals() {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();
    let mut rng = Rng::new(2, 0);
    // high rate so the test doesn't dawdle
    let schedule = loadgen::schedule(&splits.test, 4, Arrivals::Poisson { rate: 20.0 }, &mut rng);
    let report = driver::run(&executor, &Mode::Static(Strategy::mv(1)), schedule, 2).unwrap();
    assert_eq!(report.served.len(), 4);
    assert!(report.wall_s > 0.0);
    for s in &report.served {
        assert!(s.e2e_ms >= s.service_ms - 1e-6);
    }
}

#[test]
fn per_request_deadline_truncates_beam_rounds() {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();

    // Reference run: unlimited budget, full beam depth.
    let mut rng = Rng::new(3, 0);
    let schedule = loadgen::schedule(&splits.test, 3, Arrivals::Closed, &mut rng);
    let full = driver::run(&executor, &Mode::Static(Strategy::beam(2, 2, 12)), schedule, 1)
        .unwrap();
    let full_calls_ok = full.served.iter().all(|s| !s.budget_exhausted);
    assert!(full_calls_ok, "unlimited budget must never be exhausted");

    // Tight per-request deadline: the beam loop must stop after the
    // deadline passes (reactive enforcement mid-strategy) and report it.
    let mut rng = Rng::new(3, 0);
    let schedule = loadgen::schedule_budgeted(
        &splits.test,
        3,
        Arrivals::Closed,
        Budget::unlimited().with_deadline_ms(5.0),
        &mut rng,
    );
    let tight = driver::run(&executor, &Mode::Static(Strategy::beam(2, 2, 12)), schedule, 1)
        .unwrap();
    let v = tight.to_json();
    let mut any_over_deadline = false;
    for s in &tight.served {
        assert!(s.e2e_ms >= s.service_ms - 1e-6);
        // Only runs that actually reached the deadline must report it —
        // a query finishing its rounds in under 5ms wall time is
        // legitimately unflagged (timing-robust on fast hardware).
        if s.service_ms >= 5.0 {
            any_over_deadline = true;
            assert!(
                s.budget_exhausted || s.stopped_early,
                "deadline reached but unreported for {}",
                s.query_id
            );
        }
    }
    if any_over_deadline {
        assert!(
            v.req_f64("budget_exhausted_fraction").unwrap()
                + v.req_f64("stopped_early_fraction").unwrap()
                > 0.0
        );
    } else {
        eprintln!("note: all beam runs finished under the 5ms deadline; truncation not exercised");
    }
    // truncated runs must do less work than full-depth runs on average
    let mean_tokens = |r: &driver::ServeReport| {
        r.served.iter().map(|s| s.tokens as f64).sum::<f64>() / r.served.len() as f64
    };
    // (10% slack absorbs sampling noise between the two runs)
    assert!(
        mean_tokens(&tight) <= mean_tokens(&full) * 1.1 + 1.0,
        "deadline-truncated beam should not out-generate full beam: {} vs {}",
        mean_tokens(&tight),
        mean_tokens(&full)
    );
}
