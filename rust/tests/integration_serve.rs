//! Integration: the serving driver under closed-loop load with a static
//! strategy (adaptive serving is covered by integration_pipeline +
//! examples/serve_adaptive). Needs `make artifacts`; skips otherwise.

use ttc::config::Config;
use ttc::data::Splits;
use ttc::engine::Engine;
use ttc::server::driver::{self, Mode};
use ttc::server::loadgen::{self, Arrivals};
use ttc::strategies::{Executor, Strategy};
use ttc::util::rng::Rng;

#[test]
fn static_serving_reports_sane_metrics() {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();

    let mut rng = Rng::new(1, 0);
    let schedule = loadgen::schedule(&splits.test, 6, Arrivals::Closed, &mut rng);
    let report = driver::run(&executor, &Mode::Static(Strategy::mv(2)), schedule, 2).unwrap();

    assert_eq!(report.served.len(), 6);
    let v = report.to_json();
    let acc = v.req_f64("accuracy").unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(v.req_f64("throughput_rps").unwrap() > 0.0);
    assert!(v.req_f64("avg_tokens").unwrap() > 0.0);
    for s in &report.served {
        assert_eq!(s.strategy, "majority_vote@2");
        assert!(s.e2e_ms >= s.service_ms * 0.5); // e2e includes service
        assert!(s.tokens > 0);
    }
    // with 2 workers the engine batcher may merge concurrent requests
    // into shared calls — there must be at least ceil(6/2) = 3 calls and
    // real generated tokens
    assert!(engine.metrics.decode_calls.get() >= 3);
    assert!(engine.metrics.tokens_generated.get() > 0);
}

#[test]
fn poisson_schedule_respects_arrivals() {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::start(&cfg).unwrap();
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir()).unwrap();
    let mut rng = Rng::new(2, 0);
    // high rate so the test doesn't dawdle
    let schedule = loadgen::schedule(&splits.test, 4, Arrivals::Poisson { rate: 20.0 }, &mut rng);
    let report = driver::run(&executor, &Mode::Static(Strategy::mv(1)), schedule, 2).unwrap();
    assert_eq!(report.served.len(), 4);
    assert!(report.wall_s > 0.0);
}
