//! The remote engine tier end-to-end over the in-process loopback
//! transport (full wire protocol, no real sockets — CI has no network):
//!
//! * a client pool of [`RemoteBackend`]s fronting a loopback
//!   `engine-serve` fleet produces results identical to the local sim
//!   backend at temperature 0, for client pool sizes 1, 2 and 4 — on
//!   both the per-slot serial JSON path and the shared multiplexed
//!   connection speaking the TTCB binary codec;
//! * a binary-preferring client facing a JSON-only server negotiates
//!   the codec down cleanly and still completes calls;
//! * killing one remote shard mid-run fails over: every admitted
//!   request still completes and the pool report shows
//!   `rerouted_submits > 0` (also exercised on the mux/binary path);
//! * protocol-version and probe-layout mismatches surface as clear,
//!   non-transient `Error::Net`s naming both sides, and malformed TTCB
//!   payloads are non-transient decode errors.
//!
//! Client and server pools share one sim clock — the loopback-only
//! virtual-timeline exception documented in `docs/remote.md`.

use ttc::config::{BackendKind, Config, WireCodec};
use ttc::engine::EnginePool;
use ttc::net::transport::{recv_msg, send_msg};
use ttc::net::{frame, wire};
use ttc::net::{
    JsonCodec, LoopbackConnector, MuxTransport, NetMetrics, RemoteBackend, RemoteConfig,
    Serializer, TTCB,
};
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{registry, Budget, Executor, Outcome, Strategy, StrategyParams};
use ttc::util::clock::{self, SharedClock};
use ttc::util::rng::Rng;

fn sim_cfg(engines: usize) -> Config {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true;
    cfg.engine.engines = engines;
    cfg
}

/// Tight timeouts/backoff so failover paths resolve in test time.
fn quick_remote() -> RemoteConfig {
    RemoteConfig {
        call_timeout_ms: 10_000.0,
        connect_timeout_ms: 1_000.0,
        retries: 1,
        backoff_ms: 1.0,
        ..RemoteConfig::default()
    }
}

/// Same, but preferring the TTCB binary codec on the data plane.
fn quick_binary() -> RemoteConfig {
    RemoteConfig {
        wire_codec: WireCodec::Binary,
        ..quick_remote()
    }
}

/// A client pool of `engines` RemoteBackends, every slot dialing
/// `connector`, sharing the server fleet's sim clock.
fn remote_pool(
    engines: usize,
    clock: SharedClock,
    connector: LoopbackConnector,
) -> (EnginePool, Executor) {
    let metrics = NetMetrics::new();
    let pool = EnginePool::start_with_factories(
        &sim_cfg(engines),
        clock.clone(),
        "remote backend",
        |_| RemoteBackend::factory(connector.clone(), quick_remote(), clock.clone(), metrics.clone()),
    )
    .unwrap();
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    (pool, executor)
}

/// Everything except latency must match (remote calls interleave their
/// clock charges differently, but temp-0 results are time-independent).
fn assert_same_result(a: &Outcome, b: &Outcome, label: &str) {
    assert_eq!(a.answer, b.answer, "{label}: answer diverged");
    assert_eq!(a.chosen, b.chosen, "{label}: chosen diverged");
    assert_eq!(a.tokens, b.tokens, "{label}: tokens diverged");
    assert_eq!(a.engine_calls, b.engine_calls, "{label}: engine calls diverged");
    assert_eq!(a.rounds, b.rounds, "{label}: rounds diverged");
    assert_eq!(
        a.budget_exhausted, b.budget_exhausted,
        "{label}: budget_exhausted diverged"
    );
    assert_eq!(a.stopped_early, b.stopped_early, "{label}: stopped_early diverged");
    assert_eq!(a.preempted, b.preempted, "{label}: preempted diverged");
}

/// Per-method cases, no deadlines: outcomes are time-independent, so
/// they cannot depend on transport, wire codec or client pool size.
fn method_cases() -> Vec<(Strategy, Budget, String)> {
    let mut rng = Rng::new(0xC0DE, 0);
    let mut cases: Vec<(Strategy, Budget, String)> = Vec::new();
    for method in registry::all() {
        let params = if method.uses_rounds() {
            StrategyParams::beam(
                rng.range(1, 3) as usize,
                rng.range(1, 3) as usize,
                rng.range(6, 12) as usize,
            )
        } else {
            StrategyParams::parallel(rng.range(1, 4) as usize)
        };
        let budget = if rng.below(2) == 0 {
            Budget::unlimited()
        } else {
            Budget::unlimited().with_max_tokens(rng.range(8, 48) as usize)
        };
        let query = format!("Q:7+{}-2+8=?\n", rng.range(0, 9));
        cases.push((Strategy::new(method.name(), params), budget, query));
    }
    cases
}

/// Reference outcomes: one local sim engine, blocking, one request at
/// a time.
fn reference_outcomes(cases: &[(Strategy, Budget, String)]) -> Vec<Outcome> {
    let ref_pool = EnginePool::start(&sim_cfg(1)).unwrap();
    let serial = Executor::new(ref_pool.handle(), ref_pool.clock.clone(), 0.0);
    cases
        .iter()
        .map(|(s, b, q)| serial.run_budgeted(s, q, b.clone()).unwrap())
        .collect()
}

/// Drive every case through `executor` concurrently and check each
/// outcome against the local-sim reference.
fn run_cases_and_compare(
    executor: &Executor,
    cases: &[(Strategy, Budget, String)],
    reference: &[Outcome],
    label: &str,
) {
    let mut stepper = Stepper::new(executor.clone());
    for (i, (s, b, q)) in cases.iter().enumerate() {
        stepper
            .admit(Ticket {
                query: q.clone(),
                strategy: s.clone(),
                budget: b.clone(),
                tag: i as u64,
            })
            .unwrap();
    }
    stepper.run_to_completion().unwrap();
    let mut done = stepper.drain_completed();
    assert_eq!(done.len(), cases.len());
    done.sort_by_key(|c| c.tag);
    for (c, r) in done.iter().zip(reference) {
        assert_same_result(&c.outcome, r, &format!("{} via {label}", c.strategy_id));
    }
}

#[test]
fn remote_loopback_matches_local_sim_for_pool_sizes_1_2_4() {
    let cases = method_cases();
    let reference = reference_outcomes(&cases);

    for engines in [1usize, 2, 4] {
        let clock = clock::sim_clock();
        let (connector, _server) =
            ttc::net::LoopbackEngineServer::spawn_with_clock(&sim_cfg(2), clock.clone()).unwrap();
        let (_pool, executor) = remote_pool(engines, clock, connector);
        run_cases_and_compare(
            &executor,
            &cases,
            &reference,
            &format!("{engines} serial-json remote engine(s)"),
        );
    }
}

#[test]
fn binary_mux_loopback_matches_local_sim_for_pool_sizes_1_2_4() {
    let cases = method_cases();
    let reference = reference_outcomes(&cases);

    for engines in [1usize, 2, 4] {
        let clock = clock::sim_clock();
        let mut server_cfg = sim_cfg(2);
        server_cfg.engine.wire_codec = WireCodec::Binary;
        let (connector, _server) =
            ttc::net::LoopbackEngineServer::spawn_with_clock(&server_cfg, clock.clone()).unwrap();
        // ALL client slots share this one multiplexed connection.
        let transport =
            MuxTransport::new(Box::new(connector), quick_binary(), NetMetrics::new());
        let pool = EnginePool::start_with_factories(
            &sim_cfg(engines),
            clock.clone(),
            "remote backend",
            |_| RemoteBackend::mux_factory(transport.clone(), clock.clone()),
        )
        .unwrap();
        assert_eq!(
            transport.wire_status(),
            ("ttcb", true),
            "both sides speak binary, so TTCB must be negotiated"
        );
        let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
        run_cases_and_compare(
            &executor,
            &cases,
            &reference,
            &format!("{engines} mux-ttcb slot(s) on one connection"),
        );
        assert_eq!(
            transport.metrics().reconnects.get(),
            1,
            "{engines} slots must share one dial"
        );
        assert!(
            transport.metrics().bytes_saved_vs_json.get() > 0,
            "the binary codec must beat JSON on the data plane"
        );
    }
}

#[test]
fn binary_client_negotiates_down_to_json_with_a_json_only_server() {
    let clock = clock::sim_clock();
    // server keeps the default engine.wire_codec = json
    let (connector, _server) =
        ttc::net::LoopbackEngineServer::spawn_with_clock(&sim_cfg(1), clock.clone()).unwrap();
    let transport = MuxTransport::new(Box::new(connector), quick_binary(), NetMetrics::new());
    let pool = EnginePool::start_with_factories(&sim_cfg(1), clock.clone(), "remote backend", |_| {
        RemoteBackend::mux_factory(transport.clone(), clock.clone())
    })
    .unwrap();
    assert_eq!(
        transport.wire_status(),
        ("json", true),
        "codec must fall back to JSON without giving up multiplexing"
    );
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    let out = executor
        .run_budgeted(&Strategy::beam(2, 2, 8), "Q:7+1-2+8=?\n", Budget::unlimited())
        .unwrap();
    assert!(out.engine_calls > 0, "calls must succeed on the downgraded link");
    assert_eq!(
        transport.metrics().bytes_saved_vs_json.get(),
        0,
        "a JSON link cannot claim binary byte savings"
    );
}

#[test]
fn killing_a_remote_shard_mid_run_fails_over_and_completes() {
    let clock = clock::sim_clock();
    let (conn_a, _server_a) =
        ttc::net::LoopbackEngineServer::spawn_with_clock(&sim_cfg(1), clock.clone()).unwrap();
    let (conn_b, mut server_b) =
        ttc::net::LoopbackEngineServer::spawn_with_clock(&sim_cfg(1), clock.clone()).unwrap();
    let connectors = [conn_a, conn_b];
    let metrics = NetMetrics::new();
    let pool = EnginePool::start_with_factories(&sim_cfg(2), clock.clone(), "remote backend", |i| {
        RemoteBackend::factory(
            connectors[i % 2].clone(),
            quick_remote(),
            clock.clone(),
            metrics.clone(),
        )
    })
    .unwrap();
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);

    let mut stepper = Stepper::new(executor.clone());
    for i in 0..6u64 {
        stepper
            .admit(Ticket {
                query: format!("Q:7+{i}-2+8=?\n"),
                strategy: Strategy::beam(3, 2, 10),
                budget: Budget::unlimited(),
                tag: i,
            })
            .unwrap();
    }
    // progress a little, then lose the shard client slot 1 dials
    for _ in 0..2 {
        stepper.advance(None).unwrap();
    }
    server_b.kill();
    stepper.run_to_completion().unwrap();
    let done = stepper.drain_completed();
    assert_eq!(done.len(), 6, "every request must survive the shard kill");

    let report = pool.report();
    assert!(
        report.req_f64("rerouted_submits").unwrap() >= 1.0,
        "failover must be visible in the pool report: {report:?}"
    );
    assert_eq!(report.req_f64("live_engines").unwrap(), 1.0);
    assert_eq!(report.req_f64("engines_marked_dead").unwrap(), 1.0);
    assert!(
        metrics.retries.get() >= 1,
        "the client should have retried the dying shard before failing over"
    );
}

#[test]
fn killing_a_mux_shard_mid_run_fails_over_and_completes() {
    let clock = clock::sim_clock();
    let mut shard_cfg = sim_cfg(1);
    shard_cfg.engine.wire_codec = WireCodec::Binary;
    let (conn_a, _server_a) =
        ttc::net::LoopbackEngineServer::spawn_with_clock(&shard_cfg, clock.clone()).unwrap();
    let (conn_b, mut server_b) =
        ttc::net::LoopbackEngineServer::spawn_with_clock(&shard_cfg, clock.clone()).unwrap();
    // one multiplexed connection per shard, shared by the slots aimed
    // at it (the per-host sharing EnginePool does for real addresses)
    let transports = [
        MuxTransport::new(Box::new(conn_a), quick_binary(), NetMetrics::new()),
        MuxTransport::new(Box::new(conn_b), quick_binary(), NetMetrics::new()),
    ];
    let pool = EnginePool::start_with_factories(&sim_cfg(2), clock.clone(), "remote backend", |i| {
        RemoteBackend::mux_factory(transports[i % 2].clone(), clock.clone())
    })
    .unwrap();
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);

    let mut stepper = Stepper::new(executor.clone());
    for i in 0..6u64 {
        stepper
            .admit(Ticket {
                query: format!("Q:7+{i}-2+8=?\n"),
                strategy: Strategy::beam(3, 2, 10),
                budget: Budget::unlimited(),
                tag: i,
            })
            .unwrap();
    }
    // progress a little, then lose the shard behind transport 1
    for _ in 0..2 {
        stepper.advance(None).unwrap();
    }
    server_b.kill();
    stepper.run_to_completion().unwrap();
    let done = stepper.drain_completed();
    assert_eq!(done.len(), 6, "every request must survive the mux shard kill");

    let report = pool.report();
    assert!(
        report.req_f64("rerouted_submits").unwrap() >= 1.0,
        "failover must be visible in the pool report: {report:?}"
    );
    assert_eq!(report.req_f64("live_engines").unwrap(), 1.0);
    assert_eq!(report.req_f64("engines_marked_dead").unwrap(), 1.0);
}

#[test]
fn malformed_ttcb_payloads_are_non_transient_net_errors() {
    use ttc::net::transport::Connector;

    // codec-level: a truncated TTCB document must fail cleanly
    let bytes = TTCB
        .encode(&wire::hello(frame::PROTOCOL_VERSION, wire::ProbeLayout::current()))
        .unwrap();
    let err = TTCB.decode(&bytes[..bytes.len() - 1]).unwrap_err();
    assert_eq!(err.kind_str(), "net");
    assert!(!err.is_transient_net(), "truncated TTCB must not be retried: {err}");

    // wire-level: after negotiating binary, a garbage TTCB frame draws
    // a fatal error envelope (the server closes the connection after).
    let mut cfg = sim_cfg(1);
    cfg.engine.wire_codec = WireCodec::Binary;
    let (connector, _server) = ttc::net::LoopbackEngineServer::spawn(&cfg).unwrap();
    let mut conn = connector.connect().unwrap();
    let json = JsonCodec;
    let hello = wire::WireCaps {
        codecs: vec![frame::CODEC_JSON, frame::CODEC_TTCB],
        mux: false,
    }
    .stamp(wire::hello(frame::PROTOCOL_VERSION, wire::ProbeLayout::current()));
    send_msg(conn.as_mut(), &json, &hello, None).unwrap();
    let ack = recv_msg(conn.as_mut(), &json, None).unwrap();
    wire::check_ack(&ack).unwrap();
    assert_eq!(
        wire::negotiate_codec(
            &[frame::CODEC_JSON, frame::CODEC_TTCB],
            &wire::WireCaps::of(&ack).codecs,
        ),
        frame::CODEC_TTCB,
        "a binary server must advertise TTCB"
    );

    // tag 0x04 = string, varint length 100, but no bytes behind it
    frame::write_frame(conn.as_mut(), frame::CODEC_TTCB, &[0x04, 100]).unwrap();
    let err = wire::unwrap_response(recv_msg(conn.as_mut(), &TTCB, None).unwrap()).unwrap_err();
    assert_eq!(err.kind_str(), "net");
    assert!(!err.is_transient_net(), "a decode failure must not be retried: {err}");
}

#[test]
fn protocol_version_mismatch_is_a_clear_net_error() {
    use ttc::net::transport::Connector;
    let (connector, _server) = ttc::net::LoopbackEngineServer::spawn(&sim_cfg(1)).unwrap();
    let codec = JsonCodec;

    // Handshake-level skew: the hello's explicit protocol field.
    let mut conn = connector.connect().unwrap();
    let hello = wire::hello(frame::PROTOCOL_VERSION + 1, wire::ProbeLayout::current());
    send_msg(conn.as_mut(), &codec, &hello, None).unwrap();
    let err = wire::check_ack(&recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap_err();
    assert_eq!(err.kind_str(), "net");
    assert!(!err.is_transient_net(), "a version mismatch must not be retried: {err}");
    let msg = err.to_string();
    assert!(msg.contains("v2") && msg.contains("v1"), "must name both versions: {msg}");

    // Frame-level skew: a header stamped with a foreign version is
    // rejected before the payload is decoded.
    let mut conn = connector.connect().unwrap();
    let good_hello = wire::hello(frame::PROTOCOL_VERSION, wire::ProbeLayout::current());
    let payload = codec.encode(&good_hello).unwrap();
    frame::write_frame_versioned(&mut conn.as_mut(), 9, frame::CODEC_JSON, &payload).unwrap();
    let err = wire::check_ack(&recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap_err();
    assert_eq!(err.kind_str(), "net");
    let msg = err.to_string();
    assert!(msg.contains("v9") && msg.contains("v1"), "must name both versions: {msg}");
}

#[test]
fn probe_layout_mismatch_is_a_clear_net_error() {
    use ttc::net::transport::Connector;
    let (connector, _server) = ttc::net::LoopbackEngineServer::spawn(&sim_cfg(1)).unwrap();
    let codec = JsonCodec;
    let mut conn = connector.connect().unwrap();
    let mut wrong = wire::ProbeLayout::current();
    wrong.layout_version += 1;
    let hello = wire::hello(frame::PROTOCOL_VERSION, wrong);
    send_msg(conn.as_mut(), &codec, &hello, None).unwrap();
    let err = wire::check_ack(&recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap_err();
    assert_eq!(err.kind_str(), "net");
    assert!(!err.is_transient_net());
    assert!(
        err.to_string().contains("probe layout mismatch"),
        "must say what is skewed: {err}"
    );
}
