//! Integration: PJRT runtime + engine over the real AOT artifacts.
//!
//! These tests need `make artifacts`; they skip (pass with a notice)
//! when artifacts are absent so `cargo test` is green on fresh clones.

use ttc::config::Config;
use ttc::engine::{EmbedKind, Engine, GenJob, GenKind};
use ttc::tokenizer::Tokenizer;

fn artifacts_ready(cfg: &Config) -> bool {
    cfg.paths.artifacts.join("hlo_index.json").exists()
}

macro_rules! require_artifacts {
    ($cfg:ident) => {
        let $cfg = Config::default();
        if !artifacts_ready(&$cfg) {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn engine_generates_well_formed_solutions() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    let tok = Tokenizer::new();
    let prompt = tok.encode("Q:7+8-2=?\nS:").unwrap();
    let jobs: Vec<GenJob> = (0..3)
        .map(|_| GenJob::new(prompt.clone(), GenKind::Full, 0.8))
        .collect();
    let results = engine.handle().generate(jobs).unwrap();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.len() <= 96);
        let text = tok.decode(&r.tokens).unwrap();
        assert!(r.call_ms > 0.0);
        assert_eq!(r.batch_size, 3);
        if let Some(last) = r.tokens.last() {
            if *last == ttc::tokenizer::EOS_ID {
                assert!(text.ends_with('\n'));
            }
        }
    }
}

#[test]
fn greedy_generation_is_deterministic_across_calls() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    let tok = Tokenizer::new();
    let prompt = tok.encode("Q:2+3+4=?\nS:").unwrap();
    // greedy — RNG key must not matter
    let job = || vec![GenJob::new(prompt.clone(), GenKind::Full, 0.0)];
    let a = engine.handle().generate(job()).unwrap();
    let b = engine.handle().generate(job()).unwrap();
    assert_eq!(a[0].tokens, b[0].tokens);
}

#[test]
fn chunk_generation_stops_at_step_separator() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    let tok = Tokenizer::new();
    let prompt = tok.encode("Q:7+8-2+8=?\nS:7+8=5;").unwrap();
    let jobs: Vec<GenJob> = (0..4)
        .map(|_| GenJob::new(prompt.clone(), GenKind::Chunk, 0.8))
        .collect();
    let results = engine.handle().generate(jobs).unwrap();
    for r in &results {
        assert!(r.tokens.len() <= 16, "chunk produced {} tokens", r.tokens.len());
        let text = tok.decode(&r.tokens).unwrap();
        // if a separator appears, it terminates the chunk
        if let Some(pos) = text.find([';', '\n']) {
            assert_eq!(pos, text.len() - 1, "separator mid-chunk in {text:?}");
        }
    }
}

#[test]
fn prm_scores_prefer_correct_prefixes() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    let tok = Tokenizer::new();
    // correct vs corrupted arithmetic; the trained PRM should score the
    // correct prefixes higher on average
    let cases = [
        ("Q:7+8-2=?\nS:7+8=5;", "Q:7+8-2=?\nS:7+8=6;"),
        ("Q:6+7+3=?\nS:6+7=3;", "Q:6+7+3=?\nS:6+7=4;"),
        ("Q:9-4+2=?\nS:9-4=5;", "Q:9-4+2=?\nS:9-4=7;"),
        ("Q:3*4+5=?\nS:3*4=2;", "Q:3*4+5=?\nS:3*4=6;"),
    ];
    let mut prefixes = Vec::new();
    for (good, bad) in &cases {
        prefixes.push(tok.encode(good).unwrap());
        prefixes.push(tok.encode(bad).unwrap());
    }
    let scores = engine.handle().prm_score(prefixes).unwrap();
    let mut wins = 0;
    for i in 0..cases.len() {
        if scores[2 * i] > scores[2 * i + 1] {
            wins += 1;
        }
    }
    assert!(
        wins >= 3,
        "PRM preferred correct prefix only {wins}/4 times: {scores:?}"
    );
}

#[test]
fn embeddings_have_model_dim_and_distinguish_queries() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    let tok = Tokenizer::new();
    let q1 = tok.encode("Q:2+3=?\n").unwrap();
    let q2 = tok.encode("Q:9*9-8+5-2+7=?\n").unwrap();
    for kind in [EmbedKind::Pool, EmbedKind::Small] {
        let embs = engine
            .handle()
            .embed(kind, vec![q1.clone(), q2.clone()])
            .unwrap();
        assert_eq!(embs.len(), 2);
        assert!(!embs[0].is_empty());
        assert_eq!(embs[0].len(), embs[1].len());
        let diff: f32 = embs[0]
            .iter()
            .zip(&embs[1])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-3, "{kind:?} embeddings identical");
    }
}

#[test]
fn probe_fwd_shapes_and_bad_dims_rejected() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    let info = engine.handle().info().unwrap();
    let f = info
        .req("shapes")
        .unwrap()
        .req_usize("probe_features")
        .unwrap();
    let feats: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32 * 0.01; f]).collect();
    let logits = engine.handle().probe_fwd(feats.clone()).unwrap();
    assert_eq!(logits.len(), 5);
    // wrong feature dim is an engine error, not a crash
    let bad = vec![vec![0.0f32; f - 1]];
    assert!(engine.handle().probe_fwd(bad).is_err());
}

#[test]
fn oversized_prompt_is_engine_error() {
    require_artifacts!(cfg);
    let engine = Engine::start(&cfg).unwrap();
    // a 200-token prompt exceeds every length bucket
    let jobs = vec![GenJob::new(vec![2; 200], GenKind::Chunk, 0.8)];
    assert!(engine.handle().generate(jobs).is_err());
}
