//! The continuation executor end-to-end: driving any registered method
//! through `StrategyState::step()` to completion must yield the same
//! `Outcome` as the legacy blocking `run()` path (temperature 0, sim
//! clock ⇒ deterministic), and multiplexing concurrent beam requests
//! through one stepper must coalesce their expansion rounds on the
//! engine and reallocate leftover budget when a request finishes early
//! under a shared deadline pool. Needs `make artifacts`; skips
//! otherwise.

use ttc::config::Config;
use ttc::engine::Engine;
use ttc::router::EvenShareReallocator;
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{registry, Budget, Executor, Strategy, StrategyParams};
use ttc::util::rng::Rng;

fn setup() -> Option<(Engine, Executor)> {
    let mut cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    cfg.engine.sim_clock = true; // deterministic timing
    let engine = Engine::start(&cfg).unwrap();
    // temperature 0: generation is a pure function of the prompt, so
    // the blocking and stepped paths decode identically
    let executor = Executor::new(engine.handle(), engine.clock.clone(), 0.0);
    Some((engine, executor))
}

fn assert_outcomes_equal(
    blocking: &ttc::strategies::Outcome,
    stepped: &ttc::strategies::Outcome,
    label: &str,
) {
    assert_eq!(blocking.answer, stepped.answer, "{label}: answer diverged");
    assert_eq!(blocking.chosen, stepped.chosen, "{label}: chosen diverged");
    assert_eq!(blocking.tokens, stepped.tokens, "{label}: tokens diverged");
    assert_eq!(
        blocking.engine_calls, stepped.engine_calls,
        "{label}: engine calls diverged"
    );
    assert_eq!(blocking.rounds, stepped.rounds, "{label}: rounds diverged");
    assert_eq!(
        blocking.budget_exhausted, stepped.budget_exhausted,
        "{label}: budget_exhausted diverged"
    );
    assert_eq!(
        blocking.preempted, stepped.preempted,
        "{label}: preempted diverged"
    );
    assert_eq!(
        blocking.stopped_early, stepped.stopped_early,
        "{label}: stopped_early diverged"
    );
}

/// Property (per method, random params × budgets): stepping a single
/// machine through the stepper equals the blocking `run()` path.
#[test]
fn stepped_equals_blocking_for_every_method() {
    let Some((_engine, executor)) = setup() else {
        return;
    };
    let mut rng = Rng::new(0xC0FFEE, 7);
    for method in registry::all() {
        for case in 0..3 {
            let params = if method.name() == "mv_early" {
                // wave shape where a unanimous vote can only cross the
                // decided margin once a full wave has been heard (n=6,
                // w=2: wave 2's trigger needs both rows) — so the
                // mid-wave stop flag never halts a live row and
                // exact-token comparison stays deterministic under any
                // admission stagger
                StrategyParams::waves(6, 2)
            } else if method.uses_rounds() {
                StrategyParams::beam(
                    rng.range(1, 4) as usize,
                    rng.range(1, 3) as usize,
                    rng.range(6, 16) as usize,
                )
            } else {
                StrategyParams::parallel(rng.range(1, 6) as usize)
            };
            let budget = match case {
                0 => Budget::unlimited(),
                1 => Budget::unlimited().with_max_tokens(rng.range(4, 64) as usize),
                // generous deadline: exercises the deadline plumbing
                // without depending on preemption timing
                _ => Budget::unlimited().with_deadline_ms(60_000.0),
            };
            let strategy = Strategy::new(method.name(), params);
            let query = format!("Q:7+{}-2+8=?\n", rng.range(0, 9));
            let blocking = executor
                .run_budgeted(&strategy, &query, budget.clone())
                .unwrap();

            let mut stepper = Stepper::new(executor.clone());
            stepper
                .admit(Ticket {
                    query: query.clone(),
                    strategy: strategy.clone(),
                    budget,
                    tag: 0,
                })
                .unwrap();
            stepper.run_to_completion().unwrap();
            let mut done = stepper.drain_completed();
            assert_eq!(done.len(), 1);
            let completion = done.pop().unwrap();
            assert_eq!(completion.strategy_id, strategy.id());
            assert_outcomes_equal(
                &blocking,
                &completion.outcome,
                &format!("{} case {case}", strategy.id()),
            );
        }
    }
}

/// Four concurrent beam requests through one stepper: their round-k
/// expansions coalesce on the engine (`coalesced_generates > 0`), and
/// when one finishes early under a shared deadline pool, its leftover
/// deadline is granted to the still-running machines.
#[test]
fn concurrent_beams_coalesce_and_reallocate() {
    let Some((engine, executor)) = setup() else {
        return;
    };
    // Measure one beam run to size a deadline every request meets with
    // headroom — leftover budget is the reallocation pool.
    let strategy = Strategy::beam(2, 2, 12);
    let natural = executor.run(&strategy, "Q:7+0-2+8=?\n").unwrap();
    assert!(natural.latency_ms > 0.0);
    let deadline_ms = 50.0 * natural.latency_ms;

    let before = engine.metrics.coalesced_generates.get();
    let mut stepper =
        Stepper::new(executor.clone()).with_reallocator(Box::new(EvenShareReallocator));
    for i in 0..4u64 {
        stepper
            .admit(Ticket {
                query: format!("Q:7+{i}-2+8=?\n"),
                strategy: strategy.clone(),
                budget: Budget::unlimited().with_deadline_ms(deadline_ms),
                tag: i,
            })
            .unwrap();
    }
    stepper.run_to_completion().unwrap();
    let done = stepper.drain_completed();
    assert_eq!(done.len(), 4);
    for c in &done {
        assert!(
            !c.outcome.budget_exhausted,
            "deadline was sized with headroom; request {} hit it",
            c.tag
        );
    }

    // Expansion rounds from different machines merged into shared
    // engine rounds at least once across the run.
    let coalesced = engine.metrics.coalesced_generates.get() - before;
    eprintln!(
        "stepper: coalesced_generates={coalesced} steps={} submits={}",
        stepper.metrics.steps.get(),
        stepper.metrics.engine_submits.get()
    );
    assert!(
        coalesced > 0,
        "4 concurrent beam requests should coalesce at least one generate"
    );

    // Requests finished at different times under the shared deadline
    // pool, so early finishers' leftover deadline was granted to the
    // machines still running.
    assert!(
        stepper.metrics.realloc_grants.get() > 0,
        "an early finisher with deadline headroom must produce a grant"
    );
    assert!(stepper.metrics.realloc_ms_granted() > 0.0);
    assert!(stepper.metrics.realloc_events.get() >= 1);
}
