//! The accuracy probe `â_s(x)` (paper §2.4 + appendix A.1).
//!
//! A two-hidden-layer GELU MLP over `[query embedding ⊕ strategy
//! features]`, trained with BCE against *soft labels* (empirical success
//! rates from repeated strategy runs) and Platt-calibrated on a held-out
//! split. The MLP forward and Adam train-step are AOT'd HLO executed by
//! the engine — python never sees the collected labels.

pub mod features;
pub mod platt;
pub mod train;

pub use features::FeatureBuilder;
pub use platt::Platt;
pub use train::{train_probe, CalibratedProbe, ProbeCheckpoint, PROBE_LAYOUT_VERSION};
