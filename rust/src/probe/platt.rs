//! Platt scaling: `p = σ(A·z + B)` fitted on a held-out calibration set
//! (paper appendix A.1, "Calibration").
//!
//! Two-parameter logistic regression on the probe's raw logits against
//! soft labels, fitted by Newton–Raphson on the BCE objective. Closed-
//! form Hessian (2×2), a dozen iterations, no dependencies.

use crate::util::stats::{bce, sigmoid};

/// Fitted Platt parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Platt {
    pub a: f64,
    pub b: f64,
}

impl Default for Platt {
    fn default() -> Self {
        Platt { a: 1.0, b: 0.0 }
    }
}

impl Platt {
    /// Calibrated probability for a raw probe logit.
    pub fn prob(&self, z: f64) -> f64 {
        sigmoid(self.a * z + self.b)
    }

    /// Mean BCE of this calibration on (logit, soft label) pairs.
    pub fn loss(&self, pairs: &[(f64, f64)]) -> f64 {
        if pairs.is_empty() {
            return 0.0;
        }
        pairs
            .iter()
            .map(|&(z, y)| bce(y, self.prob(z)))
            .sum::<f64>()
            / pairs.len() as f64
    }

    /// Fit on (logit, soft label) pairs by damped Newton–Raphson with a
    /// backtracking line search (full Newton steps can overshoot on tiny
    /// calibration splits even though the objective is convex).
    pub fn fit(pairs: &[(f64, f64)]) -> Platt {
        let mut a = 1.0f64;
        let mut b = 0.0f64;
        if pairs.len() < 4 {
            return Platt { a, b };
        }
        let n = pairs.len() as f64;
        let mut loss = Platt { a, b }.loss(pairs);
        for _ in 0..40 {
            // gradient and Hessian of mean BCE wrt (a, b)
            let mut ga = 0.0;
            let mut gb = 0.0;
            let mut haa = 0.0;
            let mut hab = 0.0;
            let mut hbb = 0.0;
            for &(z, y) in pairs {
                let p = sigmoid(a * z + b);
                let r = p - y;
                let w = (p * (1.0 - p)).max(1e-9);
                ga += r * z;
                gb += r;
                haa += w * z * z;
                hab += w * z;
                hbb += w;
            }
            ga /= n;
            gb /= n;
            haa /= n;
            hab /= n;
            hbb /= n;
            // ridge for stability
            haa += 1e-6;
            hbb += 1e-6;
            let det = haa * hbb - hab * hab;
            if det.abs() < 1e-12 {
                break;
            }
            let da = (gb * hab - ga * hbb) / det;
            let db = (ga * hab - gb * haa) / det;
            // backtracking line search on the Newton direction
            let mut t = 1.0f64;
            let mut accepted = false;
            for _ in 0..25 {
                let cand = Platt {
                    a: a + t * da,
                    b: b + t * db,
                };
                let cand_loss = cand.loss(pairs);
                if cand_loss <= loss {
                    a = cand.a;
                    b = cand.b;
                    loss = cand_loss;
                    accepted = true;
                    break;
                }
                t *= 0.5;
            }
            if !accepted || (t * da).abs() < 1e-9 && (t * db).abs() < 1e-9 {
                break;
            }
        }
        // safeguard: never return a fit worse than identity on this data
        let fitted = Platt { a, b };
        if fitted.loss(pairs) <= Platt::default().loss(pairs) {
            fitted
        } else {
            Platt::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, prop_assert};
    use crate::util::rng::Rng;

    fn synth_pairs(rng: &mut Rng, a: f64, b: f64, n: usize) -> Vec<(f64, f64)> {
        (0..n)
            .map(|_| {
                let z = rng.normal() * 2.0;
                let p = sigmoid(a * z + b);
                // soft label = noisy estimate of p (like 3-repeat averages)
                let y = (0..3).map(|_| (rng.f64() < p) as u8 as f64).sum::<f64>() / 3.0;
                (z, y)
            })
            .collect()
    }

    #[test]
    fn recovers_scaling() {
        let mut rng = Rng::new(42, 0);
        let pairs = synth_pairs(&mut rng, 0.5, -0.8, 4000);
        let platt = Platt::fit(&pairs);
        assert!((platt.a - 0.5).abs() < 0.12, "a = {}", platt.a);
        assert!((platt.b + 0.8).abs() < 0.12, "b = {}", platt.b);
    }

    #[test]
    fn identity_when_already_calibrated() {
        let mut rng = Rng::new(7, 0);
        let pairs = synth_pairs(&mut rng, 1.0, 0.0, 4000);
        let platt = Platt::fit(&pairs);
        assert!((platt.a - 1.0).abs() < 0.15, "a = {}", platt.a);
        assert!(platt.b.abs() < 0.1, "b = {}", platt.b);
    }

    #[test]
    fn fit_never_worse_than_identity() {
        forall(
            "platt fit improves BCE",
            40,
            |rng| {
                let a = 0.25 + rng.f64() * 2.0;
                let b = rng.normal();
                synth_pairs(rng, a, b, 800)
            },
            |pairs| {
                let fitted = Platt::fit(pairs);
                let identity = Platt::default();
                prop_assert(
                    fitted.loss(pairs) <= identity.loss(pairs) + 1e-6,
                    format!(
                        "fitted {} > identity {}",
                        fitted.loss(pairs),
                        identity.loss(pairs)
                    ),
                )
            },
        );
    }

    #[test]
    fn monotone_in_logit_for_positive_a() {
        let platt = Platt { a: 0.7, b: -0.2 };
        let mut prev = 0.0;
        for i in -20..=20 {
            let p = platt.prob(i as f64 * 0.5);
            assert!(p >= prev);
            prev = p;
        }
    }

    #[test]
    fn tiny_input_returns_identity() {
        assert_eq!(Platt::fit(&[(0.3, 1.0)]), Platt::default());
    }
}
