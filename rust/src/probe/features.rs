//! Probe feature construction.
//!
//! Layout (must match `python/compile/model.py::PROBE_FEATURES` =
//! d_model + 4 + 4 + 1):
//!
//! ```text
//! [ embedding (d_model)
//! | log2(N)/4, W/4, chunk/16, beam_rounds/10        (strategy scalars)
//! | one-hot(method) (4)                              (appendix A.1)
//! | query_len/32 ]                                   (query metadata)
//! ```

use crate::strategies::space::{Method, Strategy};

/// Builds feature rows for (query, strategy) pairs.
#[derive(Debug, Clone)]
pub struct FeatureBuilder {
    pub d_model: usize,
    pub beam_max_rounds: usize,
}

impl FeatureBuilder {
    pub fn new(d_model: usize, beam_max_rounds: usize) -> FeatureBuilder {
        FeatureBuilder {
            d_model,
            beam_max_rounds,
        }
    }

    /// Total feature dimension.
    pub fn dim(&self) -> usize {
        self.d_model + 4 + 4 + 1
    }

    /// Assemble one feature row.
    ///
    /// `embedding` must have length `d_model`; `query_tokens` is the
    /// tokenized query length (the paper's "problem length" feature).
    pub fn build(&self, embedding: &[f32], strategy: &Strategy, query_tokens: usize) -> Vec<f32> {
        assert_eq!(embedding.len(), self.d_model, "embedding dim mismatch");
        let mut f = Vec::with_capacity(self.dim());
        f.extend_from_slice(embedding);
        // strategy scalars (normalized to O(1) ranges)
        f.push((strategy.n as f32).log2() / 4.0);
        f.push(strategy.width as f32 / 4.0);
        f.push(strategy.chunk as f32 / 16.0);
        f.push(if strategy.method == Method::Beam {
            self.beam_max_rounds as f32 / 10.0
        } else {
            0.0
        });
        // method one-hot
        let mut onehot = [0f32; 4];
        onehot[strategy.method.one_hot_index()] = 1.0;
        f.extend_from_slice(&onehot);
        // query metadata
        f.push(query_tokens as f32 / 32.0);
        debug_assert_eq!(f.len(), self.dim());
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_and_onehot() {
        let fb = FeatureBuilder::new(96, 10);
        assert_eq!(fb.dim(), 105);
        let emb = vec![0.5f32; 96];
        let f = fb.build(&emb, &Strategy::beam(4, 2, 12), 14);
        assert_eq!(f.len(), 105);
        // one-hot block at [96+4 .. 96+8): beam = index 3
        assert_eq!(&f[100..104], &[0.0, 0.0, 0.0, 1.0]);
        // scalars present
        assert!((f[96] - 0.5).abs() < 1e-6); // log2(4)/4
        assert!((f[97] - 0.5).abs() < 1e-6); // 2/4
        let f2 = fb.build(&emb, &Strategy::mv(8), 14);
        assert_eq!(&f2[100..104], &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(f2[99], 0.0); // no beam rounds for MV
    }

    #[test]
    fn distinct_strategies_distinct_features() {
        let fb = FeatureBuilder::new(8, 10);
        let emb = vec![0.1f32; 8];
        let space = crate::config::SpaceConfig::default();
        let all = Strategy::enumerate(&space);
        let rows: Vec<Vec<f32>> = all.iter().map(|s| fb.build(&emb, s, 12)).collect();
        for i in 0..rows.len() {
            for j in i + 1..rows.len() {
                assert_ne!(rows[i], rows[j], "{} vs {}", all[i].id(), all[j].id());
            }
        }
    }

    #[test]
    #[should_panic(expected = "embedding dim mismatch")]
    fn wrong_embedding_dim_panics() {
        let fb = FeatureBuilder::new(96, 10);
        fb.build(&[0.0; 4], &Strategy::mv(1), 5);
    }
}
