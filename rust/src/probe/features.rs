//! Probe feature construction.
//!
//! Layout (must match `python/compile/model.py::PROBE_FEATURES` =
//! d_model + 4 + n_methods + 1, where n_methods is the decoding-method
//! registry size at artifact-build time):
//!
//! ```text
//! [ embedding (d_model)
//! | log2(N)/4, W/4, chunk/16, rounds/10              (strategy scalars)
//! | one-hot(method) (registry order)                  (appendix A.1)
//! | query_len/32 ]                                    (query metadata)
//! ```
//!
//! The one-hot block is registry-driven: its width and each method's
//! index come from [`crate::strategies::registry`], frozen at
//! [`FeatureBuilder::new`] time. Methods registered *after* a builder is
//! constructed fall outside its one-hot block (their bit stays zero) —
//! retrain the probe with a fresh builder to give them a column.

use crate::strategies::registry;
use crate::strategies::space::Strategy;

/// Builds feature rows for (query, strategy) pairs.
#[derive(Debug, Clone)]
pub struct FeatureBuilder {
    pub d_model: usize,
    pub beam_max_rounds: usize,
    /// `(name, uses_rounds)` per registered method, frozen at
    /// construction — the position is the one-hot index. Cached here so
    /// the per-request router hot path (one row per strategy) never
    /// takes the registry lock.
    methods: Vec<(&'static str, bool)>,
}

impl FeatureBuilder {
    pub fn new(d_model: usize, beam_max_rounds: usize) -> FeatureBuilder {
        FeatureBuilder {
            d_model,
            beam_max_rounds,
            methods: registry::all()
                .iter()
                .map(|m| (m.name(), m.uses_rounds()))
                .collect(),
        }
    }

    /// Non-embedding feature width for the *current* registry: strategy
    /// scalars + method one-hot + query metadata. Used to recover
    /// `d_model` from an artifact's total feature count.
    pub fn aux_dim() -> usize {
        4 + registry::len() + 1
    }

    /// Total feature dimension.
    pub fn dim(&self) -> usize {
        self.d_model + 4 + self.methods.len() + 1
    }

    /// Assemble one feature row.
    ///
    /// `embedding` must have length `d_model`; `query_tokens` is the
    /// tokenized query length (the paper's "problem length" feature).
    pub fn build(&self, embedding: &[f32], strategy: &Strategy, query_tokens: usize) -> Vec<f32> {
        assert_eq!(embedding.len(), self.d_model, "embedding dim mismatch");
        // lock-free lookup against the frozen method table; a method
        // registered after this builder was constructed gets no column
        // (all-zero one-hot, no rounds feature) until the probe is
        // retrained with a fresh builder
        let method_ix = self
            .methods
            .iter()
            .position(|(name, _)| *name == strategy.method);
        let uses_rounds = matches!(method_ix, Some(ix) if self.methods[ix].1);
        let mut f = Vec::with_capacity(self.dim());
        f.extend_from_slice(embedding);
        // strategy scalars (normalized to O(1) ranges)
        f.push((strategy.n as f32).log2() / 4.0);
        f.push(strategy.width as f32 / 4.0);
        f.push(strategy.chunk as f32 / 16.0);
        f.push(if uses_rounds {
            self.beam_max_rounds as f32 / 10.0
        } else {
            0.0
        });
        // method one-hot (registry order)
        let mut onehot = vec![0f32; self.methods.len()];
        if let Some(ix) = method_ix {
            onehot[ix] = 1.0;
        }
        f.extend_from_slice(&onehot);
        // query metadata
        f.push(query_tokens as f32 / 32.0);
        debug_assert_eq!(f.len(), self.dim());
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_and_onehot() {
        let fb = FeatureBuilder::new(96, 10);
        // 6 built-in methods: 96 + 4 + 6 + 1
        assert_eq!(fb.dim(), 107);
        assert_eq!(FeatureBuilder::aux_dim(), 11);
        let emb = vec![0.5f32; 96];
        let f = fb.build(&emb, &Strategy::beam(4, 2, 12), 14);
        assert_eq!(f.len(), 107);
        // one-hot block at [96+4 .. 96+10): beam = index 3
        assert_eq!(&f[100..106], &[0.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        // scalars present
        assert!((f[96] - 0.5).abs() < 1e-6); // log2(4)/4
        assert!((f[97] - 0.5).abs() < 1e-6); // 2/4
        let f2 = fb.build(&emb, &Strategy::mv(8), 14);
        assert_eq!(&f2[100..106], &[1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(f2[99], 0.0); // no rounds feature for MV
        // the new methods get their own columns with no edits here
        let f3 = fb.build(&emb, &Strategy::mv_early(8), 14);
        assert_eq!(&f3[100..106], &[0.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        let f4 = fb.build(&emb, &Strategy::beam_latency(4, 2, 12), 14);
        assert_eq!(&f4[100..106], &[0.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
        assert!((f4[99] - 1.0).abs() < 1e-6); // rounds feature for beam family
    }

    #[test]
    fn distinct_strategies_distinct_features() {
        let fb = FeatureBuilder::new(8, 10);
        let emb = vec![0.1f32; 8];
        let space = crate::config::SpaceConfig::default();
        let all = Strategy::enumerate(&space);
        let rows: Vec<Vec<f32>> = all.iter().map(|s| fb.build(&emb, s, 12)).collect();
        for i in 0..rows.len() {
            for j in i + 1..rows.len() {
                assert_ne!(rows[i], rows[j], "{} vs {}", all[i].id(), all[j].id());
            }
        }
    }

    #[test]
    #[should_panic(expected = "embedding dim mismatch")]
    fn wrong_embedding_dim_panics() {
        let fb = FeatureBuilder::new(96, 10);
        fb.build(&[0.0; 4], &Strategy::mv(1), 5);
    }
}
