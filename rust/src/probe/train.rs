//! Probe training pipeline (paper appendix A.1), driven from rust.
//!
//! 1. Embed every train/calib query through the AOT'd embedder.
//! 2. Build (features, soft label) rows from the train-split matrix —
//!    the label is the empirical success rate of strategy `s` on query
//!    `x` across repeats.
//! 3. Train the MLP via the AOT'd Adam step on the engine (10%% of the
//!    train rows held out for early stopping).
//! 4. Platt-scale raw logits on the calib split.

use crate::config::ProbeConfig;
use crate::data::Query;
use crate::engine::{EmbedKind, EngineHandle};
use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::probe::features::FeatureBuilder;
use crate::probe::platt::Platt;
use crate::strategies::Strategy;
use crate::tokenizer::Tokenizer;
use crate::util::json::{parse, Value};
use crate::util::rng::Rng;
use crate::log_info;
use std::collections::HashMap;
use std::path::Path;

/// A trained + calibrated probe, ready for routing.
#[derive(Debug, Clone)]
pub struct CalibratedProbe {
    pub platt: Platt,
    pub embed_kind: EmbedKind,
    /// Flat trained parameters (engine also holds them after training).
    pub params: Vec<f32>,
}

impl CalibratedProbe {
    /// Calibrated success probabilities for feature rows. Assumes the
    /// engine currently holds `self.params` (call [`Self::install`] after
    /// loading from disk).
    pub fn predict(&self, engine: &EngineHandle, feats: Vec<Vec<f32>>) -> Result<Vec<f64>> {
        let logits = engine.probe_fwd(feats)?;
        Ok(logits.iter().map(|&z| self.platt.prob(z as f64)).collect())
    }

    /// Raw logits (used for calibration diagnostics).
    pub fn logits(&self, engine: &EngineHandle, feats: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        engine.probe_fwd(feats)
    }

    /// Push the stored params into the engine.
    pub fn install(&self, engine: &EngineHandle) -> Result<()> {
        engine.probe_load(self.params.clone())
    }
}

/// On-disk checkpoint: `<stem>.json` (platt + meta) + `<stem>.bin` (params).
///
/// The meta carries a **feature-layout stamp** (`layout_version` plus the
/// registry width the probe was trained against): the one-hot block is
/// registry-driven, so a checkpoint trained when the registry had N
/// methods cannot score feature rows built with M ≠ N methods. Loading
/// such a checkpoint fails with a clear retrain message instead of a dim
/// shape assert deep in the engine.
pub struct ProbeCheckpoint;

/// Bump when the feature layout changes shape in a way the
/// `n_methods` stamp alone cannot describe.
pub const PROBE_LAYOUT_VERSION: usize = 1;

impl ProbeCheckpoint {
    pub fn save(probe: &CalibratedProbe, stem: &Path) -> Result<()> {
        if let Some(parent) = stem.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let meta = Value::obj()
            .with("platt_a", probe.platt.a)
            .with("platt_b", probe.platt.b)
            .with(
                "embed_kind",
                match probe.embed_kind {
                    EmbedKind::Pool => "pool",
                    EmbedKind::Small => "small",
                },
            )
            .with("n_params", probe.params.len())
            .with("layout_version", PROBE_LAYOUT_VERSION)
            .with("n_methods", crate::strategies::registry::len());
        std::fs::write(stem.with_extension("json"), meta.pretty())?;
        let mut bytes = Vec::with_capacity(probe.params.len() * 4);
        for p in &probe.params {
            bytes.extend_from_slice(&p.to_le_bytes());
        }
        std::fs::write(stem.with_extension("bin"), bytes)?;
        Ok(())
    }

    pub fn load(stem: &Path) -> Result<CalibratedProbe> {
        let meta_path = stem.with_extension("json");
        let text = std::fs::read_to_string(&meta_path).map_err(|e| {
            Error::artifact(format!(
                "missing probe checkpoint {} ({e}) — run `ttc train-probe`",
                meta_path.display()
            ))
        })?;
        let meta = parse(&text)?;
        // Feature-layout stamp: fail loudly on checkpoints trained
        // against a different registry width (e.g. the 4-wide pre-registry
        // era) instead of tripping a shape assert at predict time.
        match meta.get("layout_version").and_then(Value::as_usize) {
            None => {
                return Err(Error::artifact(format!(
                    "probe checkpoint {} predates the feature-layout stamp \
                     (pre-registry one-hot layout) — regenerate with \
                     `ttc train-probe`",
                    meta_path.display()
                )));
            }
            Some(v) if v != PROBE_LAYOUT_VERSION => {
                return Err(Error::artifact(format!(
                    "probe checkpoint {} has layout_version {v}, this build \
                     expects {PROBE_LAYOUT_VERSION} — regenerate with `ttc train-probe`",
                    meta_path.display()
                )));
            }
            Some(_) => {}
        }
        let trained_methods = meta.req_usize("n_methods")?;
        let current = crate::strategies::registry::len();
        if trained_methods != current {
            return Err(Error::artifact(format!(
                "probe checkpoint {} was trained with a {trained_methods}-wide \
                 method one-hot but the registry now has {current} methods — \
                 rerun `ttc collect` + `ttc train-probe`",
                meta_path.display()
            )));
        }
        let bytes = std::fs::read(stem.with_extension("bin"))?;
        let n = meta.req_usize("n_params")?;
        if bytes.len() != n * 4 {
            return Err(Error::artifact("probe checkpoint size mismatch"));
        }
        let params = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(CalibratedProbe {
            platt: Platt {
                a: meta.req_f64("platt_a")?,
                b: meta.req_f64("platt_b")?,
            },
            embed_kind: match meta.req_str("embed_kind")? {
                "small" => EmbedKind::Small,
                _ => EmbedKind::Pool,
            },
            params,
        })
    }
}

/// Embed a set of queries; returns id → embedding.
pub fn embed_queries(
    engine: &EngineHandle,
    tokenizer: &Tokenizer,
    kind: EmbedKind,
    queries: &[Query],
) -> Result<HashMap<String, Vec<f32>>> {
    let token_lists: Vec<Vec<u32>> = queries
        .iter()
        .map(|q| tokenizer.encode(&q.query))
        .collect::<Result<_>>()?;
    let embs = engine.embed(kind, token_lists)?;
    Ok(queries
        .iter()
        .zip(embs)
        .map(|(q, e)| (q.id.clone(), e))
        .collect())
}

/// Feature + soft-label rows for one split's matrix.
#[allow(clippy::type_complexity)]
pub fn build_rows(
    matrix: &Matrix,
    queries: &[Query],
    embeddings: &HashMap<String, Vec<f32>>,
    fb: &FeatureBuilder,
    tokenizer: &Tokenizer,
) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
    let by_id: HashMap<&str, &Query> = queries.iter().map(|q| (q.id.as_str(), q)).collect();
    let mut feats = Vec::new();
    let mut labels = Vec::new();
    for ((query_id, strategy_id), cell) in matrix.cells() {
        let Some(query) = by_id.get(query_id.as_str()) else {
            continue; // matrix may contain other splits' rows
        };
        let strategy = Strategy::parse(&strategy_id)
            .ok_or_else(|| Error::internal(format!("bad strategy id '{strategy_id}'")))?;
        let emb = embeddings
            .get(&query_id)
            .ok_or_else(|| Error::internal(format!("no embedding for '{query_id}'")))?;
        let qlen = tokenizer.encode(&query.query)?.len();
        feats.push(fb.build(emb, &strategy, qlen));
        labels.push(cell.acc as f32);
    }
    Ok((feats, labels))
}

/// Full pipeline: train on the train-split matrix, calibrate on calib.
#[allow(clippy::too_many_arguments)]
pub fn train_probe(
    engine: &EngineHandle,
    train_matrix: &Matrix,
    calib_matrix: &Matrix,
    train_queries: &[Query],
    calib_queries: &[Query],
    fb: &FeatureBuilder,
    embed_kind: EmbedKind,
    cfg: &ProbeConfig,
    seed: u64,
) -> Result<(CalibratedProbe, Value)> {
    let tokenizer = Tokenizer::new();
    let train_emb = embed_queries(engine, &tokenizer, embed_kind, train_queries)?;
    let calib_emb = embed_queries(engine, &tokenizer, embed_kind, calib_queries)?;

    let (mut feats, mut labels) =
        build_rows(train_matrix, train_queries, &train_emb, fb, &tokenizer)?;
    if feats.is_empty() {
        return Err(Error::internal("no training rows — collect the matrix first"));
    }

    // shuffle + 90/10 early-stop split
    let mut rng = Rng::new(seed, 0x9A0BE);
    let mut order: Vec<usize> = (0..feats.len()).collect();
    rng.shuffle(&mut order);
    let reorder = |v: &mut Vec<Vec<f32>>, order: &[usize]| {
        let mut out = Vec::with_capacity(v.len());
        for &i in order {
            out.push(std::mem::take(&mut v[i]));
        }
        *v = out;
    };
    reorder(&mut feats, &order);
    let labels_new: Vec<f32> = order.iter().map(|&i| labels[i]).collect();
    labels = labels_new;
    let n_val = (feats.len() / 10).max(8).min(feats.len() / 2);
    let val_feats = feats.split_off(feats.len() - n_val);
    let val_labels = labels.split_off(labels.len() - n_val);

    log_info!(
        "probe[{}]: {} train rows, {} val rows, {} features",
        match embed_kind {
            EmbedKind::Pool => "pool",
            EmbedKind::Small => "small",
        },
        feats.len(),
        val_feats.len(),
        fb.dim()
    );
    let report = engine.probe_train(
        feats,
        labels,
        val_feats,
        val_labels,
        cfg.epochs,
        cfg.patience,
    )?;
    log_info!(
        "probe: {} steps, train loss {:.4}, best val loss {:.4}",
        report.steps,
        report.final_train_loss,
        report.best_val_loss
    );

    // Platt calibration on the calib split (raw logits vs soft labels).
    let (calib_feats, calib_labels) =
        build_rows(calib_matrix, calib_queries, &calib_emb, fb, &tokenizer)?;
    let logits = engine.probe_fwd(calib_feats)?;
    let pairs: Vec<(f64, f64)> = logits
        .iter()
        .zip(&calib_labels)
        .map(|(&z, &y)| (z as f64, y as f64))
        .collect();
    let platt = Platt::fit(&pairs);
    let pre_ece = crate::util::stats::ece(
        &pairs
            .iter()
            .map(|&(z, y)| (crate::util::stats::sigmoid(z), y))
            .collect::<Vec<_>>(),
        10,
    );
    let post_ece = crate::util::stats::ece(
        &pairs
            .iter()
            .map(|&(z, y)| (platt.prob(z), y))
            .collect::<Vec<_>>(),
        10,
    );
    log_info!(
        "platt: a={:.3} b={:.3}, ECE {:.4} -> {:.4} on {} calib rows",
        platt.a,
        platt.b,
        pre_ece,
        post_ece,
        pairs.len()
    );

    let curve_json: Vec<Value> = report
        .curve
        .iter()
        .map(|&(e, tr, va)| {
            Value::obj()
                .with("epoch", e)
                .with("train_loss", tr)
                .with("val_loss", va)
        })
        .collect();
    let report_json = Value::obj()
        .with("steps", report.steps)
        .with("final_train_loss", report.final_train_loss)
        .with("best_val_loss", report.best_val_loss)
        .with("platt_a", platt.a)
        .with("platt_b", platt.b)
        .with("calib_ece_pre", pre_ece)
        .with("calib_ece_post", post_ece)
        .with("curve", Value::Arr(curve_json));

    Ok((
        CalibratedProbe {
            platt,
            embed_kind,
            params: report.params,
        },
        report_json,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let probe = CalibratedProbe {
            platt: Platt { a: 0.7, b: -0.3 },
            embed_kind: EmbedKind::Small,
            params: vec![1.0, -2.0, 3.5],
        };
        let stem = std::env::temp_dir().join(format!("ttc_probe_{}", std::process::id()));
        ProbeCheckpoint::save(&probe, &stem).unwrap();
        let back = ProbeCheckpoint::load(&stem).unwrap();
        assert_eq!(back.params, probe.params);
        assert_eq!(back.platt, probe.platt);
        assert_eq!(back.embed_kind, EmbedKind::Small);
        std::fs::remove_file(stem.with_extension("json")).unwrap();
        std::fs::remove_file(stem.with_extension("bin")).unwrap();
    }

    #[test]
    fn missing_checkpoint_mentions_train_probe() {
        let err = ProbeCheckpoint::load(Path::new("/nonexistent/probe"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("train-probe"), "{err}");
    }

    fn write_checkpoint(stem: &Path, meta: &Value, n_params: usize) {
        std::fs::write(stem.with_extension("json"), meta.pretty()).unwrap();
        std::fs::write(stem.with_extension("bin"), vec![0u8; n_params * 4]).unwrap();
    }

    #[test]
    fn legacy_checkpoint_without_stamp_fails_clearly() {
        let stem = std::env::temp_dir().join(format!("ttc_probe_legacy_{}", std::process::id()));
        // a 4-wide-era checkpoint: no layout_version / n_methods fields
        let meta = Value::obj()
            .with("platt_a", 1.0)
            .with("platt_b", 0.0)
            .with("embed_kind", "pool")
            .with("n_params", 3usize);
        write_checkpoint(&stem, &meta, 3);
        let err = ProbeCheckpoint::load(&stem).unwrap_err().to_string();
        assert!(err.contains("layout"), "{err}");
        assert!(err.contains("train-probe"), "{err}");
        std::fs::remove_file(stem.with_extension("json")).unwrap();
        std::fs::remove_file(stem.with_extension("bin")).unwrap();
    }

    #[test]
    fn four_wide_era_checkpoint_demands_retrain_not_shape_panic() {
        // The concrete legacy shape from the pre-registry era: a
        // checkpoint stamped with the 4-method one-hot layout. It must
        // fail at *load* with the retrain message — not reach predict
        // time and trip a feature-dimension shape assert in the engine.
        let stem = std::env::temp_dir().join(format!("ttc_probe_4wide_{}", std::process::id()));
        let meta = Value::obj()
            .with("platt_a", 1.0)
            .with("platt_b", 0.0)
            .with("embed_kind", "pool")
            .with("n_params", 3usize)
            .with("layout_version", PROBE_LAYOUT_VERSION)
            .with("n_methods", 4usize);
        write_checkpoint(&stem, &meta, 3);
        let err = ProbeCheckpoint::load(&stem).unwrap_err();
        assert!(
            matches!(err, crate::error::Error::Artifact(_)),
            "expected an artifact error, got {err:?}"
        );
        let msg = err.to_string();
        assert!(msg.contains("4-wide"), "{msg}");
        assert!(
            msg.contains(&format!("{} methods", crate::strategies::registry::len())),
            "{msg}"
        );
        assert!(msg.contains("train-probe"), "{msg}");
        std::fs::remove_file(stem.with_extension("json")).unwrap();
        std::fs::remove_file(stem.with_extension("bin")).unwrap();
    }

    #[test]
    fn future_layout_version_demands_retrain() {
        let stem = std::env::temp_dir().join(format!("ttc_probe_vnext_{}", std::process::id()));
        let meta = Value::obj()
            .with("platt_a", 1.0)
            .with("platt_b", 0.0)
            .with("embed_kind", "pool")
            .with("n_params", 3usize)
            .with("layout_version", PROBE_LAYOUT_VERSION + 1)
            .with("n_methods", crate::strategies::registry::len());
        write_checkpoint(&stem, &meta, 3);
        let err = ProbeCheckpoint::load(&stem).unwrap_err().to_string();
        assert!(err.contains("layout_version"), "{err}");
        assert!(err.contains("train-probe"), "{err}");
        std::fs::remove_file(stem.with_extension("json")).unwrap();
        std::fs::remove_file(stem.with_extension("bin")).unwrap();
    }

    #[test]
    fn registry_width_mismatch_fails_clearly() {
        let stem = std::env::temp_dir().join(format!("ttc_probe_width_{}", std::process::id()));
        let wrong = crate::strategies::registry::len() + 2;
        let meta = Value::obj()
            .with("platt_a", 1.0)
            .with("platt_b", 0.0)
            .with("embed_kind", "pool")
            .with("n_params", 3usize)
            .with("layout_version", PROBE_LAYOUT_VERSION)
            .with("n_methods", wrong);
        write_checkpoint(&stem, &meta, 3);
        let err = ProbeCheckpoint::load(&stem).unwrap_err().to_string();
        assert!(err.contains("one-hot"), "{err}");
        assert!(err.contains(&format!("{wrong}-wide")), "{err}");
        std::fs::remove_file(stem.with_extension("json")).unwrap();
        std::fs::remove_file(stem.with_extension("bin")).unwrap();
    }
}
