//! In-process loopback transport: the full framed codec over a pair of
//! channel-backed byte pipes, no real sockets.
//!
//! This makes every protocol and failover path deterministically
//! testable in a container with no network: the bytes on the "wire" are
//! identical to TCP's, only the transport differs. It also permits the
//! one clock exception documented in `docs/remote.md`: because client
//! and server share a process, loopback tests may hand both sides the
//! same [`crate::util::clock::SimClock`] and keep a deterministic
//! virtual timeline — impossible across real machines.

use std::io::{Read, Write};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::error::Result;

use super::transport::{Conn, Connector, ReadHalf, WriteHalf};

/// One end of an in-process duplex byte pipe.
pub struct LoopbackConn {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    /// Bytes received but not yet consumed by `read`.
    buf: Vec<u8>,
    pos: usize,
    timeout: Option<Duration>,
    label: String,
}

/// Create a connected pair of loopback endpoints.
pub fn pair() -> (LoopbackConn, LoopbackConn) {
    let (a_tx, b_rx) = channel();
    let (b_tx, a_rx) = channel();
    let mk = |tx, rx, label: &str| LoopbackConn {
        tx,
        rx,
        buf: Vec::new(),
        pos: 0,
        timeout: None,
        label: label.to_string(),
    };
    (
        mk(a_tx, a_rx, "loopback:client"),
        mk(b_tx, b_rx, "loopback:server"),
    )
}

impl Read for LoopbackConn {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.buf.len() {
            let chunk = match self.timeout {
                Some(t) => match self.rx.recv_timeout(t) {
                    Ok(c) => c,
                    Err(RecvTimeoutError::Timeout) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "loopback read timed out",
                        ));
                    }
                    // Peer dropped: clean EOF, like a closed socket.
                    Err(RecvTimeoutError::Disconnected) => return Ok(0),
                },
                None => match self.rx.recv() {
                    Ok(c) => c,
                    Err(_) => return Ok(0),
                },
            };
            self.buf = chunk;
            self.pos = 0;
            if self.buf.is_empty() {
                return Ok(0);
            }
        }
        let n = out.len().min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl Write for LoopbackConn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.tx.send(buf.to_vec()).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "loopback peer is gone")
        })?;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Conn for LoopbackConn {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.timeout = timeout;
        Ok(())
    }
    fn peer(&self) -> String {
        self.label.clone()
    }
    fn split(self: Box<Self>) -> Result<(Box<dyn ReadHalf>, Box<dyn WriteHalf>)> {
        let this = *self;
        Ok((
            Box::new(LoopbackReadHalf {
                rx: this.rx,
                buf: this.buf,
                pos: this.pos,
                timeout: this.timeout,
                label: this.label.clone(),
            }),
            Box::new(LoopbackWriteHalf {
                tx: Some(this.tx),
                label: this.label,
            }),
        ))
    }
}

/// Read side of a split [`LoopbackConn`].
pub struct LoopbackReadHalf {
    rx: Receiver<Vec<u8>>,
    buf: Vec<u8>,
    pos: usize,
    timeout: Option<Duration>,
    label: String,
}

impl Read for LoopbackReadHalf {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.buf.len() {
            let chunk = match self.timeout {
                Some(t) => match self.rx.recv_timeout(t) {
                    Ok(c) => c,
                    Err(RecvTimeoutError::Timeout) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "loopback read timed out",
                        ));
                    }
                    Err(RecvTimeoutError::Disconnected) => return Ok(0),
                },
                None => match self.rx.recv() {
                    Ok(c) => c,
                    Err(_) => return Ok(0),
                },
            };
            self.buf = chunk;
            self.pos = 0;
            if self.buf.is_empty() {
                return Ok(0);
            }
        }
        let n = out.len().min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl ReadHalf for LoopbackReadHalf {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.timeout = timeout;
        Ok(())
    }
    fn peer(&self) -> String {
        self.label.clone()
    }
}

/// Write side of a split [`LoopbackConn`]. `shutdown` drops the sender,
/// which the peer observes as EOF — the loopback equivalent of closing
/// a socket.
pub struct LoopbackWriteHalf {
    tx: Option<Sender<Vec<u8>>>,
    label: String,
}

impl Write for LoopbackWriteHalf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let tx = self.tx.as_ref().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "loopback write half shut down")
        })?;
        tx.send(buf.to_vec()).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "loopback peer is gone")
        })?;
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl WriteHalf for LoopbackWriteHalf {
    fn peer(&self) -> String {
        self.label.clone()
    }
    fn shutdown(&mut self) {
        self.tx = None;
    }
}

/// Message to a loopback accept loop.
pub enum AcceptMsg {
    /// A freshly dialed server-side connection end.
    Conn(LoopbackConn),
    /// Stop accepting and exit the accept thread.
    Stop,
}

/// Dials loopback connections by handing the server end of a fresh
/// [`pair`] to the server's accept channel. Cloneable: each clone dials
/// the same in-process server.
#[derive(Clone)]
pub struct LoopbackConnector {
    accept_tx: Sender<AcceptMsg>,
    label: String,
}

impl LoopbackConnector {
    pub fn new(accept_tx: Sender<AcceptMsg>, label: impl Into<String>) -> LoopbackConnector {
        LoopbackConnector {
            accept_tx,
            label: label.into(),
        }
    }
}

impl Connector for LoopbackConnector {
    fn connect(&self) -> Result<Box<dyn Conn>> {
        let (client, server) = pair();
        self.accept_tx
            .send(AcceptMsg::Conn(server))
            .map_err(|_| {
                crate::error::Error::net_transient(format!(
                    "connect to {} failed: server is gone",
                    self.label
                ))
            })?;
        Ok(Box::new(client))
    }

    fn addr(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_cross_the_pipe_in_order() {
        let (mut a, mut b) = pair();
        a.write_all(b"hello ").unwrap();
        a.write_all(b"world").unwrap();
        let mut got = [0u8; 11];
        b.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"hello world");
    }

    #[test]
    fn dropped_peer_reads_as_eof() {
        let (a, mut b) = pair();
        drop(a);
        let mut buf = [0u8; 4];
        assert_eq!(b.read(&mut buf).unwrap(), 0);
    }

    #[test]
    fn read_timeout_fires() {
        let (_a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(10))).unwrap();
        let mut buf = [0u8; 4];
        let err = b.read(&mut buf).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
    }

    #[test]
    fn split_halves_keep_the_pipe_and_shutdown_eofs_the_peer() {
        let (a, mut b) = pair();
        let (mut rd, mut wr) = (Box::new(a) as Box<dyn Conn>).split().unwrap();
        b.write_all(b"pong").unwrap();
        wr.write_all(b"ping").unwrap();
        let mut got = [0u8; 4];
        rd.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"pong");
        let mut got = [0u8; 4];
        b.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"ping");
        wr.shutdown();
        let mut buf = [0u8; 1];
        assert_eq!(b.read(&mut buf).unwrap(), 0, "shutdown must read as EOF");
        assert!(wr.write_all(b"x").is_err(), "writes after shutdown must fail");
    }

    #[test]
    fn connector_hands_conns_to_the_accept_channel() {
        let (tx, rx) = channel();
        let connector = LoopbackConnector::new(tx, "loopback://test");
        let mut client = connector.connect().unwrap();
        let mut server = match rx.recv().unwrap() {
            AcceptMsg::Conn(c) => c,
            AcceptMsg::Stop => panic!("expected a connection"),
        };
        client.write_all(b"ping").unwrap();
        let mut got = [0u8; 4];
        server.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"ping");
    }
}
