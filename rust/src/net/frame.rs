//! Length-prefixed, versioned wire frames.
//!
//! Every message on a remote-engine connection is one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"TTCW"
//! 4       2     protocol version, big-endian u16
//! 6       1     codec id (1 = JSON, 2 = TTCB binary)
//! 7       1     reserved, must be 0
//! 8       4     payload length, big-endian u32
//! 12      n     payload bytes (codec-encoded message)
//! ```
//!
//! The version check happens at this layer: a reader that sees a frame
//! stamped with a different [`PROTOCOL_VERSION`] fails with a
//! non-transient [`Error::Net`] naming both versions, before any
//! payload is decoded. Payload length is validated against
//! [`MAX_FRAME_BYTES`] *before* allocation so a malformed or hostile
//! frame cannot OOM the server. Header and payload are coalesced into a
//! single buffered write, so a frame is one syscall on the way out and
//! two writers sharing a transport can never interleave halves of a
//! frame. See `docs/remote.md` for a worked byte-level example.

use std::io::{Read, Write};

use crate::error::{Error, Result};

/// Wire protocol version stamped into every frame header.
pub const PROTOCOL_VERSION: u16 = 1;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"TTCW";

/// Codec id for the JSON serializer.
pub const CODEC_JSON: u8 = 1;

/// Codec id for the TTCB binary serializer.
pub const CODEC_TTCB: u8 = 2;

/// Size of the fixed frame header in bytes.
pub const HEADER_BYTES: usize = 12;

/// Upper bound on a frame payload (64 MiB). Checked before allocating.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Write one frame with the current [`PROTOCOL_VERSION`].
pub fn write_frame(w: &mut dyn Write, codec_id: u8, payload: &[u8]) -> Result<()> {
    write_frame_versioned(w, PROTOCOL_VERSION, codec_id, payload)
}

/// Write one frame with an explicit version stamp. Exposed so tests
/// (and docs) can fabricate version-mismatch frames.
pub fn write_frame_versioned(
    w: &mut dyn Write,
    version: u16,
    codec_id: u8,
    payload: &[u8],
) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(Error::net(format!(
            "refusing to send a {} byte frame (max {MAX_FRAME_BYTES})",
            payload.len()
        )));
    }
    // One buffer, one write, one flush: header and payload must hit the
    // transport as a unit so concurrent writers on a shared (multiplexed)
    // connection cannot interleave halves of different frames.
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&version.to_be_bytes());
    buf.push(codec_id);
    buf.push(0);
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, validating magic, version, codec and length. Returns
/// the raw payload bytes.
///
/// A clean EOF before any header byte is a *transient* fault (the peer
/// closed the connection — e.g. its engine fleet shut down mid-call),
/// so callers can retry on another shard. Anything structurally wrong
/// with the header is a permanent protocol error.
pub fn read_frame(r: &mut dyn Read, expect_codec: u8) -> Result<Vec<u8>> {
    match read_frame_poll(r, expect_codec)? {
        Some(payload) => Ok(payload),
        None => Err(Error::net_transient("read timed out waiting for a frame")),
    }
}

/// Like [`read_frame`], but a read timeout that fires before *any*
/// header byte arrived returns `Ok(None)` instead of an error. The
/// multiplexer's reader thread polls with a short timeout so it can
/// notice a dying link between frames; a timeout mid-header or
/// mid-payload is still a (transient) fault.
pub fn read_frame_poll(r: &mut dyn Read, expect_codec: u8) -> Result<Option<Vec<u8>>> {
    let mut header = [0u8; HEADER_BYTES];
    if !read_exact_or_eof(r, &mut header)? {
        return Ok(None);
    }
    if header[0..4] != MAGIC {
        return Err(Error::net(format!(
            "bad frame magic {:02x?} (expected {:02x?} — not a ttc wire peer?)",
            &header[0..4],
            MAGIC
        )));
    }
    let version = u16::from_be_bytes([header[4], header[5]]);
    if version != PROTOCOL_VERSION {
        return Err(Error::net(format!(
            "protocol version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
        )));
    }
    let codec = header[6];
    if codec != expect_codec {
        return Err(Error::net(format!(
            "codec mismatch: frame uses codec {codec}, connection negotiated {expect_codec}"
        )));
    }
    if header[7] != 0 {
        return Err(Error::net(format!(
            "reserved frame byte is {} (must be 0)",
            header[7]
        )));
    }
    let len = u32::from_be_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(Error::net(format!(
            "frame announces {len} payload bytes (max {MAX_FRAME_BYTES}) — refusing to allocate"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        Error::net_transient(format!("connection dropped mid-frame ({len} byte payload): {e}"))
    })?;
    Ok(Some(payload))
}

/// Read the full header. Returns `Ok(false)` when a read timeout fired
/// before the first byte (the poll case); maps EOF-before-first-byte to
/// a transient "peer closed" error and partial reads to a mid-frame
/// drop.
fn read_exact_or_eof(r: &mut dyn Read, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if filled == 0 {
                    Error::net_transient("peer closed the connection")
                } else {
                    Error::net_transient(format!(
                        "connection dropped mid-header ({filled} of {} bytes)",
                        buf.len()
                    ))
                });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(Error::net_transient(format!(
                    "read timed out mid-header ({filled} of {} bytes): {e}",
                    buf.len()
                )));
            }
            Err(e) => return Err(Error::net_transient(format!("read failed: {e}"))),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_frame_bytes() {
        // This exact layout is documented in docs/remote.md — keep the
        // two in sync.
        let mut buf = Vec::new();
        write_frame(&mut buf, CODEC_JSON, b"{}").unwrap();
        assert_eq!(
            buf,
            vec![
                b'T', b'T', b'C', b'W', // magic
                0x00, 0x01, // protocol version 1, big-endian
                0x01, // codec: JSON
                0x00, // reserved
                0x00, 0x00, 0x00, 0x02, // payload length 2
                b'{', b'}', // payload
            ]
        );
    }

    #[test]
    fn roundtrip() {
        let payload = br#"{"op":"generate","rows":3}"#;
        let mut buf = Vec::new();
        write_frame(&mut buf, CODEC_JSON, payload).unwrap();
        let got = read_frame(&mut &buf[..], CODEC_JSON).unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn version_mismatch_names_both_versions() {
        let mut buf = Vec::new();
        write_frame_versioned(&mut buf, 7, CODEC_JSON, b"{}").unwrap();
        let err = read_frame(&mut &buf[..], CODEC_JSON).unwrap_err();
        assert!(!err.is_transient_net());
        let msg = err.to_string();
        assert!(msg.contains("v7") && msg.contains("v1"), "{msg}");
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, CODEC_JSON, b"{}").unwrap();
        buf[0] = b'X';
        let err = read_frame(&mut &buf[..], CODEC_JSON).unwrap_err();
        assert!(!err.is_transient_net());
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut buf = Vec::new();
        write_frame(&mut buf, CODEC_JSON, b"{}").unwrap();
        buf[8..12].copy_from_slice(&(u32::MAX).to_be_bytes());
        let err = read_frame(&mut &buf[..], CODEC_JSON).unwrap_err();
        assert!(!err.is_transient_net());
        assert!(err.to_string().contains("refusing to allocate"));
    }

    #[test]
    fn eof_is_transient() {
        let err = read_frame(&mut &[][..], CODEC_JSON).unwrap_err();
        assert!(err.is_transient_net(), "clean EOF must be transient: {err}");
    }

    #[test]
    fn truncated_payload_is_transient() {
        let mut buf = Vec::new();
        write_frame(&mut buf, CODEC_JSON, b"{\"k\":1}").unwrap();
        buf.truncate(buf.len() - 3);
        let err = read_frame(&mut &buf[..], CODEC_JSON).unwrap_err();
        assert!(err.is_transient_net(), "{err}");
    }

    #[test]
    fn codec_mismatch_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, CODEC_TTCB, b"{}").unwrap();
        let err = read_frame(&mut &buf[..], CODEC_JSON).unwrap_err();
        assert!(err.to_string().contains("codec"));
    }

    #[test]
    fn frame_is_a_single_write() {
        /// Writer that records each `write` call separately.
        struct CallCounter {
            calls: Vec<usize>,
        }
        impl std::io::Write for CallCounter {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.calls.push(buf.len());
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w = CallCounter { calls: Vec::new() };
        write_frame(&mut w, CODEC_JSON, br#"{"op":"info"}"#).unwrap();
        assert_eq!(
            w.calls,
            vec![HEADER_BYTES + 13],
            "header and payload must be coalesced into one write"
        );
    }

    /// Adversarial single-byte mutation of a valid frame must never
    /// panic: every outcome is either the original payload (mutating
    /// payload bytes still frames correctly) or a classified error.
    #[test]
    fn prop_mutated_frames_never_panic() {
        crate::testkit::forall(
            "frame mutation",
            300,
            |rng| {
                let payload: Vec<u8> = (0..rng.below(24)).map(|_| rng.below(256) as u8).collect();
                let mut buf = Vec::new();
                write_frame(&mut buf, CODEC_JSON, &payload).unwrap();
                let pos = rng.below(buf.len());
                let byte = rng.below(256) as u8;
                (buf, pos, byte)
            },
            |(buf, pos, byte)| {
                let mut mutated = buf.clone();
                mutated[*pos] ^= *byte;
                let _ = read_frame(&mut &mutated[..], CODEC_JSON);
                // truncation after mutation must also be handled
                let cut = mutated.len() / 2;
                let _ = read_frame(&mut &mutated[..cut], CODEC_JSON);
                Ok(())
            },
        );
    }
}
