//! Wire message schema: handshake, shape exchange and the
//! request/response envelope.
//!
//! The first frame in each direction is the handshake: the client
//! sends a `hello` carrying its [`super::frame::PROTOCOL_VERSION`] and
//! [`ProbeLayout`] stamp; the server answers with an `ack` carrying its
//! own plus its backend identity and [`EngineShapes`]. Version
//! disagreement is caught twice — at the frame layer (header stamp) and
//! here (explicit field) — so a mismatch always produces a clear
//! [`Error::Net`] naming both versions rather than a decode failure.
//!
//! After the handshake every client frame is a request object
//! (`{"op": ..., ...}`) and every server frame is an envelope:
//! `{"ok": <result>}` on success, `{"err": {"kind", "message"}}` on
//! failure. Server-reported errors are *non-transient* by construction
//! (the server executed the call and it failed); transient faults are
//! transport-level only (EOF, timeouts, refused dials).

use crate::engine::EngineShapes;
use crate::error::{Error, Result};
use crate::util::json::Value;

/// The probe feature/method layout both sides must agree on: probe
/// params trained under one layout are garbage under another.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeLayout {
    /// [`crate::probe::PROBE_LAYOUT_VERSION`] of this build.
    pub layout_version: usize,
    /// Number of registered decoding methods (feature one-hot width).
    pub n_methods: usize,
}

impl ProbeLayout {
    /// The layout stamp of this build.
    pub fn current() -> ProbeLayout {
        ProbeLayout {
            layout_version: crate::probe::PROBE_LAYOUT_VERSION,
            n_methods: crate::strategies::registry::len(),
        }
    }

    pub fn to_value(self) -> Value {
        Value::obj()
            .with("layout_version", self.layout_version)
            .with("n_methods", self.n_methods)
    }

    pub fn from_value(v: &Value) -> Result<ProbeLayout> {
        Ok(ProbeLayout {
            layout_version: v.req_usize("layout_version")?,
            n_methods: v.req_usize("n_methods")?,
        })
    }

    /// Check a peer's stamp against ours, naming both on mismatch.
    pub fn check(self, peer: ProbeLayout, peer_role: &str) -> Result<()> {
        if self != peer {
            return Err(Error::net(format!(
                "probe layout mismatch: {peer_role} has layout v{} with {} methods, \
                 this build has layout v{} with {} methods — retrain or upgrade",
                peer.layout_version, peer.n_methods, self.layout_version, self.n_methods
            )));
        }
        Ok(())
    }
}

/// Build the client hello with explicit version/layout (tests fabricate
/// mismatches by passing non-current values).
pub fn hello(protocol: u16, layout: ProbeLayout) -> Value {
    Value::obj()
        .with("type", "hello")
        .with("protocol", protocol as usize)
        .with("probe_layout", layout.to_value())
        .with("client", "ttc-remote-backend")
}

/// Build the server ack.
pub fn ack(
    protocol: u16,
    layout: ProbeLayout,
    backend: &str,
    engines: usize,
    shapes: Value,
) -> Value {
    Value::obj()
        .with("type", "ack")
        .with("protocol", protocol as usize)
        .with("probe_layout", layout.to_value())
        .with("server", "ttc-engine-serve")
        .with("backend", backend)
        .with("engines", engines)
        .with("shapes", shapes)
}

/// Codec/multiplexing capabilities riding on a hello or ack. Both
/// fields are *additive* handshake keys: a PR 6-era peer neither sends
/// nor reads them, and [`WireCaps::of`] defaults their absence to
/// "JSON only, serial", so old and new builds interoperate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireCaps {
    /// Frame codec ids the peer speaks (see [`super::serializer`]).
    pub codecs: Vec<u8>,
    /// True when the peer can run correlation-id-tagged frames
    /// concurrently on this connection.
    pub mux: bool,
}

impl WireCaps {
    /// Read the capability fields from a materialized hello/ack.
    pub fn of(v: &Value) -> WireCaps {
        let codecs = v
            .get("codecs")
            .and_then(Value::as_arr)
            .map(|items| {
                items
                    .iter()
                    .filter_map(Value::as_usize)
                    .filter(|&id| id > 0 && id <= u8::MAX as usize)
                    .map(|id| id as u8)
                    .collect()
            })
            .unwrap_or_else(|| vec![super::frame::CODEC_JSON]);
        WireCaps {
            codecs,
            mux: v.get("mux").and_then(Value::as_bool).unwrap_or(false),
        }
    }

    /// Attach the capability fields to a hello or ack.
    pub fn stamp(&self, mut msg: Value) -> Value {
        msg.set(
            "codecs",
            Value::Arr(self.codecs.iter().map(|&c| Value::from(c as u64)).collect()),
        );
        msg.set("mux", self.mux);
        msg
    }
}

/// Pick the data-plane codec: the highest id both sides advertise,
/// falling back to JSON (which every build speaks). Run independently
/// on both ends of the handshake it yields the same answer, so the
/// choice never needs a confirmation round-trip.
pub fn negotiate_codec(ours: &[u8], theirs: &[u8]) -> u8 {
    ours.iter()
        .copied()
        .filter(|c| theirs.contains(c))
        .max()
        .unwrap_or(super::frame::CODEC_JSON)
}

/// Validate an incoming hello against this build. Returns nothing on
/// success; errors name both sides' stamps.
pub fn check_hello(v: &Value) -> Result<()> {
    if v.req_str("type")? != "hello" {
        return Err(Error::net("expected a hello as the first frame"));
    }
    let peer_protocol = v.req_usize("protocol")?;
    if peer_protocol != super::frame::PROTOCOL_VERSION as usize {
        return Err(Error::net(format!(
            "protocol version mismatch: client speaks v{peer_protocol}, server speaks v{}",
            super::frame::PROTOCOL_VERSION
        )));
    }
    let peer = ProbeLayout::from_value(v.req("probe_layout")?)?;
    ProbeLayout::current().check(peer, "client")
}

/// Validate an incoming hello through the lazy cursor — the server
/// accept path. Peeks `type`/`protocol` without materializing anything
/// and only parses the small `probe_layout`/`codecs` fields; the
/// (potentially large) rest of the document is never built. Returns the
/// client's capabilities.
pub fn check_hello_lazy(doc: &crate::util::json::lazy::LazyDoc) -> Result<WireCaps> {
    if doc.str_of("type") != Some("hello") {
        return Err(Error::net("expected a hello as the first frame"));
    }
    let peer_protocol = doc
        .usize_of("protocol")
        .ok_or_else(|| Error::Json("missing or non-integer key 'protocol'".to_string()))?;
    if peer_protocol != super::frame::PROTOCOL_VERSION as usize {
        return Err(Error::net(format!(
            "protocol version mismatch: client speaks v{peer_protocol}, server speaks v{}",
            super::frame::PROTOCOL_VERSION
        )));
    }
    let peer = ProbeLayout::from_value(&doc.field("probe_layout")?)?;
    ProbeLayout::current().check(peer, "client")?;
    let codecs = if doc.has("codecs") {
        WireCaps::of(&Value::obj().with("codecs", doc.field("codecs")?)).codecs
    } else {
        vec![super::frame::CODEC_JSON]
    };
    Ok(WireCaps {
        codecs,
        mux: doc.bool_of("mux").unwrap_or(false),
    })
}

/// Validate a server ack; returns (backend name, engines, shapes).
pub fn check_ack(v: &Value) -> Result<(String, usize, EngineShapes)> {
    // The server reports handshake rejections through the error
    // envelope; surface those as-is.
    if let Some(err) = v.get("err") {
        return Err(envelope_error(err));
    }
    if v.req_str("type")? != "ack" {
        return Err(Error::net("expected an ack to the hello"));
    }
    let peer_protocol = v.req_usize("protocol")?;
    if peer_protocol != super::frame::PROTOCOL_VERSION as usize {
        return Err(Error::net(format!(
            "protocol version mismatch: server speaks v{peer_protocol}, client speaks v{}",
            super::frame::PROTOCOL_VERSION
        )));
    }
    let peer = ProbeLayout::from_value(v.req("probe_layout")?)?;
    ProbeLayout::current().check(peer, "server")?;
    let shapes = shapes_from_value(v.req("shapes")?)?;
    Ok((
        v.req_str("backend")?.to_string(),
        v.req_usize("engines")?,
        shapes,
    ))
}

/// Serialize [`EngineShapes`] for the ack (flat wire form; key names
/// match the engine `info()` shapes object).
pub fn shapes_to_value(s: &EngineShapes) -> Value {
    Value::obj()
        .with(
            "batch_buckets",
            Value::Arr(s.batch_buckets.iter().map(|&b| Value::from(b)).collect()),
        )
        .with(
            "chunk_lens",
            Value::Arr(s.chunk_lens.iter().map(|&l| Value::from(l)).collect()),
        )
        .with("query_len", s.query_len)
        .with("prm_len", s.prm_len)
        .with("gen_max_new", s.gen_max_new)
        .with("chunk_max_new", s.chunk_max_new)
        .with("probe_fwd_batch", s.probe_fwd_batch)
        .with("probe_train_batch", s.probe_train_batch)
        .with("probe_features", s.probe_features)
        .with("d_model", s.d_model)
}

/// Parse the flat wire form back into [`EngineShapes`].
pub fn shapes_from_value(v: &Value) -> Result<EngineShapes> {
    let usizes = |key: &str| -> Result<Vec<usize>> {
        v.req_arr(key)?
            .iter()
            .map(|x| {
                x.as_usize()
                    .ok_or_else(|| Error::net(format!("shapes.{key}: bad entry")))
            })
            .collect()
    };
    Ok(EngineShapes {
        batch_buckets: usizes("batch_buckets")?,
        chunk_lens: usizes("chunk_lens")?,
        query_len: v.req_usize("query_len")?,
        prm_len: v.req_usize("prm_len")?,
        gen_max_new: v.req_usize("gen_max_new")?,
        chunk_max_new: v.req_usize("chunk_max_new")?,
        probe_fwd_batch: v.req_usize("probe_fwd_batch")?,
        probe_train_batch: v.req_usize("probe_train_batch")?,
        probe_features: v.req_usize("probe_features")?,
        d_model: v.req_usize("d_model")?,
    })
}

/// Wrap a successful result for the wire.
pub fn ok_envelope(result: Value) -> Value {
    Value::obj().with("ok", result)
}

/// Wrap an error for the wire.
pub fn err_envelope(e: &Error) -> Value {
    Value::obj().with(
        "err",
        Value::obj()
            .with("kind", e.kind_str())
            .with("message", e.to_string()),
    )
}

/// Unwrap a response envelope: `ok` payload, or the server's error as a
/// non-transient [`Error::Net`].
pub fn unwrap_response(v: Value) -> Result<Value> {
    if let Some(err) = v.get("err") {
        return Err(envelope_error(err));
    }
    match v {
        Value::Obj(mut pairs) => {
            let pos = pairs.iter().position(|(k, _)| k == "ok").ok_or_else(|| {
                Error::net("response envelope has neither 'ok' nor 'err'")
            })?;
            Ok(pairs.swap_remove(pos).1)
        }
        _ => Err(Error::net("response envelope is not an object")),
    }
}

fn envelope_error(err: &Value) -> Error {
    let kind = err.req_str("kind").unwrap_or("unknown");
    let message = err.req_str("message").unwrap_or("<no message>");
    Error::net(format!("remote {kind} error: {message}"))
}

/// Encode a token row for the wire.
pub fn tokens_to_value(tokens: &[u32]) -> Value {
    Value::Arr(tokens.iter().map(|&t| Value::from(t as u64)).collect())
}

/// Decode a token row.
pub fn tokens_from_value(v: &Value, what: &str) -> Result<Vec<u32>> {
    v.as_arr()
        .ok_or_else(|| Error::net(format!("{what}: expected a token array")))?
        .iter()
        .map(|t| {
            t.as_i64()
                .filter(|&x| (0..=u32::MAX as i64).contains(&x))
                .map(|x| x as u32)
                .ok_or_else(|| Error::net(format!("{what}: bad token value")))
        })
        .collect()
}

/// Encode an f32 row for the wire.
pub fn f32s_to_value(row: &[f32]) -> Value {
    Value::from(row)
}

/// Decode an f32 row.
pub fn f32s_from_value(v: &Value, what: &str) -> Result<Vec<f32>> {
    v.as_arr()
        .ok_or_else(|| Error::net(format!("{what}: expected a float array")))?
        .iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| Error::net(format!("{what}: bad float value")))
                .map(|f| f as f32)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    #[test]
    fn shapes_roundtrip() {
        let s = EngineShapes::sim_default(&EngineConfig::default());
        let back = shapes_from_value(&shapes_to_value(&s)).unwrap();
        assert_eq!(back.batch_buckets, s.batch_buckets);
        assert_eq!(back.chunk_lens, s.chunk_lens);
        assert_eq!(back.query_len, s.query_len);
        assert_eq!(back.prm_len, s.prm_len);
        assert_eq!(back.gen_max_new, s.gen_max_new);
        assert_eq!(back.chunk_max_new, s.chunk_max_new);
        assert_eq!(back.probe_fwd_batch, s.probe_fwd_batch);
        assert_eq!(back.probe_train_batch, s.probe_train_batch);
        assert_eq!(back.probe_features, s.probe_features);
        assert_eq!(back.d_model, s.d_model);
    }

    #[test]
    fn handshake_accepts_current_build() {
        let h = hello(super::super::frame::PROTOCOL_VERSION, ProbeLayout::current());
        check_hello(&h).unwrap();
        let s = EngineShapes::sim_default(&EngineConfig::default());
        let a = ack(
            super::super::frame::PROTOCOL_VERSION,
            ProbeLayout::current(),
            "sim",
            2,
            shapes_to_value(&s),
        );
        let (backend, engines, shapes) = check_ack(&a).unwrap();
        assert_eq!(backend, "sim");
        assert_eq!(engines, 2);
        assert_eq!(shapes.d_model, s.d_model);
    }

    #[test]
    fn handshake_rejects_version_skew_naming_both() {
        let h = hello(super::super::frame::PROTOCOL_VERSION + 1, ProbeLayout::current());
        let err = check_hello(&h).unwrap_err();
        assert_eq!(err.kind_str(), "net");
        assert!(!err.is_transient_net());
        let msg = err.to_string();
        assert!(msg.contains("v2") && msg.contains("v1"), "{msg}");
    }

    #[test]
    fn handshake_rejects_probe_layout_skew() {
        let mut wrong = ProbeLayout::current();
        wrong.layout_version += 1;
        let h = hello(super::super::frame::PROTOCOL_VERSION, wrong);
        let err = check_hello(&h).unwrap_err();
        assert!(err.to_string().contains("probe layout mismatch"), "{err}");
    }

    #[test]
    fn envelopes_roundtrip_ok_and_err() {
        let ok = ok_envelope(Value::obj().with("scores", vec![0.5f64]));
        let v = unwrap_response(ok).unwrap();
        assert_eq!(v.req_arr("scores").unwrap().len(), 1);

        let err_v = err_envelope(&Error::Engine("bucket overflow".into()));
        let err = unwrap_response(err_v).unwrap_err();
        assert!(!err.is_transient_net());
        let msg = err.to_string();
        assert!(msg.contains("remote engine error") && msg.contains("bucket overflow"), "{msg}");
    }

    #[test]
    fn caps_default_to_json_serial_for_old_peers() {
        // a PR 6-era hello carries neither "codecs" nor "mux"
        let h = hello(super::super::frame::PROTOCOL_VERSION, ProbeLayout::current());
        let caps = WireCaps::of(&h);
        assert_eq!(caps.codecs, vec![super::super::frame::CODEC_JSON]);
        assert!(!caps.mux);

        let stamped = WireCaps {
            codecs: vec![1, 2],
            mux: true,
        }
        .stamp(h);
        let caps = WireCaps::of(&stamped);
        assert_eq!(caps.codecs, vec![1, 2]);
        assert!(caps.mux);
        // the stamped hello still validates for old-style readers
        check_hello(&stamped).unwrap();
    }

    #[test]
    fn codec_negotiation_picks_highest_common_id() {
        assert_eq!(negotiate_codec(&[1, 2], &[1, 2]), 2);
        assert_eq!(negotiate_codec(&[1, 2], &[1]), 1);
        assert_eq!(negotiate_codec(&[1], &[1, 2]), 1);
        // pathological: no overlap still falls back to JSON
        assert_eq!(negotiate_codec(&[2], &[7]), 1);
    }

    #[test]
    fn lazy_hello_check_matches_eager() {
        let h = WireCaps {
            codecs: vec![1, 2],
            mux: true,
        }
        .stamp(hello(super::super::frame::PROTOCOL_VERSION, ProbeLayout::current()));
        let text = h.dumps();
        let doc = crate::util::json::lazy::LazyDoc::index(&text).unwrap();
        let caps = check_hello_lazy(&doc).unwrap();
        assert_eq!(caps.codecs, vec![1, 2]);
        assert!(caps.mux);

        let skewed = hello(super::super::frame::PROTOCOL_VERSION + 1, ProbeLayout::current());
        let text = skewed.dumps();
        let doc = crate::util::json::lazy::LazyDoc::index(&text).unwrap();
        let err = check_hello_lazy(&doc).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");

        let not_hello = Value::obj().with("type", "ping").dumps();
        let doc = crate::util::json::lazy::LazyDoc::index(&not_hello).unwrap();
        assert!(check_hello_lazy(&doc).is_err());
    }

    #[test]
    fn token_rows_roundtrip() {
        let row = vec![0u32, 1, 65535, u32::MAX];
        let back = tokens_from_value(&tokens_to_value(&row), "row").unwrap();
        assert_eq!(back, row);
        let bad = Value::Arr(vec![Value::from(-1i64)]);
        assert!(tokens_from_value(&bad, "row").is_err());
    }
}
