//! Byte transports for framed messages.
//!
//! A [`Conn`] is a bidirectional byte stream with a read timeout; a
//! [`Connector`] dials new connections. TCP implementations ship for
//! the reference environment; [`super::loopback`] provides an
//! in-process pipe with the same semantics for deterministic tests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::metrics::Counter;
use crate::util::json::Value;

use super::serializer::Serializer;

/// One established bidirectional byte stream.
pub trait Conn: Read + Write + Send {
    /// Set (or clear) the blocking-read timeout.
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()>;
    /// Human-readable peer description for log/error messages.
    fn peer(&self) -> String;
}

/// Dials new [`Conn`]s to one remote endpoint.
pub trait Connector: Send {
    /// Establish a fresh connection. Connection-refused and similar
    /// dial failures surface as *transient* [`Error::Net`] so the
    /// caller's retry/backoff loop engages.
    fn connect(&self) -> Result<Box<dyn Conn>>;
    /// Endpoint description for logs and errors.
    fn addr(&self) -> String;
}

/// Wire-level counters, shared across a backend's reconnects.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Frames written to the wire.
    pub frames_sent: Counter,
    /// Frames read off the wire.
    pub frames_received: Counter,
    /// Payload bytes written (excludes frame headers).
    pub bytes_sent: Counter,
    /// Payload bytes read (excludes frame headers).
    pub bytes_received: Counter,
    /// Per-call retries after a transient fault.
    pub retries: Counter,
    /// Fresh dials (first connect and every reconnect).
    pub reconnects: Counter,
}

impl NetMetrics {
    pub fn new() -> Arc<NetMetrics> {
        Arc::new(NetMetrics::default())
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("frames_sent", self.frames_sent.get())
            .with("frames_received", self.frames_received.get())
            .with("bytes_sent", self.bytes_sent.get())
            .with("bytes_received", self.bytes_received.get())
            .with("retries", self.retries.get())
            .with("reconnects", self.reconnects.get())
    }
}

/// Encode `v` with `codec` and write it as one frame.
pub fn send_msg(
    conn: &mut dyn Conn,
    codec: &dyn Serializer,
    v: &Value,
    metrics: Option<&NetMetrics>,
) -> Result<()> {
    let payload = codec.encode(v)?;
    super::frame::write_frame(conn, codec.codec_id(), &payload)?;
    if let Some(m) = metrics {
        m.frames_sent.inc();
        m.bytes_sent.add(payload.len() as u64);
    }
    Ok(())
}

/// Read one frame and decode it with `codec`.
pub fn recv_msg(
    conn: &mut dyn Conn,
    codec: &dyn Serializer,
    metrics: Option<&NetMetrics>,
) -> Result<Value> {
    let payload = super::frame::read_frame(conn, codec.codec_id())?;
    if let Some(m) = metrics {
        m.frames_received.inc();
        m.bytes_received.add(payload.len() as u64);
    }
    codec.decode(&payload)
}

/// A real TCP connection (nodelay, blocking I/O).
pub struct TcpConn {
    stream: TcpStream,
    peer: String,
}

impl TcpConn {
    pub fn new(stream: TcpStream) -> TcpConn {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".to_string());
        let _ = stream.set_nodelay(true);
        TcpConn { stream, peer }
    }
}

impl Read for TcpConn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.stream.read(buf)
    }
}

impl Write for TcpConn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.stream.write(buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.stream.flush()
    }
}

impl Conn for TcpConn {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }
    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// Dials TCP connections to one `host:port` with a connect timeout.
pub struct TcpConnector {
    addr: String,
    connect_timeout: Duration,
}

impl TcpConnector {
    pub fn new(addr: impl Into<String>, connect_timeout: Duration) -> TcpConnector {
        TcpConnector {
            addr: addr.into(),
            connect_timeout,
        }
    }
}

impl Connector for TcpConnector {
    fn connect(&self) -> Result<Box<dyn Conn>> {
        use std::net::ToSocketAddrs;
        let mut addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| Error::net(format!("cannot resolve '{}': {e}", self.addr)))?;
        let addr = addrs
            .next()
            .ok_or_else(|| Error::net(format!("'{}' resolves to no address", self.addr)))?;
        let stream = TcpStream::connect_timeout(&addr, self.connect_timeout).map_err(|e| {
            Error::net_transient(format!("connect to {} failed: {e}", self.addr))
        })?;
        Ok(Box::new(TcpConn::new(stream)))
    }

    fn addr(&self) -> String {
        self.addr.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::serializer::JsonCodec;

    #[test]
    fn send_recv_over_loopback_pipe_counts_frames() {
        let (mut a, mut b) = crate::net::loopback::pair();
        let codec = JsonCodec;
        let metrics = NetMetrics::new();
        let msg = Value::obj().with("op", "info");
        send_msg(&mut a, &codec, &msg, Some(&metrics)).unwrap();
        let got = recv_msg(&mut b, &codec, Some(&metrics)).unwrap();
        assert_eq!(got.req_str("op").unwrap(), "info");
        assert_eq!(metrics.frames_sent.get(), 1);
        assert_eq!(metrics.frames_received.get(), 1);
        assert!(metrics.bytes_sent.get() > 0);
    }

    #[test]
    fn connect_refused_is_transient() {
        // Port 1 on localhost is essentially never listening.
        let c = TcpConnector::new("127.0.0.1:1", Duration::from_millis(200));
        match c.connect() {
            Err(e) => assert!(e.is_transient_net(), "dial failure must be transient: {e}"),
            Ok(_) => panic!("connect to port 1 unexpectedly succeeded"),
        }
    }
}
