//! Byte transports for framed messages.
//!
//! A [`Conn`] is a bidirectional byte stream with a read timeout; a
//! [`Connector`] dials new connections. TCP implementations ship for
//! the reference environment; [`super::loopback`] provides an
//! in-process pipe with the same semantics for deterministic tests.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::metrics::Counter;
use crate::util::json::Value;

use super::serializer::Serializer;

/// One established bidirectional byte stream.
pub trait Conn: Read + Write + Send {
    /// Set (or clear) the blocking-read timeout.
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()>;
    /// Human-readable peer description for log/error messages.
    fn peer(&self) -> String;
    /// Split into independently owned read and write halves so a reader
    /// thread can demultiplex replies while writers enqueue frames.
    fn split(self: Box<Self>) -> Result<(Box<dyn ReadHalf>, Box<dyn WriteHalf>)>;
}

/// The read side of a split [`Conn`], owned by a demux reader thread.
pub trait ReadHalf: Read + Send {
    /// Set (or clear) the blocking-read timeout (the reader polls with a
    /// short timeout so it can notice a dying link between frames).
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()>;
    /// Human-readable peer description for log/error messages.
    fn peer(&self) -> String;
}

/// The write side of a split [`Conn`], shared behind a mutex by
/// concurrent callers.
pub trait WriteHalf: Write + Send {
    /// Human-readable peer description for log/error messages.
    fn peer(&self) -> String;
    /// Best-effort full-connection shutdown: after this the peer sees
    /// EOF, which is how a multiplexed server signals "engine down,
    /// fail over" without a per-call error.
    fn shutdown(&mut self);
}

/// Dials new [`Conn`]s to one remote endpoint.
pub trait Connector: Send {
    /// Establish a fresh connection. Connection-refused and similar
    /// dial failures surface as *transient* [`Error::Net`] so the
    /// caller's retry/backoff loop engages.
    fn connect(&self) -> Result<Box<dyn Conn>>;
    /// Endpoint description for logs and errors.
    fn addr(&self) -> String;
}

/// Wire-level counters, shared across a backend's reconnects.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Frames written to the wire.
    pub frames_sent: Counter,
    /// Frames read off the wire.
    pub frames_received: Counter,
    /// Payload bytes written (excludes frame headers).
    pub bytes_sent: Counter,
    /// Payload bytes read (excludes frame headers).
    pub bytes_received: Counter,
    /// Per-call retries after a transient fault.
    pub retries: Counter,
    /// Fresh dials (first connect and every reconnect).
    pub reconnects: Counter,
    /// High-water mark of concurrent in-flight calls on a multiplexed
    /// connection (1 means the link never actually overlapped calls).
    pub mux_inflight_peak: Counter,
    /// Calls that blocked because the multiplexed connection was at its
    /// `max_inflight` bound and had to wait for a reply to free a slot.
    pub mux_backpressure_waits: Counter,
    /// Payload bytes the binary codec saved versus the JSON encoding of
    /// the same envelopes (0 when the negotiated codec is JSON).
    pub bytes_saved_vs_json: Counter,
}

impl NetMetrics {
    pub fn new() -> Arc<NetMetrics> {
        Arc::new(NetMetrics::default())
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("frames_sent", self.frames_sent.get())
            .with("frames_received", self.frames_received.get())
            .with("bytes_sent", self.bytes_sent.get())
            .with("bytes_received", self.bytes_received.get())
            .with("retries", self.retries.get())
            .with("reconnects", self.reconnects.get())
            .with("mux_inflight_peak", self.mux_inflight_peak.get())
            .with("mux_backpressure_waits", self.mux_backpressure_waits.get())
            .with("bytes_saved_vs_json", self.bytes_saved_vs_json.get())
    }

    /// Account one sent frame; credits `bytes_saved_vs_json` when a
    /// non-JSON codec beat the JSON encoding of the same envelope.
    pub fn note_sent(&self, codec: &dyn Serializer, v: &Value, payload_len: usize) {
        self.frames_sent.inc();
        self.bytes_sent.add(payload_len as u64);
        if codec.codec_id() != super::frame::CODEC_JSON {
            self.bytes_saved_vs_json
                .add(v.encoded_len().saturating_sub(payload_len) as u64);
        }
    }

    /// Account one received frame (see [`NetMetrics::note_sent`]).
    pub fn note_received(&self, codec: &dyn Serializer, v: &Value, payload_len: usize) {
        self.frames_received.inc();
        self.bytes_received.add(payload_len as u64);
        if codec.codec_id() != super::frame::CODEC_JSON {
            self.bytes_saved_vs_json
                .add(v.encoded_len().saturating_sub(payload_len) as u64);
        }
    }
}

/// Encode `v` with `codec` and write it as one frame.
pub fn send_msg(
    conn: &mut dyn Write,
    codec: &dyn Serializer,
    v: &Value,
    metrics: Option<&NetMetrics>,
) -> Result<()> {
    let payload = codec.encode(v)?;
    super::frame::write_frame(conn, codec.codec_id(), &payload)?;
    if let Some(m) = metrics {
        m.note_sent(codec, v, payload.len());
    }
    Ok(())
}

/// Read one frame and decode it with `codec`.
pub fn recv_msg(
    conn: &mut dyn Read,
    codec: &dyn Serializer,
    metrics: Option<&NetMetrics>,
) -> Result<Value> {
    let payload = super::frame::read_frame(conn, codec.codec_id())?;
    let v = codec.decode(&payload)?;
    if let Some(m) = metrics {
        m.note_received(codec, &v, payload.len());
    }
    Ok(v)
}

/// A real TCP connection (nodelay, blocking I/O).
pub struct TcpConn {
    stream: TcpStream,
    peer: String,
}

impl TcpConn {
    pub fn new(stream: TcpStream) -> TcpConn {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".to_string());
        let _ = stream.set_nodelay(true);
        TcpConn { stream, peer }
    }
}

impl Read for TcpConn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.stream.read(buf)
    }
}

impl Write for TcpConn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.stream.write(buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.stream.flush()
    }
}

impl Conn for TcpConn {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }
    fn peer(&self) -> String {
        self.peer.clone()
    }
    fn split(self: Box<Self>) -> Result<(Box<dyn ReadHalf>, Box<dyn WriteHalf>)> {
        let write = self.stream.try_clone().map_err(|e| {
            Error::net_transient(format!("cannot split connection to {}: {e}", self.peer))
        })?;
        Ok((
            Box::new(TcpReadHalf {
                stream: self.stream,
                peer: self.peer.clone(),
            }),
            Box::new(TcpWriteHalf {
                stream: write,
                peer: self.peer,
            }),
        ))
    }
}

/// Read side of a split [`TcpConn`] (a `try_clone` of the socket).
pub struct TcpReadHalf {
    stream: TcpStream,
    peer: String,
}

impl Read for TcpReadHalf {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.stream.read(buf)
    }
}

impl ReadHalf for TcpReadHalf {
    fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }
    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// Write side of a split [`TcpConn`].
pub struct TcpWriteHalf {
    stream: TcpStream,
    peer: String,
}

impl Write for TcpWriteHalf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.stream.write(buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.stream.flush()
    }
}

impl WriteHalf for TcpWriteHalf {
    fn peer(&self) -> String {
        self.peer.clone()
    }
    fn shutdown(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Dials TCP connections to one `host:port` with a connect timeout.
pub struct TcpConnector {
    addr: String,
    connect_timeout: Duration,
}

impl TcpConnector {
    pub fn new(addr: impl Into<String>, connect_timeout: Duration) -> TcpConnector {
        TcpConnector {
            addr: addr.into(),
            connect_timeout,
        }
    }
}

impl Connector for TcpConnector {
    fn connect(&self) -> Result<Box<dyn Conn>> {
        use std::net::ToSocketAddrs;
        let mut addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| Error::net(format!("cannot resolve '{}': {e}", self.addr)))?;
        let addr = addrs
            .next()
            .ok_or_else(|| Error::net(format!("'{}' resolves to no address", self.addr)))?;
        let stream = TcpStream::connect_timeout(&addr, self.connect_timeout).map_err(|e| {
            Error::net_transient(format!("connect to {} failed: {e}", self.addr))
        })?;
        Ok(Box::new(TcpConn::new(stream)))
    }

    fn addr(&self) -> String {
        self.addr.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::serializer::JsonCodec;

    #[test]
    fn send_recv_over_loopback_pipe_counts_frames() {
        let (mut a, mut b) = crate::net::loopback::pair();
        let codec = JsonCodec;
        let metrics = NetMetrics::new();
        let msg = Value::obj().with("op", "info");
        send_msg(&mut a, &codec, &msg, Some(&metrics)).unwrap();
        let got = recv_msg(&mut b, &codec, Some(&metrics)).unwrap();
        assert_eq!(got.req_str("op").unwrap(), "info");
        assert_eq!(metrics.frames_sent.get(), 1);
        assert_eq!(metrics.frames_received.get(), 1);
        assert!(metrics.bytes_sent.get() > 0);
    }

    #[test]
    fn connect_refused_is_transient() {
        // Port 1 on localhost is essentially never listening.
        let c = TcpConnector::new("127.0.0.1:1", Duration::from_millis(200));
        match c.connect() {
            Err(e) => assert!(e.is_transient_net(), "dial failure must be transient: {e}"),
            Ok(_) => panic!("connect to port 1 unexpectedly succeeded"),
        }
    }
}
