//! Shared, multiplexed client transport: one connection per host,
//! correlation-id-tagged frames, a reader thread demuxing replies.
//!
//! PR 6 gave every engine slot its own blocking connection: N slots
//! dialing one host meant N sockets, and each call serialized on its
//! slot's socket. [`MuxTransport`] replaces that with one shared link
//! per host:
//!
//! * **writers** — any number of threads call [`MuxTransport::call`]
//!   concurrently; each call stamps a fresh `id` into its request,
//!   registers a reply channel under that id, and writes its frame
//!   under a brief writer lock (frames are single-write at the
//!   [`super::frame`] layer, so frames never interleave);
//! * **reader** — one thread per link reads frames off the wire and
//!   routes each reply to the waiter registered under its `id`. Late
//!   replies (the waiter timed out) are dropped; a read fault fails
//!   every waiter at once, preserving transience so the pool's
//!   failover engages.
//!
//! The codec and the multiplexing mode are negotiated per connection in
//! the JSON-framed hello/ack handshake. A PR 6-era server (no `mux`
//! capability) degrades the link to *serial* mode — one call at a time
//! under a connection lock, exactly the old semantics — so old and new
//! peers interoperate.
//!
//! Retry/backoff/redial semantics are unchanged from PR 6: transient
//! faults get bounded same-host retries with doubled backoff, and an
//! exhausted retry budget surfaces as a *transient* net error the pool
//! treats as "shard dead".

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use crate::engine::EngineShapes;
use crate::error::{Error, Result};
use crate::util::json::Value;

use super::client::RemoteConfig;
use super::serializer::{self, Serializer};
use super::transport::{recv_msg, send_msg, Connector, NetMetrics, ReadHalf, WriteHalf};
use super::{frame, wire};

/// How often the reader thread wakes between frames to check whether
/// the link was torn down locally.
const READER_POLL: Duration = Duration::from_millis(200);

/// What the server told us in its ack.
#[derive(Debug, Clone)]
pub struct AckInfo {
    /// The server's execution backend name (`sim`, `device`).
    pub backend: String,
    /// Engines in the server's pool.
    pub engines: usize,
    /// The server's engine shapes.
    pub shapes: EngineShapes,
}

/// One live connection: negotiated codec plus its concurrency mode.
struct Link {
    codec: &'static dyn Serializer,
    dead: AtomicBool,
    mode: LinkMode,
}

enum LinkMode {
    /// PR 6-era peer: whole-call lock, one request/response at a time.
    Serial(Mutex<Box<dyn super::transport::Conn>>),
    /// Correlation-id multiplexing over split halves.
    Mux(MuxIo),
}

struct MuxIo {
    /// `None` once the link is torn down — writers then fail fast.
    writer: Mutex<Option<Box<dyn WriteHalf>>>,
    /// Reply channels keyed by correlation id. Bounded at
    /// `RemoteConfig::max_inflight` entries: callers at the bound park
    /// on `slot_freed` until a removal makes room.
    pending: Mutex<HashMap<u64, mpsc::Sender<Result<Value>>>>,
    /// Signalled on every `pending` removal (reply routed, call timed
    /// out, link torn down), so bounded callers re-check.
    slot_freed: Condvar,
    next_id: AtomicU64,
}

/// Shared per-host client transport. Every engine slot pointed at the
/// same host holds the same `Arc<MuxTransport>`; the transport owns the
/// dial/handshake/negotiation lifecycle and the retry loop.
pub struct MuxTransport {
    connector: Mutex<Box<dyn Connector>>,
    addr: String,
    cfg: RemoteConfig,
    metrics: Arc<NetMetrics>,
    state: Mutex<TransportState>,
}

#[derive(Default)]
struct TransportState {
    link: Option<Arc<Link>>,
    ack: Option<AckInfo>,
}

impl MuxTransport {
    pub fn new(
        connector: Box<dyn Connector>,
        cfg: RemoteConfig,
        metrics: Arc<NetMetrics>,
    ) -> Arc<MuxTransport> {
        let addr = connector.addr();
        Arc::new(MuxTransport {
            connector: Mutex::new(connector),
            addr,
            cfg,
            metrics,
            state: Mutex::new(TransportState::default()),
        })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn metrics(&self) -> &Arc<NetMetrics> {
        &self.metrics
    }

    /// Dial and handshake if there is no live link; returns the
    /// server's identity. Called eagerly at backend construction so a
    /// bad address, version skew or layout mismatch fails engine
    /// startup with a clear error instead of poisoning the first call.
    pub fn ensure(&self) -> Result<AckInfo> {
        let mut st = self.state.lock().unwrap();
        if st
            .link
            .as_ref()
            .map_or(true, |l| l.dead.load(Ordering::Relaxed))
        {
            self.dial_locked(&mut st)?;
        }
        Ok(st.ack.clone().expect("dial_locked records the ack"))
    }

    /// Negotiated codec name and whether the link is multiplexed, for
    /// `describe()` output.
    pub fn wire_status(&self) -> (&'static str, bool) {
        let st = self.state.lock().unwrap();
        match &st.link {
            Some(link) => (link.codec.name(), matches!(link.mode, LinkMode::Mux(_))),
            None => ("none", false),
        }
    }

    /// Execute one request with bounded retry on transient faults.
    /// Takes the request by value: the mux path stamps a fresh
    /// correlation id into it per attempt without cloning row data.
    pub fn call(&self, mut req: Value) -> Result<Value> {
        let mut backoff_ms = self.cfg.backoff_ms;
        let mut last: Option<Error> = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                self.metrics.retries.inc();
                if backoff_ms > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(backoff_ms / 1e3));
                }
                backoff_ms *= 2.0;
            }
            let link = match self.live_link() {
                Ok(link) => link,
                Err(e) if e.is_transient_net() => {
                    last = Some(e);
                    continue;
                }
                Err(e) => return Err(e),
            };
            match self.try_once(&link, &mut req) {
                Ok(v) => return Ok(v),
                Err(e) if e.is_transient_net() => {
                    // The link is suspect: tear it down so the next
                    // attempt redials.
                    self.drop_link(&link);
                    last = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        let last = last.map(|e| e.to_string()).unwrap_or_default();
        // Still transient: the *shard* is down, but the pool can rescue
        // the request on another one.
        Err(Error::net_transient(format!(
            "{} unreachable after {} attempt(s): {last}",
            self.addr,
            self.cfg.retries + 1
        )))
    }

    fn live_link(&self) -> Result<Arc<Link>> {
        let mut st = self.state.lock().unwrap();
        if let Some(link) = &st.link {
            if !link.dead.load(Ordering::Relaxed) {
                return Ok(link.clone());
            }
        }
        self.dial_locked(&mut st)?;
        Ok(st.link.clone().expect("dial_locked installs the link"))
    }

    /// Dial, handshake (always JSON-framed), negotiate codec + mux, and
    /// install the resulting link. Caller holds the state lock.
    fn dial_locked(&self, st: &mut TransportState) -> Result<()> {
        let mut conn = self.connector.lock().unwrap().connect()?;
        conn.set_read_timeout(Some(Duration::from_secs_f64(
            (self.cfg.call_timeout_ms / 1e3).max(1e-3),
        )))
        .map_err(|e| Error::net(format!("cannot set read timeout: {e}")))?;
        self.metrics.reconnects.inc();
        let ours = serializer::supported_ids(self.cfg.wire_codec);
        let hello = wire::WireCaps {
            codecs: ours.to_vec(),
            mux: true,
        }
        .stamp(wire::hello(
            frame::PROTOCOL_VERSION,
            wire::ProbeLayout::current(),
        ));
        send_msg(&mut *conn, &serializer::JSON, &hello, Some(&self.metrics))?;
        let ack = recv_msg(&mut *conn, &serializer::JSON, Some(&self.metrics))?;
        let caps = wire::WireCaps::of(&ack);
        let (backend, engines, shapes) = wire::check_ack(&ack)?;
        let chosen = wire::negotiate_codec(ours, &caps.codecs);
        let codec = serializer::codec_by_id(chosen)
            .ok_or_else(|| Error::net(format!("negotiated unknown codec id {chosen}")))?;
        let link = if caps.mux {
            let (mut rd, wr) = conn.split()?;
            rd.set_read_timeout(Some(READER_POLL))
                .map_err(|e| Error::net(format!("cannot set reader poll timeout: {e}")))?;
            let link = Arc::new(Link {
                codec,
                dead: AtomicBool::new(false),
                mode: LinkMode::Mux(MuxIo {
                    writer: Mutex::new(Some(wr)),
                    pending: Mutex::new(HashMap::new()),
                    slot_freed: Condvar::new(),
                    next_id: AtomicU64::new(0),
                }),
            });
            let reader_link = link.clone();
            let reader_metrics = self.metrics.clone();
            std::thread::Builder::new()
                .name("ttc-mux-read".to_string())
                .spawn(move || reader_loop(rd, reader_link, reader_metrics))
                .map_err(|e| Error::internal(format!("cannot spawn mux reader: {e}")))?;
            link
        } else {
            Arc::new(Link {
                codec,
                dead: AtomicBool::new(false),
                mode: LinkMode::Serial(Mutex::new(conn)),
            })
        };
        st.link = Some(link);
        st.ack = Some(AckInfo {
            backend,
            engines,
            shapes,
        });
        Ok(())
    }

    fn try_once(&self, link: &Link, req: &mut Value) -> Result<Value> {
        match &link.mode {
            LinkMode::Serial(conn) => {
                let mut conn = conn.lock().unwrap();
                send_msg(&mut **conn, link.codec, req, Some(&self.metrics))?;
                let resp = recv_msg(&mut **conn, link.codec, Some(&self.metrics))?;
                wire::unwrap_response(resp)
            }
            LinkMode::Mux(io) => {
                let id = io.next_id.fetch_add(1, Ordering::Relaxed) + 1;
                req.set("id", id);
                let (tx, rx) = mpsc::channel();
                {
                    let mut pending = io.pending.lock().unwrap();
                    // backpressure: bound the in-flight set so a slow
                    // server can't absorb unbounded queued work
                    let bound = self.cfg.max_inflight.max(1);
                    if pending.len() >= bound {
                        self.metrics.mux_backpressure_waits.inc();
                        while pending.len() >= bound && !link.dead.load(Ordering::Relaxed) {
                            pending = io.slot_freed.wait(pending).unwrap();
                        }
                    }
                    if link.dead.load(Ordering::Relaxed) {
                        return Err(Error::net_transient("connection closed"));
                    }
                    pending.insert(id, tx);
                    self.metrics.mux_inflight_peak.record_max(pending.len() as u64);
                }
                let sent = (|| -> Result<()> {
                    let payload = link.codec.encode(req)?;
                    let mut writer = io.writer.lock().unwrap();
                    let w = writer
                        .as_mut()
                        .ok_or_else(|| Error::net_transient("connection is closing"))?;
                    frame::write_frame(&mut **w, link.codec.codec_id(), &payload)?;
                    self.metrics.note_sent(link.codec, req, payload.len());
                    Ok(())
                })();
                if let Err(e) = sent {
                    io.pending.lock().unwrap().remove(&id);
                    io.slot_freed.notify_one();
                    return Err(e);
                }
                let timeout =
                    Duration::from_secs_f64((self.cfg.call_timeout_ms / 1e3).max(1e-3));
                match rx.recv_timeout(timeout) {
                    Ok(result) => result.and_then(wire::unwrap_response),
                    Err(_) => {
                        io.pending.lock().unwrap().remove(&id);
                        io.slot_freed.notify_one();
                        Err(Error::net_transient(format!(
                            "call timed out after {:.0}ms",
                            self.cfg.call_timeout_ms
                        )))
                    }
                }
            }
        }
    }

    /// One shared transport per `engine.remote_addrs` entry, with
    /// duplicate addresses collapsed onto one connection: the returned
    /// vector preserves the config order (slot `i` maps to entry
    /// `i % len`, as the per-slot dialing did), but every entry naming
    /// the same host holds the same `Arc` — N pool slots on one host
    /// share one multiplexed socket.
    pub fn per_host(cfg: &crate::config::EngineConfig) -> Result<Vec<Arc<MuxTransport>>> {
        if cfg.remote_addrs.is_empty() {
            return Err(Error::Config(
                "backend 'remote' needs at least one address \
                 (engine.remote_addrs / --remote host:port[,host:port...])"
                    .into(),
            ));
        }
        let remote_cfg = RemoteConfig {
            call_timeout_ms: cfg.remote_timeout_ms,
            retries: cfg.remote_retries,
            wire_codec: cfg.wire_codec,
            max_inflight: cfg.mux_max_inflight,
            ..RemoteConfig::default()
        };
        let mut by_addr: HashMap<&str, Arc<MuxTransport>> = HashMap::new();
        let mut out = Vec::with_capacity(cfg.remote_addrs.len());
        for addr in &cfg.remote_addrs {
            let transport = by_addr
                .entry(addr.as_str())
                .or_insert_with(|| {
                    let connector = super::transport::TcpConnector::new(
                        addr.clone(),
                        Duration::from_secs_f64(
                            (remote_cfg.connect_timeout_ms / 1e3).max(1e-3),
                        ),
                    );
                    MuxTransport::new(
                        Box::new(connector),
                        remote_cfg.clone(),
                        NetMetrics::new(),
                    )
                })
                .clone();
            out.push(transport);
        }
        Ok(out)
    }

    /// Tear a link down (idempotent) and forget it if it is still the
    /// current one, so the next call redials.
    fn drop_link(&self, link: &Arc<Link>) {
        link.dead.store(true, Ordering::Relaxed);
        if let LinkMode::Mux(io) = &link.mode {
            if let Some(mut w) = io.writer.lock().unwrap().take() {
                w.shutdown();
            }
        }
        let mut st = self.state.lock().unwrap();
        if let Some(current) = &st.link {
            if Arc::ptr_eq(current, link) {
                st.link = None;
            }
        }
    }
}

/// The demux loop: route replies to waiters by correlation id until the
/// link dies, then fail every remaining waiter with a replica of the
/// fault (preserving transience, so failover semantics survive the
/// fan-out).
fn reader_loop(mut rd: Box<dyn ReadHalf>, link: Arc<Link>, metrics: Arc<NetMetrics>) {
    let LinkMode::Mux(io) = &link.mode else { return };
    let expect = link.codec.codec_id();
    let failure: Error = loop {
        if link.dead.load(Ordering::Relaxed) {
            break Error::net_transient("connection closed");
        }
        match frame::read_frame_poll(&mut *rd, expect) {
            Ok(None) => continue,
            Ok(Some(payload)) => match link.codec.decode(&payload) {
                Ok(reply) => {
                    metrics.note_received(link.codec, &reply, payload.len());
                    let Some(id) = reply.get("id").and_then(|v| v.as_usize()) else {
                        break Error::net("multiplexed reply is missing its correlation id");
                    };
                    let waiter = io.pending.lock().unwrap().remove(&(id as u64));
                    io.slot_freed.notify_one();
                    if let Some(tx) = waiter {
                        let _ = tx.send(Ok(reply));
                    }
                    // no waiter: the call timed out — drop the late reply
                }
                Err(e) => break e,
            },
            Err(e) => break e,
        }
    };
    link.dead.store(true, Ordering::Relaxed);
    // Close the write half so concurrent writers fail fast instead of
    // queueing frames into a dead socket.
    if let Some(mut w) = io.writer.lock().unwrap().take() {
        w.shutdown();
    }
    let waiters: Vec<_> = {
        let mut pending = io.pending.lock().unwrap();
        pending.drain().collect()
    };
    // wake every caller parked on the in-flight bound: the link is dead
    io.slot_freed.notify_all();
    for (_, tx) in waiters {
        let _ = tx.send(Err(failure.replicate()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, WireCodec};
    use crate::net::loopback::{AcceptMsg, LoopbackConnector};

    fn quick_cfg(codec: WireCodec) -> RemoteConfig {
        RemoteConfig {
            call_timeout_ms: 5_000.0,
            connect_timeout_ms: 1_000.0,
            retries: 1,
            backoff_ms: 0.0,
            wire_codec: codec,
            max_inflight: 256,
        }
    }

    /// Hand-rolled single-connection peer: handshakes (advertising the
    /// given caps), then reads `n` data frames and answers them in
    /// REVERSE order — exactly the out-of-order delivery the demux
    /// layer must handle.
    fn reversing_peer(
        rx: mpsc::Receiver<AcceptMsg>,
        server_caps: wire::WireCaps,
        n: usize,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let AcceptMsg::Conn(conn) = rx.recv().unwrap() else {
                return;
            };
            let mut conn: Box<dyn super::super::transport::Conn> = Box::new(conn);
            let hello_payload = frame::read_frame(&mut *conn, frame::CODEC_JSON).unwrap();
            let hello = serializer::JSON.decode(&hello_payload).unwrap();
            let client_caps = wire::WireCaps::of(&hello);
            assert!(client_caps.mux, "client must request multiplexing");
            let shapes =
                wire::shapes_to_value(&EngineShapes::sim_default(&EngineConfig::default()));
            let ack = server_caps.clone().stamp(wire::ack(
                frame::PROTOCOL_VERSION,
                wire::ProbeLayout::current(),
                "sim",
                1,
                shapes,
            ));
            let payload = serializer::JSON.encode(&ack).unwrap();
            frame::write_frame(&mut *conn, frame::CODEC_JSON, &payload).unwrap();
            let codec_id = wire::negotiate_codec(&client_caps.codecs, &server_caps.codecs);
            let codec = serializer::codec_by_id(codec_id).unwrap();
            let mut reqs = Vec::new();
            for _ in 0..n {
                let p = frame::read_frame(&mut *conn, codec_id).unwrap();
                reqs.push(codec.decode(&p).unwrap());
            }
            reqs.reverse();
            for req in reqs {
                let mut reply = wire::ok_envelope(
                    Value::obj().with("echo", req.req_str("tag").unwrap()),
                );
                // serial clients send no correlation id; echo when present
                if let Some(id) = req.get("id").and_then(Value::as_usize) {
                    reply = reply.with("id", id);
                }
                let p = codec.encode(&reply).unwrap();
                frame::write_frame(&mut *conn, codec_id, &p).unwrap();
            }
            // hold the connection open until the client hangs up
            let _ = frame::read_frame(&mut *conn, codec_id);
        })
    }

    #[test]
    fn demuxes_out_of_order_replies_and_tracks_inflight_peak() {
        let (tx, rx) = mpsc::channel();
        let _peer = reversing_peer(
            rx,
            wire::WireCaps {
                codecs: vec![1, 2],
                mux: true,
            },
            2,
        );
        let connector = LoopbackConnector::new(tx, "loopback://mux-test");
        let t = MuxTransport::new(
            Box::new(connector),
            quick_cfg(WireCodec::Binary),
            NetMetrics::new(),
        );
        let ack = t.ensure().unwrap();
        assert_eq!(ack.backend, "sim");
        assert_eq!(t.wire_status(), ("ttcb", true));

        let t2 = t.clone();
        let other = std::thread::spawn(move || {
            t2.call(Value::obj().with("op", "x").with("tag", "b")).unwrap()
        });
        let mine = t
            .call(Value::obj().with("op", "x").with("tag", "a"))
            .unwrap();
        let theirs = other.join().unwrap();
        // replies arrived in reverse order, yet each call got its own
        assert_eq!(mine.req_str("echo").unwrap(), "a");
        assert_eq!(theirs.req_str("echo").unwrap(), "b");
        assert_eq!(t.metrics().mux_inflight_peak.get(), 2);
        assert!(
            t.metrics().bytes_saved_vs_json.get() > 0,
            "binary codec must beat JSON on these envelopes"
        );
    }

    #[test]
    fn bounds_inflight_and_counts_backpressure_waits() {
        let (tx, rx) = mpsc::channel();
        let (got_first_tx, got_first_rx) = mpsc::channel();
        let (go_tx, go_rx) = mpsc::channel::<()>();
        // Echo peer that answers one frame at a time, holding the FIRST
        // reply until released — so the connection sits at 1 in-flight
        // call for as long as the test wants.
        let peer = std::thread::spawn(move || {
            let AcceptMsg::Conn(conn) = rx.recv().unwrap() else {
                return;
            };
            let mut conn: Box<dyn super::super::transport::Conn> = Box::new(conn);
            let hello_payload = frame::read_frame(&mut *conn, frame::CODEC_JSON).unwrap();
            let hello = serializer::JSON.decode(&hello_payload).unwrap();
            let client_caps = wire::WireCaps::of(&hello);
            let shapes =
                wire::shapes_to_value(&EngineShapes::sim_default(&EngineConfig::default()));
            let server_caps = wire::WireCaps {
                codecs: vec![1],
                mux: true,
            };
            let ack = server_caps.clone().stamp(wire::ack(
                frame::PROTOCOL_VERSION,
                wire::ProbeLayout::current(),
                "sim",
                1,
                shapes,
            ));
            let payload = serializer::JSON.encode(&ack).unwrap();
            frame::write_frame(&mut *conn, frame::CODEC_JSON, &payload).unwrap();
            let codec_id = wire::negotiate_codec(&client_caps.codecs, &server_caps.codecs);
            let codec = serializer::codec_by_id(codec_id).unwrap();
            for i in 0..2 {
                let p = frame::read_frame(&mut *conn, codec_id).unwrap();
                let req = codec.decode(&p).unwrap();
                if i == 0 {
                    got_first_tx.send(()).unwrap();
                    go_rx.recv().unwrap();
                }
                let reply = wire::ok_envelope(
                    Value::obj().with("echo", req.req_str("tag").unwrap()),
                )
                .with("id", req.req_usize("id").unwrap());
                let p = codec.encode(&reply).unwrap();
                frame::write_frame(&mut *conn, codec_id, &p).unwrap();
            }
            let _ = frame::read_frame(&mut *conn, codec_id);
        });
        let connector = LoopbackConnector::new(tx, "loopback://mux-bound");
        let mut cfg = quick_cfg(WireCodec::Json);
        cfg.max_inflight = 1;
        let t = MuxTransport::new(Box::new(connector), cfg, NetMetrics::new());
        t.ensure().unwrap();
        let t1 = t.clone();
        let first = std::thread::spawn(move || {
            t1.call(Value::obj().with("op", "x").with("tag", "a")).unwrap()
        });
        got_first_rx.recv().unwrap(); // "a" is on the wire, unanswered
        let frames_before = t.metrics().frames_sent.get();
        let t2 = t.clone();
        let second = std::thread::spawn(move || {
            t2.call(Value::obj().with("op", "x").with("tag", "b")).unwrap()
        });
        // the second call must park on the bound *before* writing its
        // frame; the wait is counted as soon as it parks
        while t.metrics().mux_backpressure_waits.get() == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(
            t.metrics().frames_sent.get(),
            frames_before,
            "bounded call must not reach the wire while at the bound"
        );
        go_tx.send(()).unwrap(); // release reply "a" → frees the slot
        assert_eq!(first.join().unwrap().req_str("echo").unwrap(), "a");
        assert_eq!(second.join().unwrap().req_str("echo").unwrap(), "b");
        assert_eq!(
            t.metrics().mux_inflight_peak.get(),
            1,
            "the bound must hold the in-flight set at 1"
        );
        assert!(t.metrics().mux_backpressure_waits.get() >= 1);
        peer.join().unwrap();
    }

    #[test]
    fn json_only_peer_negotiates_down_to_serial_json() {
        let (tx, rx) = mpsc::channel();
        let _peer = reversing_peer(
            rx,
            wire::WireCaps {
                codecs: vec![1],
                mux: false,
            },
            1,
        );
        let connector = LoopbackConnector::new(tx, "loopback://mux-test");
        let t = MuxTransport::new(
            Box::new(connector),
            quick_cfg(WireCodec::Binary),
            NetMetrics::new(),
        );
        t.ensure().unwrap();
        assert_eq!(t.wire_status(), ("json", false));
        // serial path still answers calls (the peer echoes after reading
        // one frame; with n == 1 "reverse" order is just order)
        let got = t
            .call(Value::obj().with("op", "x").with("tag", "solo"))
            .unwrap();
        assert_eq!(got.req_str("echo").unwrap(), "solo");
        assert_eq!(t.metrics().bytes_saved_vs_json.get(), 0);
    }
}
