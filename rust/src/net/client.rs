//! [`RemoteBackend`]: the existing [`Backend`] trait over a framed
//! connection to a `ttc engine-serve` fleet.
//!
//! A `RemoteBackend` is a thin request-builder over a shared
//! [`super::mux::MuxTransport`]: the transport owns the connection,
//! the hello/ack codec + mux negotiation and the retry loop, and N
//! engine slots pointed at the same host share one multiplexed socket
//! (see [`super::mux`]). Faults are handled in two tiers:
//!
//! * **in the transport** — transient faults (refused dials, dropped
//!   connections, timeouts) get bounded retry-with-backoff against the
//!   same endpoint, reconnecting each time;
//! * **above** — when retries are exhausted the call fails with a
//!   *transient* [`crate::error::Error::Net`], which the pool's
//!   failover path treats as "shard dead": the engine slot is excluded
//!   from placement and in-flight work is re-placed on live shards.
//!
//! Wire calls are stateless (all request state travels in the frame),
//! so retrying — on this shard or another — is always safe.

use std::sync::Arc;

use crate::config::WireCodec;
use crate::engine::batcher::BatchPlan;
use crate::engine::protocol::{EmbedKind, ProbeTrainReport};
use crate::engine::{Backend, BackendFactory, EngineShapes};
use crate::error::{Error, Result};
use crate::util::clock::SharedClock;
use crate::util::json::Value;

use super::mux::MuxTransport;
use super::transport::{Connector, NetMetrics};
use super::wire;

/// Client-side fault-handling knobs.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// Blocking-read timeout per call (wall-clock ms). Also bounds how
    /// long a kill-race can strand a caller whose connect won the race
    /// against a dying server.
    pub call_timeout_ms: f64,
    /// Dial timeout (wall-clock ms).
    pub connect_timeout_ms: f64,
    /// Transient-fault retries per call (beyond the first attempt).
    pub retries: usize,
    /// Initial backoff between retries (doubles per retry).
    pub backoff_ms: f64,
    /// Preferred data-plane codec; the handshake negotiates down to
    /// JSON when the peer doesn't speak it.
    pub wire_codec: WireCodec,
    /// Bound on concurrently in-flight calls per multiplexed
    /// connection (`engine.mux_max_inflight`). Submitters past the
    /// bound block until a reply frees a slot; the waits are counted in
    /// [`NetMetrics`]`.mux_backpressure_waits`.
    pub max_inflight: usize,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            call_timeout_ms: 30_000.0,
            connect_timeout_ms: 5_000.0,
            retries: 2,
            backoff_ms: 10.0,
            wire_codec: WireCodec::Json,
            max_inflight: 256,
        }
    }
}

/// A [`Backend`] whose bucket-shaped calls execute on a remote fleet.
pub struct RemoteBackend {
    transport: Arc<MuxTransport>,
    clock: SharedClock,
    shapes: EngineShapes,
    remote_backend: String,
    remote_engines: usize,
    metrics: Arc<NetMetrics>,
    /// Absolute engine-clock deadline for the next generate (see
    /// [`Backend::deadline_hint`]); reset after each call.
    next_deadline_ms: f64,
}

impl RemoteBackend {
    /// Dial and handshake eagerly over a private transport, so a bad
    /// address, version skew or probe-layout mismatch fails engine
    /// startup with a clear error instead of poisoning the first
    /// request.
    pub fn connect(
        connector: Box<dyn Connector>,
        cfg: RemoteConfig,
        clock: SharedClock,
        metrics: Arc<NetMetrics>,
    ) -> Result<RemoteBackend> {
        Self::over(MuxTransport::new(connector, cfg, metrics), clock)
    }

    /// Build a backend over an existing (possibly shared) transport.
    /// This is how N pool slots multiplex one socket: they all hold the
    /// same `Arc<MuxTransport>`.
    pub fn over(transport: Arc<MuxTransport>, clock: SharedClock) -> Result<RemoteBackend> {
        let ack = transport.ensure()?;
        let metrics = transport.metrics().clone();
        Ok(RemoteBackend {
            transport,
            clock,
            shapes: ack.shapes,
            remote_backend: ack.backend,
            remote_engines: ack.engines,
            metrics,
            next_deadline_ms: f64::INFINITY,
        })
    }

    /// A [`BackendFactory`] for [`crate::engine::EnginePool`] slots with
    /// a private connection per slot.
    pub fn factory(
        connector: impl Connector + 'static,
        cfg: RemoteConfig,
        clock: SharedClock,
        metrics: Arc<NetMetrics>,
    ) -> BackendFactory {
        Box::new(move || {
            RemoteBackend::connect(Box::new(connector), cfg, clock, metrics)
                .map(|b| Box::new(b) as Box<dyn Backend>)
        })
    }

    /// A [`BackendFactory`] over a shared transport: every slot built
    /// from the same `Arc` shares one multiplexed connection.
    pub fn mux_factory(transport: Arc<MuxTransport>, clock: SharedClock) -> BackendFactory {
        Box::new(move || {
            RemoteBackend::over(transport, clock).map(|b| Box::new(b) as Box<dyn Backend>)
        })
    }

    fn call(&mut self, req: Value) -> Result<Value> {
        self.transport.call(req)
    }

    /// Decode an array-of-token-rows response field, checking arity.
    fn expect_rows(v: &Value, key: &str, want: usize) -> Result<Vec<Vec<u32>>> {
        let rows = v
            .req_arr(key)?
            .iter()
            .map(|r| wire::tokens_from_value(r, key))
            .collect::<Result<Vec<_>>>()?;
        if rows.len() != want {
            return Err(Error::net(format!(
                "server returned {} {key}, expected {want}",
                rows.len()
            )));
        }
        Ok(rows)
    }
}

impl Backend for RemoteBackend {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn shapes(&self) -> &EngineShapes {
        &self.shapes
    }

    fn describe(&self) -> Value {
        let (codec, mux) = self.transport.wire_status();
        Value::obj()
            .with("backend", "remote")
            .with("addr", self.transport.addr())
            .with("remote_backend", self.remote_backend.as_str())
            .with("remote_engines", self.remote_engines)
            .with("wire_codec", codec)
            .with("mux", mux)
            .with("net", self.metrics.to_json())
    }

    fn deadline_hint(&mut self, deadline_ms: f64) {
        self.next_deadline_ms = deadline_ms;
    }

    fn generate(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
        let mut req = Value::obj()
            .with("op", "generate")
            .with("kind", plan.kind.as_str())
            .with("temperature", plan.temperature as f64)
            .with("bucket", plan.bucket)
            .with(
                "prompts",
                Value::Arr(prompts.iter().map(|p| wire::tokens_to_value(p)).collect()),
            );
        if let Some(cap) = plan.max_steps {
            req = req.with("max_steps", cap);
        }
        // Deadlines cross the wire *relative*: the server re-anchors to
        // its own clock (processes cannot share one — docs/remote.md).
        let deadline = std::mem::replace(&mut self.next_deadline_ms, f64::INFINITY);
        if deadline.is_finite() {
            let rel = (deadline - self.clock.now_ms()).max(0.0);
            req = req.with("deadline_rel_ms", rel);
        }
        let want = prompts.len();
        let resp = self.call(req)?;
        Self::expect_rows(&resp, "rows", want)
    }

    fn prm_score(&mut self, bucket: usize, prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
        let req = Value::obj()
            .with("op", "prm_score")
            .with("bucket", bucket)
            .with(
                "prefixes",
                Value::Arr(prefixes.iter().map(|p| wire::tokens_to_value(p)).collect()),
            );
        let resp = self.call(req)?;
        let scores = wire::f32s_from_value(resp.req("scores")?, "scores")?;
        if scores.len() != prefixes.len() {
            return Err(Error::net(format!(
                "server returned {} scores, expected {}",
                scores.len(),
                prefixes.len()
            )));
        }
        Ok(scores)
    }

    fn embed(&mut self, kind: EmbedKind, bucket: usize, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let req = Value::obj()
            .with("op", "embed")
            .with("kind", kind.as_str())
            .with("bucket", bucket)
            .with(
                "queries",
                Value::Arr(queries.iter().map(|q| wire::tokens_to_value(q)).collect()),
            );
        let resp = self.call(req)?;
        let vectors = resp
            .req_arr("vectors")?
            .iter()
            .map(|v| wire::f32s_from_value(v, "vectors"))
            .collect::<Result<Vec<_>>>()?;
        if vectors.len() != queries.len() {
            return Err(Error::net(format!(
                "server returned {} vectors, expected {}",
                vectors.len(),
                queries.len()
            )));
        }
        Ok(vectors)
    }

    fn probe_fwd(&mut self, feats: &[Vec<f32>]) -> Result<Vec<f32>> {
        let req = Value::obj().with("op", "probe_fwd").with(
            "feats",
            Value::Arr(feats.iter().map(|f| wire::f32s_to_value(f)).collect()),
        );
        let resp = self.call(req)?;
        wire::f32s_from_value(resp.req("logits")?, "logits")
    }

    fn probe_train(
        &mut self,
        train_feats: Vec<Vec<f32>>,
        train_labels: Vec<f32>,
        val_feats: Vec<Vec<f32>>,
        val_labels: Vec<f32>,
        epochs: usize,
        patience: usize,
    ) -> Result<ProbeTrainReport> {
        let rows = |rows: &[Vec<f32>]| {
            Value::Arr(rows.iter().map(|f| wire::f32s_to_value(f)).collect())
        };
        let req = Value::obj()
            .with("op", "probe_train")
            .with("train_feats", rows(&train_feats))
            .with("train_labels", wire::f32s_to_value(&train_labels))
            .with("val_feats", rows(&val_feats))
            .with("val_labels", wire::f32s_to_value(&val_labels))
            .with("epochs", epochs)
            .with("patience", patience);
        let resp = self.call(req)?;
        let curve = resp
            .req_arr("curve")?
            .iter()
            .map(|p| -> Result<(usize, f64, f64)> {
                let p = p
                    .as_arr()
                    .filter(|a| a.len() == 3)
                    .ok_or_else(|| Error::net("curve: expected [epoch, train, val] triples"))?;
                Ok((
                    p[0].as_usize()
                        .ok_or_else(|| Error::net("curve: bad epoch"))?,
                    p[1].as_f64().ok_or_else(|| Error::net("curve: bad loss"))?,
                    p[2].as_f64().ok_or_else(|| Error::net("curve: bad loss"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ProbeTrainReport {
            steps: resp.req_usize("steps")?,
            final_train_loss: resp.req_f64("final_train_loss")?,
            best_val_loss: resp.req_f64("best_val_loss")?,
            curve,
            params: wire::f32s_from_value(resp.req("params")?, "params")?,
        })
    }

    fn probe_load(&mut self, params: Vec<f32>) -> Result<()> {
        let req = Value::obj()
            .with("op", "probe_load")
            .with("params", wire::f32s_to_value(&params));
        self.call(req)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, Config};
    use crate::engine::protocol::GenKind;
    use crate::net::server::LoopbackEngineServer;

    fn sim_cfg(engines: usize) -> Config {
        let mut cfg = Config::default();
        cfg.engine.backend = BackendKind::Sim;
        cfg.engine.sim_clock = true;
        cfg.engine.engines = engines;
        cfg
    }

    fn quick_remote() -> RemoteConfig {
        RemoteConfig {
            call_timeout_ms: 5_000.0,
            connect_timeout_ms: 1_000.0,
            retries: 1,
            backoff_ms: 0.0,
            ..RemoteConfig::default()
        }
    }

    #[test]
    fn remote_generate_matches_local_sim_at_temp_zero() {
        use crate::engine::batcher::plan_batches;
        use crate::engine::protocol::GenJob;
        use crate::engine::{Backend, SimBackend};
        use crate::util::clock;

        let cfg = sim_cfg(1);
        let mut local = SimBackend::new(
            EngineShapes::sim_default(&cfg.engine),
            clock::sim_clock(),
            cfg.seed,
            0,
        );

        let (connector, _server) = LoopbackEngineServer::spawn(&cfg).unwrap();
        let mut remote = RemoteBackend::connect(
            Box::new(connector),
            quick_remote(),
            clock::sim_clock(),
            NetMetrics::new(),
        )
        .unwrap();

        let tok = crate::tokenizer::Tokenizer::new();
        let prompt = tok.encode("Q:7+5-2+8=?\n").unwrap();
        let jobs = vec![GenJob::new(prompt.clone(), GenKind::Full, 0.0)];
        let shapes = local.shapes().clone();
        let plans = plan_batches(
            &jobs,
            &shapes.batch_buckets,
            &shapes.chunk_lens,
            shapes.query_len,
        );
        assert_eq!(plans.len(), 1);
        let prompts: Vec<&[u32]> = vec![&prompt];
        let a = local.generate(&plans[0], &prompts).unwrap();
        let b = remote.generate(&plans[0], &prompts).unwrap();
        assert_eq!(a, b, "remote sim must replay the local sim exactly");
    }

    #[test]
    fn exhausted_retries_surface_as_transient_net() {
        let cfg = sim_cfg(1);
        let (connector, mut server) = LoopbackEngineServer::spawn(&cfg).unwrap();
        let mut remote = RemoteBackend::connect(
            Box::new(connector),
            RemoteConfig {
                call_timeout_ms: 200.0,
                ..quick_remote()
            },
            crate::util::clock::sim_clock(),
            NetMetrics::new(),
        )
        .unwrap();
        server.kill();
        let err = remote.prm_score(8, &[vec![1, 2, 3]]).unwrap_err();
        assert!(err.is_transient_net(), "dead shard must be transient: {err}");
        assert!(remote.metrics.retries.get() >= 1);
    }

    #[test]
    fn shared_transport_backends_report_mux_wire_status() {
        let mut cfg = sim_cfg(2);
        cfg.engine.wire_codec = WireCodec::Binary;
        let (connector, _server) = LoopbackEngineServer::spawn(&cfg).unwrap();
        let transport = MuxTransport::new(
            Box::new(connector),
            RemoteConfig {
                wire_codec: WireCodec::Binary,
                ..quick_remote()
            },
            NetMetrics::new(),
        );
        let a = RemoteBackend::over(transport.clone(), crate::util::clock::sim_clock()).unwrap();
        let b = RemoteBackend::over(transport, crate::util::clock::sim_clock()).unwrap();
        for backend in [&a, &b] {
            let d = backend.describe();
            assert_eq!(d.req_str("wire_codec").unwrap(), "ttcb");
            assert_eq!(d.req("mux").unwrap().as_bool(), Some(true));
        }
        // one shared socket: exactly one dial across both backends
        assert_eq!(a.metrics.reconnects.get(), 1);
    }
}
