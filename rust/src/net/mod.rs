//! The remote engine tier: versioned wire protocol, engine servers and
//! the client-side [`RemoteBackend`].
//!
//! Layering (each piece swappable independently):
//!
//! | layer | module | contents |
//! |---|---|---|
//! | framing | [`frame`] | `TTCW` magic, version stamp, length prefix |
//! | codec | [`serializer`] | [`serializer::Serializer`] trait, JSON first |
//! | transport | [`transport`], [`loopback`] | [`transport::Conn`]/[`transport::Connector`]: TCP and in-process pipes |
//! | schema | [`wire`] | handshake, shapes, request/response envelopes |
//! | server | [`server`] | accept loops fronting an [`crate::engine::EnginePool`] |
//! | client | [`client`] | [`RemoteBackend`] with retry/backoff |
//!
//! The loopback transport runs the full protocol (same bytes as TCP)
//! inside one process, which is how CI exercises every handshake,
//! failover and kill path deterministically with the sim backend. See
//! `docs/remote.md` for the frame format, version negotiation and the
//! clock model.

pub mod client;
pub mod frame;
pub mod loopback;
pub mod serializer;
pub mod server;
pub mod transport;
pub mod wire;

pub use client::{RemoteBackend, RemoteConfig};
pub use frame::{PROTOCOL_VERSION, MAX_FRAME_BYTES};
pub use loopback::LoopbackConnector;
pub use serializer::{JsonCodec, Serializer};
pub use server::{LoopbackEngineServer, TcpEngineServer};
pub use transport::{Conn, Connector, NetMetrics, TcpConnector};
pub use wire::ProbeLayout;
