//! The remote engine tier: versioned wire protocol, engine servers and
//! the client-side [`RemoteBackend`].
//!
//! Layering (each piece swappable independently):
//!
//! | layer | module | contents |
//! |---|---|---|
//! | framing | [`frame`] | `TTCW` magic, version stamp, codec id, length prefix |
//! | codec | [`serializer`] | [`serializer::Serializer`] trait: JSON (id 1) and the TTCB binary codec (id 2) |
//! | transport | [`transport`], [`loopback`] | [`transport::Conn`]/[`transport::Connector`]: TCP and in-process pipes, splittable into read/write halves |
//! | schema | [`wire`] | handshake (with codec/mux negotiation), shapes, request/response envelopes |
//! | mux | [`mux`] | [`MuxTransport`]: one shared connection per host, correlation-id demux, retry/backoff |
//! | server | [`server`] | accept loops fronting an [`crate::engine::EnginePool`], serial + mux request loops |
//! | client | [`client`] | [`RemoteBackend`] request builders over a (possibly shared) transport |
//!
//! The loopback transport runs the full protocol (same bytes as TCP)
//! inside one process, which is how CI exercises every handshake,
//! codec negotiation, failover and kill path deterministically with the
//! sim backend. See `docs/remote.md` for the frame format, the TTCB
//! byte grammar, codec negotiation and the clock model.

pub mod client;
pub mod frame;
pub mod loopback;
pub mod mux;
pub mod serializer;
pub mod server;
pub mod transport;
pub mod wire;

pub use client::{RemoteBackend, RemoteConfig};
pub use frame::{CODEC_JSON, CODEC_TTCB, MAX_FRAME_BYTES, PROTOCOL_VERSION};
pub use loopback::LoopbackConnector;
pub use mux::MuxTransport;
pub use serializer::{codec_by_id, supported_ids, JsonCodec, Serializer, TtcbCodec, JSON, TTCB};
pub use server::{LoopbackEngineServer, TcpEngineServer};
pub use transport::{Conn, Connector, NetMetrics, ReadHalf, TcpConnector, WriteHalf};
pub use wire::{ProbeLayout, WireCaps};
