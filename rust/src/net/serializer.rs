//! Payload codecs: the wire format is decoupled from the transport.
//!
//! A [`Serializer`] turns a [`Value`] message into payload bytes and
//! back. Two codecs ship:
//!
//! * [`JsonCodec`] (frame codec id 1) — the control-plane and fallback
//!   codec, over the hand-rolled parser in [`crate::util::json`].
//! * [`TtcbCodec`] (frame codec id 2) — "TTC Binary", a compact
//!   tag-length-value encoding for the data-plane envelopes. Strings are
//!   raw length-prefixed UTF-8 (no escaping), numbers are 8-byte IEEE-754
//!   (no float-to-text round-trips), and homogeneous numeric arrays —
//!   token blocks, score vectors, embeddings — collapse into typed runs
//!   (LEB128 varints for token ids, raw f64 words for scores).
//!
//! Which codec a connection uses is negotiated in the hello/ack
//! handshake (see [`super::wire`]): the client advertises the ids it
//! speaks, the server answers with its own, and both sides pick the
//! highest common id, falling back to JSON. The handshake itself is
//! always JSON-framed so peers that predate the binary codec
//! interoperate unchanged.
//!
//! ## TTCB payload grammar
//!
//! ```text
//! value   := tag(1 byte) body
//! 0x00    null
//! 0x01    false
//! 0x02    true
//! 0x03    number   f64, 8 bytes big-endian, finite
//! 0x04    string   varint byte-length, raw UTF-8 bytes
//! 0x05    array    varint count, then count values
//! 0x06    object   varint count, then count * (varint key-length,
//!                  raw key bytes, value)
//! 0x07    u32 run  varint count, then count varints (token blocks)
//! 0x08    f64 run  varint count, then count * 8 bytes big-endian
//! varint  := LEB128, at most 5 bytes, value < 2^32
//! ```
//!
//! Non-finite numbers encode as null, matching what the JSON codec's
//! `dumps` emits for them, so the two codecs agree on every envelope.
//! The decoder validates every count against the bytes actually
//! remaining *before* allocating, caps nesting depth, and rejects
//! trailing bytes — a truncated or hostile payload fails with a
//! non-transient [`Error::Net`], never a panic or an OOM.

use crate::config::WireCodec;
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Message codec: encode/decode one [`Value`] per frame payload.
pub trait Serializer: Send + Sync {
    /// Human-readable codec name.
    fn name(&self) -> &'static str;
    /// Codec id stamped into the frame header.
    fn codec_id(&self) -> u8;
    /// Encode a message into payload bytes.
    fn encode(&self, v: &Value) -> Result<Vec<u8>>;
    /// Decode payload bytes into a message. Must enforce resource
    /// limits (depth, size) — the payload may come from a hostile peer.
    fn decode(&self, bytes: &[u8]) -> Result<Value>;
}

/// Shared instance of the JSON codec (codec id 1).
pub static JSON: JsonCodec = JsonCodec;

/// Shared instance of the TTCB binary codec (codec id 2).
pub static TTCB: TtcbCodec = TtcbCodec;

/// Look up a codec by its frame id.
pub fn codec_by_id(id: u8) -> Option<&'static dyn Serializer> {
    match id {
        super::frame::CODEC_JSON => Some(&JSON),
        super::frame::CODEC_TTCB => Some(&TTCB),
        _ => None,
    }
}

/// The codec ids a peer configured with `wire_codec` advertises in the
/// handshake, lowest to highest preference.
pub fn supported_ids(codec: WireCodec) -> &'static [u8] {
    match codec {
        WireCodec::Json => &[super::frame::CODEC_JSON],
        WireCodec::Binary => &[super::frame::CODEC_JSON, super::frame::CODEC_TTCB],
    }
}

/// JSON codec over [`crate::util::json`]. The parser enforces a
/// nesting-depth cap and a payload byte cap, so a malformed frame
/// cannot exhaust server memory.
#[derive(Debug, Clone, Default)]
pub struct JsonCodec;

impl Serializer for JsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn codec_id(&self) -> u8 {
        super::frame::CODEC_JSON
    }

    fn encode(&self, v: &Value) -> Result<Vec<u8>> {
        Ok(v.dumps().into_bytes())
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| Error::net(format!("frame payload is not UTF-8: {e}")))?;
        json::parse_bounded(text, super::frame::MAX_FRAME_BYTES)
            .map_err(|e| Error::net(format!("frame payload is not valid JSON: {e}")))
    }
}

const TAG_NULL: u8 = 0x00;
const TAG_FALSE: u8 = 0x01;
const TAG_TRUE: u8 = 0x02;
const TAG_NUM: u8 = 0x03;
const TAG_STR: u8 = 0x04;
const TAG_ARR: u8 = 0x05;
const TAG_OBJ: u8 = 0x06;
const TAG_U32_RUN: u8 = 0x07;
const TAG_F64_RUN: u8 = 0x08;

/// Nesting cap for hostile payloads, matching the JSON parser's.
const TTCB_MAX_DEPTH: usize = 128;

/// TTC Binary codec (codec id 2). See the module docs for the grammar.
#[derive(Debug, Clone, Default)]
pub struct TtcbCodec;

impl Serializer for TtcbCodec {
    fn name(&self) -> &'static str {
        "ttcb"
    }

    fn codec_id(&self) -> u8 {
        super::frame::CODEC_TTCB
    }

    fn encode(&self, v: &Value) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(64);
        enc_value(&mut out, v);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let mut dec = Dec { bytes, pos: 0 };
        let v = dec.value(0)?;
        if dec.pos != bytes.len() {
            return Err(Error::net(format!(
                "ttcb: {} trailing bytes after the value",
                bytes.len() - dec.pos
            )));
        }
        Ok(v)
    }
}

/// True when a value fits the token-run element type (finite integer in
/// u32 range).
fn is_u32(v: &Value) -> bool {
    matches!(v, Value::Num(n) if n.is_finite() && n.fract() == 0.0 && *n >= 0.0 && *n <= u32::MAX as f64)
}

fn is_finite_num(v: &Value) -> bool {
    matches!(v, Value::Num(n) if n.is_finite())
}

fn enc_varint(out: &mut Vec<u8>, mut n: u32) {
    loop {
        let byte = (n & 0x7f) as u8;
        n >>= 7;
        if n == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn enc_value(out: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Null => out.push(TAG_NULL),
        Value::Bool(false) => out.push(TAG_FALSE),
        Value::Bool(true) => out.push(TAG_TRUE),
        Value::Num(n) => {
            if n.is_finite() {
                out.push(TAG_NUM);
                out.extend_from_slice(&n.to_be_bytes());
            } else {
                // JSON parity: dumps() writes null for NaN/Inf
                out.push(TAG_NULL);
            }
        }
        Value::Str(s) => {
            out.push(TAG_STR);
            enc_varint(out, s.len() as u32);
            out.extend_from_slice(s.as_bytes());
        }
        Value::Arr(items) => {
            if !items.is_empty() && items.iter().all(is_u32) {
                // token block: varint run, 1-2 bytes per typical token id
                out.push(TAG_U32_RUN);
                enc_varint(out, items.len() as u32);
                for item in items {
                    if let Value::Num(n) = item {
                        enc_varint(out, *n as u32);
                    }
                }
            } else if !items.is_empty() && items.iter().all(is_finite_num) {
                // score/embedding vector: raw f64 words
                out.push(TAG_F64_RUN);
                enc_varint(out, items.len() as u32);
                for item in items {
                    if let Value::Num(n) = item {
                        out.extend_from_slice(&n.to_be_bytes());
                    }
                }
            } else {
                out.push(TAG_ARR);
                enc_varint(out, items.len() as u32);
                for item in items {
                    enc_value(out, item);
                }
            }
        }
        Value::Obj(fields) => {
            out.push(TAG_OBJ);
            enc_varint(out, fields.len() as u32);
            for (k, v) in fields {
                enc_varint(out, k.len() as u32);
                out.extend_from_slice(k.as_bytes());
                enc_value(out, v);
            }
        }
    }
}

/// Bounds-checked TTCB decoder.
struct Dec<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn fail(&self, msg: &str) -> Error {
        Error::net(format!("ttcb: {msg} at byte {}", self.pos))
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn byte(&mut self) -> Result<u8> {
        let b = *self
            .bytes
            .get(self.pos)
            .ok_or_else(|| self.fail("truncated"))?;
        self.pos += 1;
        Ok(b)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(self.fail(&format!("{n} bytes announced, {} remain", self.remaining())));
        }
        let slice = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    fn varint(&mut self) -> Result<u32> {
        let mut value: u32 = 0;
        for shift in [0u32, 7, 14, 21, 28] {
            let byte = self.byte()?;
            if shift == 28 && byte > 0x0f {
                return Err(self.fail("varint overflows u32"));
            }
            value |= u32::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(value);
            }
        }
        Err(self.fail("varint longer than 5 bytes"))
    }

    fn f64(&mut self) -> Result<f64> {
        let raw = self.take(8)?;
        let mut word = [0u8; 8];
        word.copy_from_slice(raw);
        let n = f64::from_be_bytes(word);
        if !n.is_finite() {
            return Err(self.fail("non-finite number"));
        }
        Ok(n)
    }

    fn str_of(&mut self, len: usize) -> Result<String> {
        let raw = self.take(len)?;
        std::str::from_utf8(raw)
            .map(|s| s.to_string())
            .map_err(|e| self.fail(&format!("invalid UTF-8: {e}")))
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth >= TTCB_MAX_DEPTH {
            return Err(self.fail("nesting too deep"));
        }
        match self.byte()? {
            TAG_NULL => Ok(Value::Null),
            TAG_FALSE => Ok(Value::Bool(false)),
            TAG_TRUE => Ok(Value::Bool(true)),
            TAG_NUM => Ok(Value::Num(self.f64()?)),
            TAG_STR => {
                let len = self.varint()? as usize;
                Ok(Value::Str(self.str_of(len)?))
            }
            TAG_ARR => {
                let count = self.varint()? as usize;
                // every element is at least one tag byte
                if count > self.remaining() {
                    return Err(self.fail(&format!(
                        "array announces {count} elements, {} bytes remain",
                        self.remaining()
                    )));
                }
                let mut items = Vec::with_capacity(count);
                for _ in 0..count {
                    items.push(self.value(depth + 1)?);
                }
                Ok(Value::Arr(items))
            }
            TAG_OBJ => {
                let count = self.varint()? as usize;
                if count > self.remaining() {
                    return Err(self.fail(&format!(
                        "object announces {count} fields, {} bytes remain",
                        self.remaining()
                    )));
                }
                let mut fields = Vec::with_capacity(count);
                for _ in 0..count {
                    let klen = self.varint()? as usize;
                    let key = self.str_of(klen)?;
                    let v = self.value(depth + 1)?;
                    fields.push((key, v));
                }
                Ok(Value::Obj(fields))
            }
            TAG_U32_RUN => {
                let count = self.varint()? as usize;
                // every varint is at least one byte
                if count > self.remaining() {
                    return Err(self.fail(&format!(
                        "token run announces {count} entries, {} bytes remain",
                        self.remaining()
                    )));
                }
                let mut items = Vec::with_capacity(count);
                for _ in 0..count {
                    items.push(Value::Num(self.varint()? as f64));
                }
                Ok(Value::Arr(items))
            }
            TAG_F64_RUN => {
                let count = self.varint()? as usize;
                match count.checked_mul(8) {
                    Some(need) if need <= self.remaining() => {}
                    _ => {
                        return Err(self.fail(&format!(
                            "f64 run announces {count} entries, {} bytes remain",
                            self.remaining()
                        )));
                    }
                }
                let mut items = Vec::with_capacity(count);
                for _ in 0..count {
                    items.push(Value::Num(self.f64()?));
                }
                Ok(Value::Arr(items))
            }
            tag => Err(self.fail(&format!("unknown tag 0x{tag:02x}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let codec = JsonCodec;
        let v = Value::obj()
            .with("op", "generate")
            .with("rows", 3usize)
            .with("temps", vec![0.0f64, 0.8]);
        let bytes = codec.encode(&v).unwrap();
        let back = codec.decode(&bytes).unwrap();
        assert_eq!(back.req_str("op").unwrap(), "generate");
        assert_eq!(back.req_usize("rows").unwrap(), 3);
    }

    #[test]
    fn decode_rejects_garbage_as_net_error() {
        let codec = JsonCodec;
        let err = codec.decode(b"{not json").unwrap_err();
        assert_eq!(err.kind_str(), "net");
        assert!(!err.is_transient_net());
        let err = codec.decode(&[0xff, 0xfe]).unwrap_err();
        assert!(err.to_string().contains("UTF-8"));
    }

    #[test]
    fn ttcb_golden_bytes() {
        // This exact layout is documented in docs/remote.md — keep the
        // two in sync.
        let v = Value::obj()
            .with("op", "generate")
            .with("tokens", vec![1.0f64, 2.0, 300.0]);
        let bytes = TtcbCodec.encode(&v).unwrap();
        assert_eq!(
            bytes,
            vec![
                0x06, 0x02, // object, 2 fields
                0x02, b'o', b'p', // key "op"
                0x04, 0x08, b'g', b'e', b'n', b'e', b'r', b'a', b't', b'e', // str "generate"
                0x06, b't', b'o', b'k', b'e', b'n', b's', // key "tokens"
                0x07, 0x03, // u32 run, 3 entries
                0x01, 0x02, 0xac, 0x02, // varints 1, 2, 300
            ]
        );
        assert_eq!(TtcbCodec.decode(&bytes).unwrap(), v);
        // the empty object is two bytes
        assert_eq!(TtcbCodec.encode(&Value::obj()).unwrap(), vec![0x06, 0x00]);
    }

    #[test]
    fn ttcb_registry_and_ids() {
        assert_eq!(codec_by_id(1).unwrap().name(), "json");
        assert_eq!(codec_by_id(2).unwrap().name(), "ttcb");
        assert!(codec_by_id(3).is_none());
        assert_eq!(supported_ids(WireCodec::Json), &[1]);
        assert_eq!(supported_ids(WireCodec::Binary), &[1, 2]);
    }

    #[test]
    fn non_finite_numbers_agree_with_json() {
        let v = Value::obj().with("x", f64::NAN).with("y", f64::INFINITY);
        let via_json = JSON.decode(&JSON.encode(&v).unwrap()).unwrap();
        let via_ttcb = TTCB.decode(&TTCB.encode(&v).unwrap()).unwrap();
        assert_eq!(via_json, via_ttcb);
        assert_eq!(via_ttcb.get("x"), Some(&Value::Null));
    }

    #[test]
    fn ttcb_rejects_hostile_payloads() {
        // announced size far beyond the buffer must fail before allocating
        for bytes in [
            &[TAG_STR, 0xff, 0xff, 0xff, 0xff, 0x0f][..], // 4 GiB string
            &[TAG_ARR, 0xff, 0xff, 0xff, 0xff, 0x0f][..], // 4 G elements
            &[TAG_F64_RUN, 0xff, 0xff, 0xff, 0xff, 0x0f][..],
            &[TAG_U32_RUN, 0x05, 0x01][..],               // run cut short
            &[TAG_NUM, 0x00][..],                         // truncated f64
            &[0x4f][..],                                  // unknown tag
            &[][..],                                      // empty payload
            &[TAG_NULL, TAG_NULL][..],                    // trailing bytes
            &[TAG_STR, 0x02, 0xff, 0xfe][..],             // invalid UTF-8
        ] {
            let err = TtcbCodec.decode(bytes).unwrap_err();
            assert_eq!(err.kind_str(), "net", "{bytes:?}");
            assert!(!err.is_transient_net(), "{bytes:?}: {err}");
        }
        // unbounded nesting must hit the depth cap, not the stack
        let mut deep = vec![0u8; 0];
        for _ in 0..4096 {
            deep.extend_from_slice(&[TAG_ARR, 0x01]);
        }
        deep.push(TAG_NULL);
        assert!(TtcbCodec.decode(&deep).is_err());
    }

    /// Random wire-envelope-shaped value: the op/ok envelopes the data
    /// plane actually sends, with token blocks, score vectors and
    /// escape-heavy prompt strings, plus arbitrary nested extras.
    fn gen_envelope(rng: &mut crate::util::rng::Rng) -> Value {
        fn gen_str(rng: &mut crate::util::rng::Rng) -> String {
            (0..rng.below(16))
                .map(|_| match rng.below(8) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => 'é',
                    4 => '😀',
                    _ => (b'a' + rng.below(26) as u8) as char,
                })
                .collect()
        }
        fn gen_tokens(rng: &mut crate::util::rng::Rng) -> Value {
            Value::Arr(
                (0..rng.below(24))
                    .map(|_| Value::Num(rng.below(50_000) as f64))
                    .collect(),
            )
        }
        fn gen_scores(rng: &mut crate::util::rng::Rng) -> Value {
            Value::Arr(
                (0..rng.below(8))
                    .map(|_| Value::Num(rng.range(-1000, 1000) as f64 / 256.0))
                    .collect(),
            )
        }
        match rng.below(4) {
            0 => Value::obj()
                .with("op", "generate")
                .with("kind", "sample")
                .with("temperature", rng.below(100) as f64 / 100.0)
                .with("bucket", rng.below(4096) as f64)
                .with(
                    "prompts",
                    Value::Arr((0..1 + rng.below(4)).map(|_| gen_tokens(rng)).collect()),
                )
                .with("id", rng.below(1_000_000) as f64),
            1 => Value::obj().with(
                "ok",
                Value::obj()
                    .with(
                        "rows",
                        Value::Arr((0..1 + rng.below(4)).map(|_| gen_tokens(rng)).collect()),
                    )
                    .with("scores", gen_scores(rng)),
            ),
            2 => Value::obj()
                .with("op", "prm_score")
                .with("bucket", rng.below(4096) as f64)
                .with(
                    "prefixes",
                    Value::Arr((0..1 + rng.below(4)).map(|_| Value::Str(gen_str(rng))).collect()),
                ),
            _ => Value::obj().with(
                "err",
                Value::obj()
                    .with("kind", "engine")
                    .with("message", gen_str(rng)),
            ),
        }
    }

    #[test]
    fn prop_envelopes_roundtrip_identically_through_both_codecs() {
        crate::testkit::forall(
            "cross-codec equivalence",
            300,
            |rng| gen_envelope(rng),
            |v| {
                let via_json = JSON
                    .decode(&JSON.encode(v).unwrap())
                    .map_err(|e| format!("json roundtrip failed: {e}"))?;
                let bytes = TTCB.encode(v).unwrap();
                let via_ttcb = TTCB
                    .decode(&bytes)
                    .map_err(|e| format!("ttcb roundtrip of {v:?} failed: {e}"))?;
                crate::testkit::prop_assert(
                    via_json == via_ttcb,
                    format!("codecs disagree: json {via_json:?} != ttcb {via_ttcb:?}"),
                )?;
                crate::testkit::prop_assert(
                    &via_ttcb == v,
                    format!("ttcb roundtrip changed the value: {v:?} -> {via_ttcb:?}"),
                )
            },
        );
    }

    #[test]
    fn prop_truncated_ttcb_always_errors() {
        crate::testkit::forall(
            "ttcb truncation",
            200,
            |rng| TTCB.encode(&gen_envelope(rng)).unwrap(),
            |bytes| {
                for cut in 0..bytes.len() {
                    crate::testkit::prop_assert(
                        TTCB.decode(&bytes[..cut]).is_err(),
                        format!("prefix of length {cut} of {bytes:02x?} decoded"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_mutated_ttcb_never_panics() {
        crate::testkit::forall(
            "ttcb mutation",
            300,
            |rng| {
                let bytes = TTCB.encode(&gen_envelope(rng)).unwrap();
                let pos = rng.below(bytes.len());
                (bytes, pos, rng.below(256) as u8)
            },
            |(bytes, pos, byte)| {
                let mut mutated = bytes.clone();
                mutated[*pos] ^= *byte;
                // decode must classify, never panic; a successful decode
                // must re-encode without panicking either
                if let Ok(v) = TTCB.decode(&mutated) {
                    let _ = TTCB.encode(&v).unwrap();
                }
                Ok(())
            },
        );
    }
}
