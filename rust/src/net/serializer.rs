//! Payload codecs: the wire format is decoupled from the transport.
//!
//! A [`Serializer`] turns a [`Value`] message into payload bytes and
//! back. JSON ships first (the crate already carries a hand-rolled
//! parser in [`crate::util::json`]); a binary codec can slot in later
//! by claiming a new codec id in [`super::frame`] without touching the
//! transport or the request schema.

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Message codec: encode/decode one [`Value`] per frame payload.
pub trait Serializer: Send {
    /// Human-readable codec name.
    fn name(&self) -> &'static str;
    /// Codec id stamped into the frame header.
    fn codec_id(&self) -> u8;
    /// Encode a message into payload bytes.
    fn encode(&self, v: &Value) -> Result<Vec<u8>>;
    /// Decode payload bytes into a message. Must enforce resource
    /// limits (depth, size) — the payload may come from a hostile peer.
    fn decode(&self, bytes: &[u8]) -> Result<Value>;
}

/// JSON codec over [`crate::util::json`]. The parser enforces a
/// nesting-depth cap and a payload byte cap, so a malformed frame
/// cannot exhaust server memory.
#[derive(Debug, Clone, Default)]
pub struct JsonCodec;

impl Serializer for JsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn codec_id(&self) -> u8 {
        super::frame::CODEC_JSON
    }

    fn encode(&self, v: &Value) -> Result<Vec<u8>> {
        Ok(v.dumps().into_bytes())
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| Error::net(format!("frame payload is not UTF-8: {e}")))?;
        json::parse_bounded(text, super::frame::MAX_FRAME_BYTES)
            .map_err(|e| Error::net(format!("frame payload is not valid JSON: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let codec = JsonCodec;
        let v = Value::obj()
            .with("op", "generate")
            .with("rows", 3usize)
            .with("temps", vec![0.0f64, 0.8]);
        let bytes = codec.encode(&v).unwrap();
        let back = codec.decode(&bytes).unwrap();
        assert_eq!(back.req_str("op").unwrap(), "generate");
        assert_eq!(back.req_usize("rows").unwrap(), 3);
    }

    #[test]
    fn decode_rejects_garbage_as_net_error() {
        let codec = JsonCodec;
        let err = codec.decode(b"{not json").unwrap_err();
        assert_eq!(err.kind_str(), "net");
        assert!(!err.is_transient_net());
        let err = codec.decode(&[0xff, 0xfe]).unwrap_err();
        assert!(err.to_string().contains("UTF-8"));
    }
}
