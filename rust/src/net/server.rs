//! The remote-engine server: an [`EnginePool`] fleet behind an accept
//! loop, answering framed requests.
//!
//! Blocking I/O, thread-per-connection — matching the codebase's
//! no-async style. Two front doors share one connection handler:
//!
//! * [`TcpEngineServer`] — real sockets, used by `ttc engine-serve`;
//! * [`LoopbackEngineServer`] — the in-process [`super::loopback`]
//!   transport, used by tests and benches (no network in CI).
//!
//! A connection speaks the JSON-framed handshake first (hello → ack
//! with shapes, layout stamps and capability keys), negotiating the
//! data-plane codec and whether the link multiplexes:
//!
//! * **serial** (old peers, or peers that didn't ask for mux): one
//!   request/response at a time, with a lazy-JSON fast path that
//!   answers control-plane ops (`info`, `metrics`) without
//!   materializing the request;
//! * **mux** : each frame carries a correlation `id`; every request
//!   runs on its own worker thread and replies are written id-tagged
//!   under a writer lock, so a slow `generate` never head-of-line
//!   blocks a quick `prm_score` sharing the socket.
//!
//! Engine-fleet shutdown mid-call is deliberately *not* reported
//! through the error envelope: the handler closes the connection
//! instead, so the client observes a transient EOF and fails over to
//! another shard.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::{Config, WireCodec};
use crate::engine::pool::PoolReporter;
use crate::engine::protocol::{EmbedKind, GenJob, GenKind};
use crate::engine::{EngineHandle, EnginePool};
use crate::error::{Error, Result};
use crate::util::clock::SharedClock;
use crate::util::json::lazy::LazyDoc;
use crate::util::json::Value;

use super::loopback::{AcceptMsg, LoopbackConnector};
use super::serializer::{self, Serializer};
use super::transport::{send_msg, Conn, TcpConn, WriteHalf};
use super::{frame, wire};

/// Immutable per-server context shared by every connection handler.
pub struct ServeCtx {
    /// Backend name advertised in the ack (`"sim"` / `"device"`).
    pub backend: String,
    /// Engine-fleet size advertised in the ack.
    pub engines: usize,
    /// Wire form of the fleet's [`crate::engine::EngineShapes`].
    pub shapes: Value,
    /// This build's probe layout stamp.
    pub layout: wire::ProbeLayout,
    /// Metrics view over the fleet, for the `metrics` op.
    pub reporter: PoolReporter,
    /// The fleet's clock: relative wire deadlines are anchored to it.
    pub clock: SharedClock,
    /// Richest codec this server is willing to speak on the data plane
    /// (`engine.wire_codec`); each connection negotiates down from it.
    pub wire_codec: WireCodec,
}

impl ServeCtx {
    fn from_pool(pool: &EnginePool, cfg: &Config) -> Result<ServeCtx> {
        // The engine's own info() carries the full shapes object (same
        // key names as the wire form), so the ack works for any backend.
        let info = pool.handle().info()?;
        let shapes = info.req("shapes")?.clone();
        Ok(ServeCtx {
            backend: cfg.engine.backend.as_str().to_string(),
            engines: pool.engines(),
            shapes,
            layout: wire::ProbeLayout::current(),
            reporter: pool.reporter(),
            clock: pool.clock.clone(),
            wire_codec: cfg.engine.wire_codec,
        })
    }
}

/// What one request produced, and what it means for the connection.
enum Outcome {
    /// Write the reply, keep serving.
    Reply(Value),
    /// Write the reply, then close (protocol violation).
    Fatal(Value),
    /// Close without replying: the fleet is down and the client should
    /// observe EOF and fail over.
    Close,
}

/// Serve one connection to completion: JSON-framed handshake with
/// codec/mux negotiation, then the negotiated request loop.
pub fn serve_conn(mut conn: Box<dyn Conn>, ctx: Arc<ServeCtx>, handle: EngineHandle) {
    let peer = conn.peer();
    // Handshake. A frame-level version mismatch surfaces here as a
    // non-transient error whose message names both versions — forward
    // it to the peer before closing. The hello is indexed lazily: the
    // accept loop touches only its top-level keys.
    let payload = match frame::read_frame(conn.as_mut(), frame::CODEC_JSON) {
        Ok(p) => p,
        Err(e) => {
            if !e.is_transient_net() {
                let _ = send_msg(conn.as_mut(), &serializer::JSON, &wire::err_envelope(&e), None);
                crate::log_warn!("engine-serve: {peer}: bad handshake: {e}");
            }
            return;
        }
    };
    let caps = match check_hello_payload(&payload) {
        Ok(caps) => caps,
        Err(e) => {
            let _ = send_msg(conn.as_mut(), &serializer::JSON, &wire::err_envelope(&e), None);
            crate::log_warn!("engine-serve: {peer}: rejected handshake: {e}");
            return;
        }
    };
    let ours = serializer::supported_ids(ctx.wire_codec);
    let ack = wire::WireCaps {
        codecs: ours.to_vec(),
        // The server always supports multiplexing; the link uses it iff
        // the client asked. Echoing the choice keeps negotiation
        // symmetric with no extra round-trip.
        mux: caps.mux,
    }
    .stamp(wire::ack(
        frame::PROTOCOL_VERSION,
        ctx.layout,
        &ctx.backend,
        ctx.engines,
        ctx.shapes.clone(),
    ));
    if send_msg(conn.as_mut(), &serializer::JSON, &ack, None).is_err() {
        return;
    }
    let codec_id = wire::negotiate_codec(ours, &caps.codecs);
    let Some(codec) = serializer::codec_by_id(codec_id) else {
        return; // unreachable: negotiation picks from our own list
    };
    if caps.mux {
        serve_mux(conn, codec, ctx, handle, peer);
    } else {
        serve_serial(conn, codec, ctx, handle, peer);
    }
}

/// Validate a raw hello payload without materializing it.
fn check_hello_payload(payload: &[u8]) -> Result<wire::WireCaps> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| Error::net("hello is not valid UTF-8"))?;
    let doc = LazyDoc::index(text)?;
    wire::check_hello_lazy(&doc)
}

/// One request/response at a time over the whole connection — the PR 6
/// semantics, kept for old peers and non-mux clients.
fn serve_serial(
    mut conn: Box<dyn Conn>,
    codec: &'static dyn Serializer,
    ctx: Arc<ServeCtx>,
    handle: EngineHandle,
    peer: String,
) {
    loop {
        let payload = match frame::read_frame(conn.as_mut(), codec.codec_id()) {
            Ok(p) => p,
            Err(e) => {
                if !e.is_transient_net() {
                    let _ = send_msg(conn.as_mut(), codec, &wire::err_envelope(&e), None);
                }
                return;
            }
        };
        match answer(&payload, codec, &ctx, &handle, &peer) {
            Outcome::Reply(reply) => {
                if send_msg(conn.as_mut(), codec, &reply, None).is_err() {
                    return;
                }
            }
            Outcome::Fatal(reply) => {
                let _ = send_msg(conn.as_mut(), codec, &reply, None);
                return;
            }
            Outcome::Close => return,
        }
    }
}

/// Correlation-id multiplexing: the reader keeps draining frames while
/// each request runs on its own worker; replies are written id-tagged
/// under the writer lock. Closing is one-way: once any worker takes the
/// writer (fleet down / protocol violation), later workers drop their
/// replies and the reader exits on the client's EOF.
fn serve_mux(
    conn: Box<dyn Conn>,
    codec: &'static dyn Serializer,
    ctx: Arc<ServeCtx>,
    handle: EngineHandle,
    peer: String,
) {
    let (mut rd, wr) = match conn.split() {
        Ok(halves) => halves,
        Err(e) => {
            crate::log_warn!("engine-serve: {peer}: cannot split connection: {e}");
            return;
        }
    };
    let writer: Arc<Mutex<Option<Box<dyn WriteHalf>>>> = Arc::new(Mutex::new(Some(wr)));
    loop {
        let payload = match frame::read_frame(&mut *rd, codec.codec_id()) {
            Ok(p) => p,
            Err(e) => {
                if !e.is_transient_net() {
                    if let Some(w) = writer.lock().unwrap().as_mut() {
                        let _ = send_msg(&mut **w, codec, &wire::err_envelope(&e), None);
                    }
                }
                return;
            }
        };
        let ctx = ctx.clone();
        let handle = handle.clone();
        let writer = writer.clone();
        let peer = peer.clone();
        let spawned = std::thread::Builder::new()
            .name("ttc-mux-op".to_string())
            .spawn(move || match answer(&payload, codec, &ctx, &handle, &peer) {
                Outcome::Reply(reply) => {
                    let mut w = writer.lock().unwrap();
                    if let Some(w) = w.as_mut() {
                        let _ = send_msg(&mut **w, codec, &reply, None);
                    }
                }
                Outcome::Fatal(reply) => {
                    if let Some(mut w) = writer.lock().unwrap().take() {
                        let _ = send_msg(&mut *w, codec, &reply, None);
                        w.shutdown();
                    }
                }
                Outcome::Close => {
                    crate::log_warn!("engine-serve: {peer}: fleet down mid-call, closing");
                    if let Some(mut w) = writer.lock().unwrap().take() {
                        w.shutdown();
                    }
                }
            });
        if spawned.is_err() {
            return;
        }
    }
}

/// Execute one raw request payload. Echoes the request's correlation
/// `id` (when present) into the reply so the client's demux layer can
/// route it.
fn answer(
    payload: &[u8],
    codec: &'static dyn Serializer,
    ctx: &ServeCtx,
    handle: &EngineHandle,
    peer: &str,
) -> Outcome {
    // Control-plane fast path: on a JSON link, `info` and `metrics`
    // need only the `op` (and `id`) keys — index the payload lazily
    // instead of materializing the whole document.
    if codec.codec_id() == frame::CODEC_JSON {
        if let Some(outcome) = lazy_control_answer(payload, ctx, handle) {
            return outcome;
        }
    }
    let req = match codec.decode(payload) {
        Ok(v) => v,
        Err(e) => return Outcome::Fatal(wire::err_envelope(&e)),
    };
    let id = req.get("id").and_then(Value::as_usize);
    match dispatch_op(&req, ctx, handle) {
        Ok(result) => Outcome::Reply(stamp_id(wire::ok_envelope(result), id)),
        Err(e) if is_engine_down(&e) => {
            // The fleet is shutting down: close instead of replying so
            // the client treats this shard as dead and reroutes.
            crate::log_warn!("engine-serve: {peer}: fleet down mid-call, closing");
            Outcome::Close
        }
        Err(e) => Outcome::Reply(stamp_id(wire::err_envelope(&e), id)),
    }
}

/// Answer `info`/`metrics` from a lazily indexed JSON payload, or
/// `None` when the op needs (or the payload defies) a full parse.
fn lazy_control_answer(payload: &[u8], ctx: &ServeCtx, handle: &EngineHandle) -> Option<Outcome> {
    let text = std::str::from_utf8(payload).ok()?;
    let doc = LazyDoc::index(text).ok()?;
    let result = match doc.str_of("op") {
        Some("info") => handle.info(),
        Some("metrics") => Ok(Value::obj().with("pool", ctx.reporter.report())),
        _ => return None,
    };
    let reply = match result {
        Ok(v) => wire::ok_envelope(v),
        // Fleet down: let the eager path re-discover it and close.
        Err(e) if is_engine_down(&e) => return None,
        Err(e) => wire::err_envelope(&e),
    };
    Some(Outcome::Reply(stamp_id(reply, doc.usize_of("id"))))
}

fn stamp_id(reply: Value, id: Option<usize>) -> Value {
    match id {
        Some(id) => reply.with("id", id),
        None => reply,
    }
}

/// True for errors that mean the engine fleet itself is gone (as
/// opposed to a request-level failure the client should see).
fn is_engine_down(e: &Error) -> bool {
    match e {
        Error::Engine(msg) => {
            msg.contains("is gone")
                || msg.contains("shut down")
                || msg.contains("down —")
                || msg.contains("dropped the reply")
        }
        _ => false,
    }
}

/// Execute one request against the fleet.
fn dispatch_op(req: &Value, ctx: &ServeCtx, handle: &EngineHandle) -> Result<Value> {
    let op = req.req_str("op")?;
    match op {
        "generate" => {
            let kind = GenKind::parse(req.req_str("kind")?)?;
            let temperature = req.req_f64("temperature")? as f32;
            let max_steps = req.get("max_steps").and_then(Value::as_usize);
            let rows = req.req_arr("prompts")?;
            let mut jobs = Vec::with_capacity(rows.len());
            for row in rows {
                let tokens = wire::tokens_from_value(row, "generate.prompts")?;
                let mut job = GenJob::new(tokens, kind, temperature);
                if let Some(cap) = max_steps {
                    job = job.with_max_new_tokens(cap);
                }
                jobs.push(job);
            }
            // Deadlines cross the wire relative (clocks differ across
            // processes) and are re-anchored to the server's clock.
            let deadline = req
                .get("deadline_rel_ms")
                .and_then(Value::as_f64)
                .map(|rel| ctx.clock.now_ms() + rel.max(0.0));
            let results = handle.generate_with_deadline(jobs, deadline)?;
            Ok(Value::obj().with(
                "rows",
                Value::Arr(
                    results
                        .iter()
                        .map(|r| wire::tokens_to_value(&r.tokens))
                        .collect(),
                ),
            ))
        }
        "prm_score" => {
            let prefixes = req
                .req_arr("prefixes")?
                .iter()
                .map(|p| wire::tokens_from_value(p, "prm_score.prefixes"))
                .collect::<Result<Vec<_>>>()?;
            let scores = handle.prm_score(prefixes)?;
            Ok(Value::obj().with("scores", wire::f32s_to_value(&scores)))
        }
        "embed" => {
            let kind = EmbedKind::parse(req.req_str("kind")?)?;
            let queries = req
                .req_arr("queries")?
                .iter()
                .map(|q| wire::tokens_from_value(q, "embed.queries"))
                .collect::<Result<Vec<_>>>()?;
            let vectors = handle.embed(kind, queries)?;
            Ok(Value::obj().with(
                "vectors",
                Value::Arr(vectors.iter().map(|v| wire::f32s_to_value(v)).collect()),
            ))
        }
        "probe_fwd" => {
            let feats = req
                .req_arr("feats")?
                .iter()
                .map(|f| wire::f32s_from_value(f, "probe_fwd.feats"))
                .collect::<Result<Vec<_>>>()?;
            let logits = handle.probe_fwd(feats)?;
            Ok(Value::obj().with("logits", wire::f32s_to_value(&logits)))
        }
        "probe_train" => {
            let rows = |key: &str| -> Result<Vec<Vec<f32>>> {
                req.req_arr(key)?
                    .iter()
                    .map(|f| wire::f32s_from_value(f, key))
                    .collect()
            };
            let report = handle.probe_train(
                rows("train_feats")?,
                wire::f32s_from_value(req.req("train_labels")?, "train_labels")?,
                rows("val_feats")?,
                wire::f32s_from_value(req.req("val_labels")?, "val_labels")?,
                req.req_usize("epochs")?,
                req.req_usize("patience")?,
            )?;
            Ok(Value::obj()
                .with("steps", report.steps)
                .with("final_train_loss", report.final_train_loss)
                .with("best_val_loss", report.best_val_loss)
                .with(
                    "curve",
                    Value::Arr(
                        report
                            .curve
                            .iter()
                            .map(|&(e, tl, vl)| {
                                Value::Arr(vec![
                                    Value::from(e),
                                    Value::from(tl),
                                    Value::from(vl),
                                ])
                            })
                            .collect(),
                    ),
                )
                .with("params", wire::f32s_to_value(&report.params)))
        }
        "probe_load" => {
            let params = wire::f32s_from_value(req.req("params")?, "probe_load.params")?;
            handle.probe_load(params)?;
            Ok(Value::obj())
        }
        "info" => handle.info(),
        "metrics" => Ok(Value::obj().with("pool", ctx.reporter.report())),
        other => Err(Error::net(format!(
            "unknown op '{other}' (this server speaks wire protocol v{})",
            frame::PROTOCOL_VERSION
        ))),
    }
}

/// A TCP-fronted engine fleet (`ttc engine-serve`).
pub struct TcpEngineServer {
    pool: Option<EnginePool>,
    accept: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    local_addr: std::net::SocketAddr,
}

impl TcpEngineServer {
    /// Start the fleet from `cfg` and listen on `addr`.
    pub fn bind(cfg: &Config, addr: &str) -> Result<TcpEngineServer> {
        let pool = EnginePool::start(cfg)?;
        let ctx = Arc::new(ServeCtx::from_pool(&pool, cfg)?);
        let handle = pool.handle();
        let listener = std::net::TcpListener::bind(addr)
            .map_err(|e| Error::net(format!("cannot listen on {addr}: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::net(format!("no local addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let accept = std::thread::Builder::new()
            .name("ttc-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let ctx = ctx.clone();
                    let handle = handle.clone();
                    // Connection handlers are detached: they exit on
                    // client EOF or fleet shutdown.
                    let _ = std::thread::Builder::new()
                        .name("ttc-conn".to_string())
                        .spawn(move || serve_conn(Box::new(TcpConn::new(stream)), ctx, handle));
                }
            })
            .map_err(|e| Error::internal(format!("cannot spawn accept thread: {e}")))?;
        Ok(TcpEngineServer {
            pool: Some(pool),
            accept: Some(accept),
            stop,
            local_addr,
        })
    }

    /// The bound address (useful when `addr` used port 0).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting, join the accept thread and shut the fleet down.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway dial.
        let _ = std::net::TcpStream::connect(self.local_addr);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        self.pool.take();
    }
}

impl Drop for TcpEngineServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// An in-process engine fleet reachable through [`LoopbackConnector`] —
/// the whole remote path minus real sockets, for deterministic tests.
pub struct LoopbackEngineServer {
    pool: Option<EnginePool>,
    accept: Option<JoinHandle<()>>,
    accept_tx: Sender<AcceptMsg>,
}

impl LoopbackEngineServer {
    /// Start a fleet from `cfg` (clock chosen by the config, as
    /// [`EnginePool::start`] does).
    pub fn spawn(cfg: &Config) -> Result<(LoopbackConnector, LoopbackEngineServer)> {
        let pool = EnginePool::start(cfg)?;
        Self::with_pool(cfg, pool)
    }

    /// Start a fleet sharing an explicit clock — the loopback-only
    /// virtual-timeline exception documented in `docs/remote.md`:
    /// client and server live in one process, so tests may hand both
    /// the same sim clock.
    pub fn spawn_with_clock(
        cfg: &Config,
        clock: SharedClock,
    ) -> Result<(LoopbackConnector, LoopbackEngineServer)> {
        let pool = EnginePool::start_with_clock(cfg, clock)?;
        Self::with_pool(cfg, pool)
    }

    fn with_pool(
        cfg: &Config,
        pool: EnginePool,
    ) -> Result<(LoopbackConnector, LoopbackEngineServer)> {
        let ctx = Arc::new(ServeCtx::from_pool(&pool, cfg)?);
        let handle = pool.handle();
        let (accept_tx, accept_rx) = channel::<AcceptMsg>();
        let accept = std::thread::Builder::new()
            .name("ttc-loopback-accept".to_string())
            .spawn(move || {
                while let Ok(AcceptMsg::Conn(conn)) = accept_rx.recv() {
                    let ctx = ctx.clone();
                    let handle = handle.clone();
                    let _ = std::thread::Builder::new()
                        .name("ttc-loopback-conn".to_string())
                        .spawn(move || serve_conn(Box::new(conn), ctx, handle));
                }
            })
            .map_err(|e| Error::internal(format!("cannot spawn accept thread: {e}")))?;
        let connector = LoopbackConnector::new(accept_tx.clone(), "loopback://engine-serve");
        Ok((
            connector,
            LoopbackEngineServer {
                pool: Some(pool),
                accept: Some(accept),
                accept_tx,
            },
        ))
    }

    /// Kill the server: stop accepting, join the acceptor and shut the
    /// engine fleet down. In-flight connections observe engine-down and
    /// close, which clients see as a transient EOF.
    pub fn kill(&mut self) {
        let _ = self.accept_tx.send(AcceptMsg::Stop);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        self.pool.take();
    }
}

impl Drop for LoopbackEngineServer {
    fn drop(&mut self) {
        self.kill();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::net::serializer::JsonCodec;
    use crate::net::transport::recv_msg;

    fn sim_cfg(engines: usize) -> Config {
        let mut cfg = Config::default();
        cfg.engine.backend = BackendKind::Sim;
        cfg.engine.sim_clock = true;
        cfg.engine.engines = engines;
        cfg
    }

    #[test]
    fn tcp_server_answers_a_handshake_and_info() {
        use super::super::transport::Connector;
        let mut server = TcpEngineServer::bind(&sim_cfg(1), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let connector = super::super::transport::TcpConnector::new(
            addr,
            std::time::Duration::from_secs(5),
        );
        let mut conn = connector.connect().unwrap();
        let codec = JsonCodec;
        let hello = wire::hello(super::super::frame::PROTOCOL_VERSION, wire::ProbeLayout::current());
        send_msg(conn.as_mut(), &codec, &hello, None).unwrap();
        let ack = recv_msg(conn.as_mut(), &codec, None).unwrap();
        let (backend, engines, shapes) = wire::check_ack(&ack).unwrap();
        assert_eq!(backend, "sim");
        assert_eq!(engines, 1);
        assert!(shapes.gen_max_new > 0);

        send_msg(conn.as_mut(), &codec, &Value::obj().with("op", "info"), None).unwrap();
        let info = wire::unwrap_response(recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap();
        assert_eq!(info.req_str("backend").unwrap(), "sim");
        server.stop();
    }

    #[test]
    fn unknown_op_is_a_net_error_but_keeps_the_connection() {
        use super::super::transport::Connector;
        let (connector, _server) = LoopbackEngineServer::spawn(&sim_cfg(1)).unwrap();
        let mut conn = connector.connect().unwrap();
        let codec = JsonCodec;
        let hello = wire::hello(super::super::frame::PROTOCOL_VERSION, wire::ProbeLayout::current());
        send_msg(conn.as_mut(), &codec, &hello, None).unwrap();
        wire::check_ack(&recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap();

        send_msg(conn.as_mut(), &codec, &Value::obj().with("op", "nope"), None).unwrap();
        let err =
            wire::unwrap_response(recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap_err();
        assert!(err.to_string().contains("unknown op"), "{err}");

        // The connection survives a request-level error.
        send_msg(conn.as_mut(), &codec, &Value::obj().with("op", "metrics"), None).unwrap();
        let m = wire::unwrap_response(recv_msg(conn.as_mut(), &codec, None).unwrap()).unwrap();
        assert!(m.req("pool").is_ok());
    }

    #[test]
    fn old_style_hello_gets_a_serial_json_connection_with_id_echo() {
        use super::super::transport::Connector;
        let (connector, _server) = LoopbackEngineServer::spawn(&sim_cfg(1)).unwrap();
        let mut conn = connector.connect().unwrap();
        let codec = JsonCodec;
        // a hello with NO capability keys — a PR 6-era client
        let hello = wire::hello(super::super::frame::PROTOCOL_VERSION, wire::ProbeLayout::current());
        send_msg(conn.as_mut(), &codec, &hello, None).unwrap();
        let ack = recv_msg(conn.as_mut(), &codec, None).unwrap();
        // the ack advertises the new capability keys additively
        let caps = wire::WireCaps::of(&ack);
        assert!(caps.codecs.contains(&super::super::frame::CODEC_JSON));
        assert!(!caps.mux, "mux must only engage when the client asks");
        // replies on a serial link echo a correlation id if one is sent
        let req = Value::obj().with("op", "metrics").with("id", 7usize);
        send_msg(conn.as_mut(), &codec, &req, None).unwrap();
        let reply = recv_msg(conn.as_mut(), &codec, None).unwrap();
        assert_eq!(reply.req_usize("id").unwrap(), 7);
        wire::unwrap_response(reply).unwrap();
    }
}
