//! PJRT runtime: loading and executing the AOT'd HLO artifacts.
//!
//! The published `xla` crate wraps xla_extension 0.5.1's PJRT C API. Key
//! constraints this module absorbs so the rest of the system doesn't see
//! them:
//!
//! * **HLO text interchange** — `HloModuleProto::from_text_file` parses
//!   the text emitted by `python/compile/aot.py` (serialized protos from
//!   jax ≥ 0.5 are rejected by this XLA version).
//! * **`Rc`-based handles** — `PjRtClient`/buffers are `!Send`; all PJRT
//!   state lives on the engine thread ([`crate::engine`]). Nothing in
//!   this module is `Send` and nothing needs to be.
//! * **Static shapes** — every entry point is compiled per batch bucket;
//!   [`ExecutableSet`] owns the bucket → executable map and type-checks
//!   call arguments against the signatures recorded in `hlo_index.json`.

pub mod artifacts;
pub mod literals;
pub mod weights;

pub use artifacts::{ArtifactIndex, ExecSignature, ExecutableSet, TensorSig};
pub use weights::WeightSet;
