//! Weight loading: the manifest + flat-f32 format written by
//! `python/compile/weights_io.py`.
//!
//! The manifest order IS the call convention: AOT'd executables take the
//! flattened tensor list as their leading arguments, in exactly this
//! order (jax tree-flatten order, fixed by sorted dict keys).

use crate::error::{Error, Result};
use crate::util::json::{parse, Value};
use std::path::Path;

/// One tensor's manifest entry.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in elements into the flat blob.
    pub offset: usize,
    pub size: usize,
}

/// A named set of weights ("lm", "prm", "probe") loaded from disk.
#[derive(Debug)]
pub struct WeightSet {
    pub name: String,
    pub entries: Vec<WeightEntry>,
    /// Raw f32 blob, little-endian order as written.
    pub blob: Vec<f32>,
    /// Model config recorded at save time (dims etc.).
    pub config: Value,
}

impl WeightSet {
    /// Load `<dir>/<name>_weights.bin` + `<dir>/<name>_manifest.json`.
    pub fn load(dir: &Path, name: &str) -> Result<WeightSet> {
        let man_path = dir.join(format!("{name}_manifest.json"));
        let bin_path = dir.join(format!("{name}_weights.bin"));
        let man_text = std::fs::read_to_string(&man_path).map_err(|e| {
            Error::artifact(format!(
                "missing weight manifest {} ({e}) — run `make artifacts`",
                man_path.display()
            ))
        })?;
        let man = parse(&man_text)?;
        let total = man.req_usize("total_elems")?;

        let bytes = std::fs::read(&bin_path).map_err(|e| {
            Error::artifact(format!("missing weights {} ({e})", bin_path.display()))
        })?;
        if bytes.len() != total * 4 {
            return Err(Error::artifact(format!(
                "{}: expected {} f32 elems, file has {} bytes",
                bin_path.display(),
                total,
                bytes.len()
            )));
        }
        let mut blob = Vec::with_capacity(total);
        for chunk in bytes.chunks_exact(4) {
            blob.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }

        let mut entries = Vec::new();
        let mut expected_offset = 0usize;
        for e in man.req_arr("params")? {
            let shape: Vec<usize> = e
                .req_arr("shape")?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::artifact("bad shape in manifest"))
                })
                .collect::<Result<_>>()?;
            let entry = WeightEntry {
                name: e.req_str("name")?.to_string(),
                offset: e.req_usize("offset")?,
                size: e.req_usize("size")?,
                shape,
            };
            if entry.offset != expected_offset {
                return Err(Error::artifact(format!(
                    "manifest {} tensor '{}' offset {} != running offset {}",
                    name, entry.name, entry.offset, expected_offset
                )));
            }
            let shape_elems: usize = entry.shape.iter().product::<usize>().max(1);
            if shape_elems != entry.size && !(entry.shape.is_empty() && entry.size == 1) {
                return Err(Error::artifact(format!(
                    "manifest {} tensor '{}' size {} != shape product {}",
                    name, entry.name, entry.size, shape_elems
                )));
            }
            expected_offset += entry.size;
            entries.push(entry);
        }
        if expected_offset != total {
            return Err(Error::artifact(format!(
                "manifest {name}: tensors cover {expected_offset} elems, blob has {total}"
            )));
        }

        let config = man.get("config").cloned().unwrap_or(Value::obj());
        Ok(WeightSet {
            name: name.to_string(),
            entries,
            blob,
            config,
        })
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Slice of one tensor's data.
    pub fn tensor_data(&self, idx: usize) -> &[f32] {
        let e = &self.entries[idx];
        &self.blob[e.offset..e.offset + e.size]
    }

    /// Materialize every tensor as an XLA literal, in manifest order.
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let data = self.tensor_data(i);
                if e.shape.is_empty() {
                    Ok(xla::Literal::scalar(data[0]))
                } else {
                    crate::runtime::literals::f32_tensor(data, &e.shape)
                }
            })
            .collect()
    }

    /// A zero-filled clone (used for Adam moment states of the probe).
    pub fn zeros_like(&self) -> WeightSet {
        WeightSet {
            name: format!("{}_zeros", self.name),
            entries: self.entries.clone(),
            blob: vec![0.0; self.blob.len()],
            config: self.config.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
            "params": [
                {"name": "a", "shape": [2, 2], "offset": 0, "size": 4},
                {"name": "b", "shape": [3], "offset": 4, "size": 3}
            ],
            "total_elems": 7,
            "config": {"d": 2}
        }"#;
        std::fs::write(dir.join("toy_manifest.json"), manifest).unwrap();
        let mut f = std::fs::File::create(dir.join("toy_weights.bin")).unwrap();
        for i in 0..7 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn loads_and_slices() {
        let dir = std::env::temp_dir().join(format!("ttc_w_{}", std::process::id()));
        write_fixture(&dir);
        let ws = WeightSet::load(&dir, "toy").unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.tensor_data(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ws.tensor_data(1), &[4.0, 5.0, 6.0]);
        assert_eq!(ws.config.req_usize("d").unwrap(), 2);
        let z = ws.zeros_like();
        assert!(z.blob.iter().all(|&x| x == 0.0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_bad_offsets() {
        let dir = std::env::temp_dir().join(format!("ttc_wb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("bad_manifest.json"),
            r#"{"params": [{"name": "a", "shape": [2], "offset": 1, "size": 2}],
                "total_elems": 3, "config": {}}"#,
        )
        .unwrap();
        std::fs::write(dir.join("bad_weights.bin"), [0u8; 12]).unwrap();
        assert!(WeightSet::load(&dir, "bad").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_files_are_artifact_errors() {
        let dir = std::env::temp_dir().join("ttc_missing_weights");
        let err = WeightSet::load(&dir, "nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
