//! Artifact index: `hlo_index.json` + lazy compilation of executables.
//!
//! `python/compile/aot.py` emits one HLO-text module per
//! (entry-point × batch-bucket × length-bucket) plus a JSON index with
//! every module's call signature. [`ExecutableSet`] loads the index,
//! compiles modules lazily on first use (startup stays fast for light
//! subcommands) and type-checks arguments before execution.
//!
//! Everything here is engine-thread-local (`Rc`-based PJRT handles).

use crate::error::{Error, Result};
use crate::util::json::{parse, Value};
use crate::log_debug;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

/// Signature of one tensor (dtype + shape) from the index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorSig {
    fn from_json(v: &Value) -> Result<TensorSig> {
        Ok(TensorSig {
            name: v.opt_str("name", "").to_string(),
            dtype: v.req_str("dtype")?.to_string(),
            shape: v
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| Error::artifact("bad shape dim")))
                .collect::<Result<_>>()?,
        })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Signature of one executable.
#[derive(Debug, Clone)]
pub struct ExecSignature {
    pub name: String,
    pub file: String,
    /// Which weight set is prepended to the args ("lm", "prm", "probe",
    /// "probe_train", or "" for none).
    pub weights: String,
    pub args: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Parsed `hlo_index.json`.
#[derive(Debug)]
pub struct ArtifactIndex {
    pub meta: Value,
    pub executables: Vec<ExecSignature>,
    pub dir: PathBuf,
}

impl ArtifactIndex {
    pub fn load(artifacts_dir: &PathBuf) -> Result<ArtifactIndex> {
        let path = artifacts_dir.join("hlo_index.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!(
                "missing {} ({e}) — run `make artifacts`",
                path.display()
            ))
        })?;
        let v = parse(&text)?;
        let meta = v.req("meta")?.clone();
        let mut executables = Vec::new();
        for e in v.req_arr("executables")? {
            executables.push(ExecSignature {
                name: e.req_str("name")?.to_string(),
                file: e.req_str("file")?.to_string(),
                weights: e.opt_str("weights", "").to_string(),
                args: e
                    .req_arr("args")?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<Result<_>>()?,
                outputs: e
                    .req_arr("outputs")?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<Result<_>>()?,
            });
        }
        Ok(ArtifactIndex {
            meta,
            executables,
            dir: artifacts_dir.clone(),
        })
    }

    pub fn find(&self, name: &str) -> Result<&ExecSignature> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                Error::artifact(format!(
                    "no executable '{name}' in hlo_index.json — re-run `make artifacts`?"
                ))
            })
    }

    /// The batch buckets recorded at AOT time.
    pub fn batch_buckets(&self) -> Result<Vec<usize>> {
        self.meta
            .req_arr("batch_buckets")?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| Error::artifact("bad bucket")))
            .collect()
    }

    /// The prefill length buckets recorded at AOT time.
    pub fn prefill_lens(&self) -> Result<Vec<usize>> {
        self.meta
            .req_arr("prefill_lens")?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| Error::artifact("bad len bucket")))
            .collect()
    }
}

/// A compiled executable with its signature.
pub struct LoadedExec {
    pub sig: ExecSignature,
    pub exe: xla::PjRtLoadedExecutable,
}

/// Lazily-compiled executable cache over one PJRT client.
///
/// NOT `Send` — lives on the engine thread.
pub struct ExecutableSet {
    client: xla::PjRtClient,
    index: ArtifactIndex,
    cache: RefCell<HashMap<String, Rc<LoadedExec>>>,
    /// Cumulative compile time (reported by `ttc info`).
    compile_ms: RefCell<f64>,
}

impl ExecutableSet {
    pub fn new(artifacts_dir: &PathBuf) -> Result<ExecutableSet> {
        let index = ArtifactIndex::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(ExecutableSet {
            client,
            index,
            cache: RefCell::new(HashMap::new()),
            compile_ms: RefCell::new(0.0),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn index(&self) -> &ArtifactIndex {
        &self.index
    }

    pub fn total_compile_ms(&self) -> f64 {
        *self.compile_ms.borrow()
    }

    /// Get (compiling on first use) the named executable.
    pub fn get(&self, name: &str) -> Result<Rc<LoadedExec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let sig = self.index.find(name)?.clone();
        let path = self.index.dir.join(&sig.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            Error::artifact(format!("cannot parse HLO {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        *self.compile_ms.borrow_mut() += ms;
        log_debug!("compiled {name} in {ms:.0}ms");
        let loaded = Rc::new(LoadedExec { sig, exe });
        self.cache.borrow_mut().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Pre-compile a list of executables (engine warmup).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }
}

impl LoadedExec {
    /// Execute with literal arguments (weights prepended by the caller),
    /// returning the flattened output tuple as literals.
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<xla::Literal>(args)?;
        let result = out[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Execute with device buffers, returning output buffers WITHOUT
    /// copying to host (the KV-cache round-trip path).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b(args)?;
        let outputs = out.pop().ok_or_else(|| Error::internal("no output device"))?;
        Ok(outputs)
    }
}
