//! Literal construction/extraction helpers over the `xla` crate.

use crate::error::{Error, Result};

/// Build an i32 literal of shape `[n]` from a slice.
pub fn i32_vec(values: &[i32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

/// Build an i32 literal of shape `dims` (row-major `values`).
pub fn i32_tensor(values: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != values.len() {
        return Err(Error::internal(format!(
            "i32_tensor: {} values for shape {dims:?}",
            values.len()
        )));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(values).reshape(&dims_i64)?)
}

/// Build an f32 literal of shape `dims` (row-major `values`).
pub fn f32_tensor(values: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != values.len() {
        return Err(Error::internal(format!(
            "f32_tensor: {} values for shape {dims:?}",
            values.len()
        )));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(values).reshape(&dims_i64)?)
}

/// Scalar f32 literal.
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 literal into a Vec (any shape, row-major).
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(i32_tensor(&[1, 2, 3], &[2, 2]).is_err());
        assert!(f32_tensor(&[1.0; 6], &[2, 3]).is_ok());
    }
}
