//! Answer extraction and aggregation (majority vote, weighted vote).
//!
//! Mirrors the paper's math evaluation: the generator emits a CoT
//! solution ending in `A:<answer>\n`; accuracy is exact match of the
//! extracted answer against ground truth.

use std::collections::HashMap;

/// Extract the final answer from a generated solution.
///
/// Accepts the canonical form `...;A:30\n` (or without the trailing
/// newline if generation hit the token cap right after the answer).
/// Returns `None` for malformed outputs — which count as incorrect, the
/// same way an unparseable model answer does in math benchmarks.
pub fn extract_answer(solution: &str) -> Option<String> {
    let idx = solution.rfind("A:")?;
    let tail = &solution[idx + 2..];
    let answer: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    if answer.is_empty() {
        return None;
    }
    // Require the answer to be terminated (newline or end-of-output):
    // a truncated "A:1" from "A:17" must not silently match "1" — but we
    // cannot distinguish truncation from completion at the char level, so
    // we accept end-of-string. Mid-string non-newline garbage is rejected.
    let after = &tail[answer.len()..];
    if after.is_empty() || after.starts_with('\n') {
        Some(answer)
    } else {
        None
    }
}

/// Exact-match correctness of one candidate solution.
pub fn is_correct(solution: &str, ground_truth: &str) -> bool {
    extract_answer(solution).as_deref() == Some(ground_truth)
}

/// One scored candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Generated solution text (everything after the prompt).
    pub text: String,
    /// Reward-model score (higher is better), if scored.
    pub score: f64,
    /// Tokens generated for this candidate.
    pub tokens: usize,
}

/// Majority voting: most frequent extracted answer; ties broken by total
/// score, then by first occurrence. Candidates with no extractable answer
/// are ignored (they can never win), unless *no* candidate parses, in
/// which case the first candidate's text is returned as-is.
pub fn majority_vote(candidates: &[Candidate]) -> Option<&Candidate> {
    vote(candidates, |_c| 1.0)
}

/// Weighted best-of-N: aggregate reward scores across candidates with
/// identical final answers, pick the answer with the highest total, then
/// return its highest-scored candidate. (Paper §2.1, "Weighted".)
pub fn weighted_vote(candidates: &[Candidate]) -> Option<&Candidate> {
    vote(candidates, |c| c.score)
}

/// Naive best-of-N: the single candidate with the highest score.
/// (Paper §2.1, "Naive".)
pub fn best_of_n(candidates: &[Candidate]) -> Option<&Candidate> {
    candidates
        .iter()
        .filter(|c| extract_answer(&c.text).is_some())
        .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap_or(std::cmp::Ordering::Equal))
        .or_else(|| candidates.first())
}

fn vote<'a>(
    candidates: &'a [Candidate],
    weight: impl Fn(&Candidate) -> f64,
) -> Option<&'a Candidate> {
    if candidates.is_empty() {
        return None;
    }
    // answer -> (total weight, best candidate index, best candidate score)
    let mut tally: HashMap<String, (f64, usize)> = HashMap::new();
    for (i, c) in candidates.iter().enumerate() {
        if let Some(ans) = extract_answer(&c.text) {
            let entry = tally.entry(ans).or_insert((0.0, i));
            entry.0 += weight(c);
            if c.score > candidates[entry.1].score {
                entry.1 = i;
            }
        }
    }
    if tally.is_empty() {
        return candidates.first();
    }
    let (_, &(_, best_idx)) = tally
        .iter()
        .max_by(|a, b| {
            a.1 .0
                .partial_cmp(&b.1 .0)
                .unwrap_or(std::cmp::Ordering::Equal)
                // deterministic tie-break: lower candidate index wins
                .then(b.1 .1.cmp(&a.1 .1))
        })
        .unwrap();
    Some(&candidates[best_idx])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(text: &str, score: f64) -> Candidate {
        Candidate {
            text: text.to_string(),
            score,
            tokens: text.len(),
        }
    }

    #[test]
    fn extracts_answers() {
        assert_eq!(extract_answer("S:1+2=3;A:3\n"), Some("3".to_string()));
        assert_eq!(extract_answer("S:1+2=3;A:30"), Some("30".to_string()));
        assert_eq!(extract_answer("S:1+2=3;"), None);
        assert_eq!(extract_answer("A:"), None);
        assert_eq!(extract_answer("A:12;junk"), None);
        // last A: wins (model may emit stray As mid-stream)
        assert_eq!(extract_answer("A:1\nA:2\n"), Some("2".to_string()));
    }

    #[test]
    fn correctness() {
        assert!(is_correct("S:1+2=3;A:3\n", "3"));
        assert!(!is_correct("S:1+2=3;A:4\n", "3"));
        assert!(!is_correct("garbage", "3"));
    }

    #[test]
    fn majority_picks_mode() {
        let cs = vec![
            cand("A:7\n", 0.1),
            cand("A:9\n", 0.9),
            cand("A:7\n", 0.2),
        ];
        assert_eq!(
            extract_answer(&majority_vote(&cs).unwrap().text),
            Some("7".to_string())
        );
    }

    #[test]
    fn weighted_can_override_majority() {
        let cs = vec![
            cand("A:7\n", 0.1),
            cand("A:7\n", 0.1),
            cand("A:9\n", 0.9),
        ];
        // majority says 7, weighted says 9 (0.9 > 0.2)
        assert_eq!(
            extract_answer(&majority_vote(&cs).unwrap().text),
            Some("7".to_string())
        );
        assert_eq!(
            extract_answer(&weighted_vote(&cs).unwrap().text),
            Some("9".to_string())
        );
    }

    #[test]
    fn best_of_n_ignores_unparseable() {
        let cs = vec![cand("junk", 5.0), cand("A:3\n", 0.2)];
        assert_eq!(
            extract_answer(&best_of_n(&cs).unwrap().text),
            Some("3".to_string())
        );
    }

    #[test]
    fn empty_and_all_garbage() {
        assert!(majority_vote(&[]).is_none());
        let garbage = vec![cand("x", 0.0), cand("y", 0.0)];
        // falls back to first candidate (counted incorrect downstream)
        assert_eq!(majority_vote(&garbage).unwrap().text, "x");
    }

    #[test]
    fn vote_deterministic_on_ties() {
        let cs = vec![cand("A:1\n", 0.5), cand("A:2\n", 0.5)];
        let a = majority_vote(&cs).unwrap().text.clone();
        for _ in 0..5 {
            assert_eq!(majority_vote(&cs).unwrap().text, a);
        }
    }

    #[test]
    fn prop_ground_truth_solutions_extract_correctly() {
        use crate::taskgen::Problem;
        use crate::testkit::{forall, prop_assert};
        forall(
            "taskgen solutions round-trip through answer extraction",
            200,
            |rng| {
                let k = rng.range(2, 9) as usize;
                Problem::sample(rng, k)
            },
            |p| {
                let sol = p.solution_text();
                prop_assert(
                    extract_answer(&sol).as_deref() == Some(p.answer().to_string().as_str()),
                    format!("extraction failed on {sol:?}"),
                )?;
                prop_assert(
                    is_correct(&sol, &p.answer().to_string()),
                    "is_correct disagrees".to_string(),
                )
            },
        );
    }

    #[test]
    fn prop_majority_winner_is_a_mode() {
        use crate::testkit::{forall, gen_vec, prop_assert};
        forall(
            "majority vote returns a modal answer",
            200,
            |rng| {
                gen_vec(rng, 1..12, |r| {
                    let ans = r.below(5);
                    Candidate {
                        text: format!("S:x;A:{ans}\n"),
                        score: r.f64(),
                        tokens: 10,
                    }
                })
            },
            |cands| {
                let winner = majority_vote(cands).unwrap();
                let winner_ans = extract_answer(&winner.text).unwrap();
                let count = |a: &str| {
                    cands
                        .iter()
                        .filter(|c| extract_answer(&c.text).as_deref() == Some(a))
                        .count()
                };
                let w = count(&winner_ans);
                for ans in ["0", "1", "2", "3", "4"] {
                    prop_assert(
                        count(ans) <= w,
                        format!("answer {ans} beats winner {winner_ans}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_best_of_n_maximizes_score() {
        use crate::testkit::{forall, gen_vec, prop_assert};
        forall(
            "naive BoN picks the max-score parseable candidate",
            200,
            |rng| {
                gen_vec(rng, 1..10, |r| {
                    let parseable = r.below(4) > 0;
                    Candidate {
                        text: if parseable {
                            format!("A:{}\n", r.below(10))
                        } else {
                            "garbage".to_string()
                        },
                        score: r.f64(),
                        tokens: 5,
                    }
                })
            },
            |cands| {
                let winner = best_of_n(cands).unwrap();
                if extract_answer(&winner.text).is_some() {
                    for c in cands {
                        if extract_answer(&c.text).is_some() {
                            prop_assert(
                                c.score <= winner.score,
                                format!("{} beats winner {}", c.score, winner.score),
                            )?;
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
