//! Miniature property-based testing framework (proptest is unavailable).
//!
//! Usage:
//! ```ignore
//! forall("batch sizes", 200, |rng| gen_vec(rng, 0..32, |r| r.below(100)), |v| {
//!     prop_assert(invariant(v), "invariant broke")
//! });
//! ```
//!
//! On failure the harness panics with the case index, the root seed and a
//! debug dump of the failing input, so the case is reproducible by
//! construction (generation is fully deterministic from the seed).

use crate::util::rng::Rng;

/// Root seed for property runs; override with `TTC_PROP_SEED` to replay.
fn root_seed() -> u64 {
    std::env::var("TTC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Number of cases multiplier; override with `TTC_PROP_CASES`.
fn cases_override(default: usize) -> usize {
    std::env::var("TTC_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Run `check` against `cases` generated inputs.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    generate: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let seed = root_seed();
    let cases = cases_override(cases);
    for case in 0..cases {
        let mut rng = Rng::new(seed, case as u64);
        let input = generate(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}, \
                 TTC_PROP_SEED={seed} to replay):\n  {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Assertion helper returning the `Result` the `forall` checker expects.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert approximate equality of floats.
pub fn prop_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

/// Generate a vector whose length is uniform in `len_range`.
pub fn gen_vec<T>(
    rng: &mut Rng,
    len_range: std::ops::Range<usize>,
    mut item: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let len = rng.range(len_range.start as i64, len_range.end as i64) as usize;
    (0..len).map(|_| item(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            "reverse twice is identity",
            50,
            |rng| gen_vec(rng, 0..20, |r| r.below(1000)),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                prop_assert(&w == v, "reverse∘reverse != id")
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", 5, |rng| rng.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(prop_close(1.0, 1.1, 1e-3, "x").is_err());
    }
}
