//! Corpus emission: everything the build-time python trainers and the
//! evaluation pipeline read.
//!
//! `ttc taskgen --out artifacts/data --seed S` writes:
//!
//! | file | contents |
//! |---|---|
//! | `vocab.json` | tokenizer manifest (see [`crate::tokenizer`]) |
//! | `lm_corpus.jsonl` | `{text, k}` documents for LM training |
//! | `prm_corpus.jsonl` | `{text, label, k, cut}` prefix examples for PRM training |
//! | `queries_train.jsonl` | probe-training queries `{id, query, answer, k}` |
//! | `queries_calib.jsonl` | Platt-calibration queries |
//! | `queries_test.jsonl` | held-out evaluation queries |
//!
//! Queries across the three splits and the LM corpus are sampled from
//! independent RNG streams, so the evaluation problems are (with
//! overwhelming probability over a ~10¹²-size problem space) unseen.

use crate::error::Result;
use crate::taskgen::arith::{corrupt_result, Problem, MAX_OPS, MIN_OPS};
use crate::tokenizer::Tokenizer;
use crate::util::json::Value;
use crate::util::rng::Rng;
use std::io::Write;
use std::path::Path;

/// Sizes of every emitted corpus.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub lm_docs: usize,
    pub prm_examples: usize,
    pub queries_train: usize,
    pub queries_calib: usize,
    pub queries_test: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        // Sized for the single-core CPU testbed: LM training is ~10 min,
        // matrix collection ~45 min (see EXPERIMENTS.md §Budget).
        CorpusConfig {
            lm_docs: 40_000,
            prm_examples: 30_000,
            queries_train: 120,
            queries_calib: 60,
            queries_test: 160,
            seed: 17,
        }
    }
}

/// A difficulty-balanced problem sampler.
fn balanced_problem(rng: &mut Rng, i: usize) -> Problem {
    let k = MIN_OPS + (i % (MAX_OPS - MIN_OPS + 1));
    Problem::sample(rng, k)
}

/// Emit every corpus into `dir`. Returns the number of files written.
pub fn emit_all(dir: &Path, cfg: &CorpusConfig) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let tok = Tokenizer::new();

    write_file(dir, "vocab.json", &tok.vocab_json().pretty())?;
    emit_lm_corpus(dir, cfg)?;
    emit_prm_corpus(dir, cfg)?;
    emit_queries(dir, "queries_train.jsonl", cfg.queries_train, cfg.seed, 100)?;
    emit_queries(dir, "queries_calib.jsonl", cfg.queries_calib, cfg.seed, 200)?;
    emit_queries(dir, "queries_test.jsonl", cfg.queries_test, cfg.seed, 300)?;
    Ok(6)
}

fn write_file(dir: &Path, name: &str, contents: &str) -> Result<()> {
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(contents.as_bytes())?;
    Ok(())
}

fn emit_lm_corpus(dir: &Path, cfg: &CorpusConfig) -> Result<()> {
    let mut rng = Rng::new(cfg.seed, 1);
    let mut out = String::with_capacity(cfg.lm_docs * 96);
    for i in 0..cfg.lm_docs {
        let p = balanced_problem(&mut rng, i);
        let rec = Value::obj()
            .with("text", p.document())
            .with("k", p.difficulty());
        out.push_str(&rec.dumps());
        out.push('\n');
    }
    write_file(dir, "lm_corpus.jsonl", &out)
}

/// PRM prefix corpus. Positives are clean solution prefixes; negatives
/// corrupt one step's result and *propagate consistently* from it (the way
/// a real decoding slip unfolds), so the PRM must detect the arithmetic
/// error rather than a formatting anomaly. Roughly half the examples end
/// with the final `A:x` line so the PRM also scores complete solutions
/// (the best-of-N use case).
fn emit_prm_corpus(dir: &Path, cfg: &CorpusConfig) -> Result<()> {
    let mut rng = Rng::new(cfg.seed, 2);
    let mut out = String::with_capacity(cfg.prm_examples * 96);
    for i in 0..cfg.prm_examples {
        let p = balanced_problem(&mut rng, i);
        let steps = p.steps();
        let k = steps.len();
        // prefix cut point: include steps[0..cut]
        let cut = rng.range(1, k as i64 + 1) as usize;
        let include_answer = cut == k && rng.below(2) == 0;
        let corrupt = rng.below(2) == 0;
        let corrupt_at = if corrupt {
            rng.range(0, cut as i64) as usize
        } else {
            usize::MAX
        };

        let mut text = p.query_text();
        text.push_str("S:");
        let mut acc = p.first;
        for (j, step) in steps.iter().take(cut).enumerate() {
            let mut result = step.op.apply(acc, step.rhs);
            if j == corrupt_at {
                result = corrupt_result(&mut rng, result);
            }
            text.push_str(&format!(
                "{}{}{}={}",
                acc,
                step.op.symbol(),
                step.rhs,
                result
            ));
            text.push(';');
            acc = result;
        }
        if include_answer {
            text.push_str(&format!("A:{acc}\n"));
        }

        let rec = Value::obj()
            .with("text", text)
            .with("label", if corrupt { 0.0 } else { 1.0 })
            .with("k", k)
            .with("cut", cut);
        out.push_str(&rec.dumps());
        out.push('\n');
    }
    write_file(dir, "prm_corpus.jsonl", &out)
}

fn emit_queries(dir: &Path, name: &str, n: usize, seed: u64, stream: u64) -> Result<()> {
    let mut rng = Rng::new(seed, stream);
    let mut out = String::with_capacity(n * 80);
    for i in 0..n {
        let p = balanced_problem(&mut rng, i);
        let rec = Value::obj()
            .with("id", format!("{}-{i}", name.trim_end_matches(".jsonl")))
            .with("query", p.query_text())
            .with("answer", p.answer().to_string())
            .with("k", p.difficulty());
        out.push_str(&rec.dumps());
        out.push('\n');
    }
    write_file(dir, name, &out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ttc_corpus_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_cfg() -> CorpusConfig {
        CorpusConfig {
            lm_docs: 60,
            prm_examples: 60,
            queries_train: 12,
            queries_calib: 6,
            queries_test: 12,
            seed: 7,
        }
    }

    #[test]
    fn emit_all_writes_expected_files() {
        let dir = tmp_dir("all");
        emit_all(&dir, &small_cfg()).unwrap();
        for f in [
            "vocab.json",
            "lm_corpus.jsonl",
            "prm_corpus.jsonl",
            "queries_train.jsonl",
            "queries_calib.jsonl",
            "queries_test.jsonl",
        ] {
            assert!(dir.join(f).exists(), "{f} missing");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lm_corpus_documents_parse_and_tokenize() {
        let dir = tmp_dir("lm");
        emit_all(&dir, &small_cfg()).unwrap();
        let tok = Tokenizer::new();
        let text = std::fs::read_to_string(dir.join("lm_corpus.jsonl")).unwrap();
        let mut n = 0;
        for line in text.lines() {
            let v = parse(line).unwrap();
            let doc = v.req_str("text").unwrap();
            assert!(doc.starts_with("Q:"));
            assert!(doc.ends_with('\n'));
            tok.encode(doc).unwrap();
            n += 1;
        }
        assert_eq!(n, 60);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prm_negatives_differ_from_ground_truth() {
        let dir = tmp_dir("prm");
        emit_all(&dir, &small_cfg()).unwrap();
        let text = std::fs::read_to_string(dir.join("prm_corpus.jsonl")).unwrap();
        let mut pos = 0;
        let mut neg = 0;
        for line in text.lines() {
            let v = parse(line).unwrap();
            let label = v.req_f64("label").unwrap();
            if label > 0.5 {
                pos += 1;
            } else {
                neg += 1;
            }
        }
        // ~50/50 split
        assert!(pos >= 15 && neg >= 15, "pos={pos} neg={neg}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prm_positive_prefixes_are_arithmetically_correct() {
        let dir = tmp_dir("prmpos");
        emit_all(&dir, &small_cfg()).unwrap();
        let text = std::fs::read_to_string(dir.join("prm_corpus.jsonl")).unwrap();
        for line in text.lines() {
            let v = parse(line).unwrap();
            if v.req_f64("label").unwrap() < 0.5 {
                continue;
            }
            let doc = v.req_str("text").unwrap();
            let sol = doc.split('\n').nth(1).unwrap();
            // verify each step string "a+b=c" actually holds mod 100
            for step in sol.trim_start_matches("S:").split(';') {
                if step.is_empty() || step.starts_with("A:") {
                    continue;
                }
                let (expr, result) = step.split_once('=').unwrap();
                let op_pos = expr[1..].find(['+', '-', '*']).unwrap() + 1;
                let a: i64 = expr[..op_pos].parse().unwrap();
                let b: i64 = expr[op_pos + 1..].parse().unwrap();
                let r: i64 = result.parse().unwrap();
                let expect = match &expr[op_pos..op_pos + 1] {
                    "+" => crate::taskgen::arith::Op::Add.apply(a, b),
                    "-" => crate::taskgen::arith::Op::Sub.apply(a, b),
                    _ => crate::taskgen::arith::Op::Mul.apply(a, b),
                };
                assert_eq!(r, expect, "bad positive step {step}");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn query_splits_are_disjoint() {
        let dir = tmp_dir("splits");
        emit_all(&dir, &small_cfg()).unwrap();
        let mut seen = std::collections::HashSet::new();
        for f in ["queries_train.jsonl", "queries_calib.jsonl", "queries_test.jsonl"] {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            for line in text.lines() {
                let v = parse(line).unwrap();
                let q = v.req_str("query").unwrap().to_string();
                assert!(seen.insert(q), "duplicate query across splits in {f}");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let d1 = tmp_dir("det1");
        let d2 = tmp_dir("det2");
        emit_all(&d1, &small_cfg()).unwrap();
        emit_all(&d2, &small_cfg()).unwrap();
        let a = std::fs::read_to_string(d1.join("queries_test.jsonl")).unwrap();
        let b = std::fs::read_to_string(d2.join("queries_test.jsonl")).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }
}
