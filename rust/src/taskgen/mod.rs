//! Synthetic task generation.
//!
//! Stand-in for the paper's NuminaMath-CoT workload (see DESIGN.md §2):
//! multi-step **modular-arithmetic chains** with chain-of-thought
//! solutions. The two properties the paper's evaluation depends on are
//! preserved:
//!
//! 1. a *difficulty gradient* — accuracy of a sampled model decays with
//!    chain length `k`, so routing by predicted difficulty matters;
//! 2. *verifiable intermediate steps* — each CoT step is an independent
//!    binary operation, so a process reward model can be trained to score
//!    partial solutions, and step-level beam search has signal to exploit.
//!
//! Rust is the system of record: `ttc taskgen` writes the LM training
//! corpus, PRM prefix corpus, query splits and vocab manifest that the
//! build-time python trainers consume.

pub mod arith;
pub mod corpus;

pub use arith::{Op, Problem, StepRecord};
pub use corpus::{emit_all, CorpusConfig};
