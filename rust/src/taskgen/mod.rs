//! Synthetic task generation.
//!
//! Stand-in for the paper's NuminaMath-CoT workload (see DESIGN.md §2):
//! multi-step **modular-arithmetic chains** with chain-of-thought
//! solutions, plus a second **max-value** domain ([`maxval`]) whose
//! comparison steps are deliberately easier — agentic chains mix the
//! two so per-step difficulty is genuinely heterogeneous. The two
//! properties the paper's evaluation depends on are preserved:
//!
//! 1. a *difficulty gradient* — accuracy of a sampled model decays with
//!    chain length `k`, so routing by predicted difficulty matters;
//! 2. *verifiable intermediate steps* — each CoT step is an independent
//!    binary operation, so a process reward model can be trained to score
//!    partial solutions, and step-level beam search has signal to exploit.
//!
//! Rust is the system of record: `ttc taskgen` writes the LM training
//! corpus, PRM prefix corpus, query splits and vocab manifest that the
//! build-time python trainers consume.

pub mod arith;
pub mod corpus;
pub mod maxval;

pub use arith::{Op, Problem, StepRecord};
pub use corpus::{emit_all, CorpusConfig};
pub use maxval::{MaxProblem, MaxStep};

/// A problem from either task domain, behind one accumulator-chain
/// interface: every problem is a left-to-right chain of `k` steps, each
/// combining the running accumulator with the next operand. This is the
/// single grammar definition shared by the SimBackend emulator (which
/// parses prompts back into problems) and the agentic chain tier
/// (`server::chain`, which re-seeds a step's first operand with the
/// previous step's answer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChainProblem {
    Arith(arith::Problem),
    Max(maxval::MaxProblem),
}

impl ChainProblem {
    /// Parse a query expression (the text between `Q:` and `=?`) into a
    /// problem. Dispatches on the unambiguous `max(` prefix; anything
    /// else is tried as an arithmetic op chain. `None` = out of domain.
    pub fn parse_expr(expr: &str) -> Option<ChainProblem> {
        if let Some(inner) = expr.strip_prefix("max(") {
            let inner = inner.strip_suffix(')')?;
            let items: Vec<i64> = inner
                .split(',')
                .map(|d| d.parse().ok())
                .collect::<Option<_>>()?;
            if items.len() < 2 {
                return None;
            }
            return Some(ChainProblem::Max(MaxProblem { items }));
        }
        let mut chars = expr.chars().peekable();
        let first = take_int(&mut chars)?;
        let mut chain = Vec::new();
        while let Some(&c) = chars.peek() {
            let op = match c {
                '+' => Op::Add,
                '-' => Op::Sub,
                '*' => Op::Mul,
                _ => return None,
            };
            chars.next();
            chain.push((op, take_int(&mut chars)?));
        }
        if chain.is_empty() {
            return None;
        }
        Some(ChainProblem::Arith(Problem { first, chain }))
    }

    /// Short domain tag (`arith` | `max`) — the trace-file spelling.
    pub fn domain(&self) -> &'static str {
        match self {
            ChainProblem::Arith(_) => "arith",
            ChainProblem::Max(_) => "max",
        }
    }

    /// Number of CoT steps.
    pub fn k(&self) -> usize {
        match self {
            ChainProblem::Arith(p) => p.chain.len(),
            ChainProblem::Max(p) => p.difficulty(),
        }
    }

    /// Initial accumulator (the first operand / item).
    pub fn start(&self) -> i64 {
        match self {
            ChainProblem::Arith(p) => p.first,
            ChainProblem::Max(p) => p.items[0],
        }
    }

    /// Ground-truth final answer.
    pub fn answer(&self) -> i64 {
        match self {
            ChainProblem::Arith(p) => p.answer(),
            ChainProblem::Max(p) => p.answer(),
        }
    }

    /// The i-th step's surface form up to and including `=`, given the
    /// running accumulator, plus the correct result: `("7+8=", 5)` or
    /// `("max(7,8)=", 8)`. The caller appends the (possibly slipped)
    /// result digit. `None` when `i >= k()`.
    pub fn step_stem(&self, i: usize, acc: i64) -> Option<(String, i64)> {
        match self {
            ChainProblem::Arith(p) => {
                let &(op, rhs) = p.chain.get(i)?;
                Some((format!("{acc}{}{rhs}=", op.symbol()), op.apply(acc, rhs)))
            }
            ChainProblem::Max(p) => {
                let &rhs = p.items.get(i + 1)?;
                Some((format!("max({acc},{rhs})="), acc.max(rhs)))
            }
        }
    }

    /// Ground-truth step texts (no trailing separators), e.g.
    /// `["7+8=5", "5-5=0"]` — what the PRM scores prefixes against.
    pub fn step_texts(&self) -> Vec<String> {
        match self {
            ChainProblem::Arith(p) => p.steps().iter().map(|s| s.text()).collect(),
            ChainProblem::Max(p) => p.steps().iter().map(|s| s.text()).collect(),
        }
    }

    /// Relative slip difficulty of this domain's steps under sampled
    /// decoding (1.0 = the arithmetic baseline). Comparison steps carry
    /// no carry table, so the emulated generator slips on them half as
    /// often — the cross-domain difficulty gradient agentic chains mix.
    pub fn slip_factor(&self) -> f64 {
        match self {
            ChainProblem::Arith(_) => 1.0,
            ChainProblem::Max(_) => 0.5,
        }
    }

    /// The same problem re-seeded with a new first operand / item — how
    /// a chain derives step k+1's prompt from step k's selected answer.
    pub fn with_first(&self, v: i64) -> ChainProblem {
        match self {
            ChainProblem::Arith(p) => ChainProblem::Arith(Problem {
                first: v,
                chain: p.chain.clone(),
            }),
            ChainProblem::Max(p) => {
                let mut items = p.items.clone();
                items[0] = v;
                ChainProblem::Max(MaxProblem { items })
            }
        }
    }

    /// `Q:<expr>=?\n`
    pub fn query_text(&self) -> String {
        match self {
            ChainProblem::Arith(p) => p.query_text(),
            ChainProblem::Max(p) => p.query_text(),
        }
    }

    /// `S:<step;>*A:<answer>\n`
    pub fn solution_text(&self) -> String {
        match self {
            ChainProblem::Arith(p) => p.solution_text(),
            ChainProblem::Max(p) => p.solution_text(),
        }
    }
}

fn take_int(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) -> Option<i64> {
    let mut s = String::new();
    while let Some(&c) = chars.peek() {
        if c.is_ascii_digit() {
            s.push(c);
            chars.next();
        } else {
            break;
        }
    }
    s.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_expr_dispatches_on_domain() {
        let a = ChainProblem::parse_expr("7+8-5").unwrap();
        assert_eq!(a.domain(), "arith");
        assert_eq!(a.k(), 2);
        assert_eq!(a.start(), 7);
        assert_eq!(a.answer(), 0);
        let m = ChainProblem::parse_expr("max(3,8,5)").unwrap();
        assert_eq!(m.domain(), "max");
        assert_eq!(m.k(), 2);
        assert_eq!(m.start(), 3);
        assert_eq!(m.answer(), 8);
    }

    #[test]
    fn parse_expr_rejects_out_of_domain() {
        assert!(ChainProblem::parse_expr("7").is_none()); // no ops
        assert!(ChainProblem::parse_expr("7/2").is_none()); // unknown op
        assert!(ChainProblem::parse_expr("max(5)").is_none()); // one item
        assert!(ChainProblem::parse_expr("max(3,8").is_none()); // unclosed
        assert!(ChainProblem::parse_expr("max(3,x)").is_none()); // non-digit
        assert!(ChainProblem::parse_expr("").is_none());
    }

    #[test]
    fn step_stem_follows_accumulator() {
        let a = ChainProblem::parse_expr("7+8-5").unwrap();
        assert_eq!(a.step_stem(0, 7).unwrap(), ("7+8=".to_string(), 5));
        // a slipped accumulator is continued from, like a real LM would
        assert_eq!(a.step_stem(1, 9).unwrap(), ("9-5=".to_string(), 4));
        assert!(a.step_stem(2, 4).is_none());
        let m = ChainProblem::parse_expr("max(3,8,5)").unwrap();
        assert_eq!(m.step_stem(0, 3).unwrap(), ("max(3,8)=".to_string(), 8));
        assert_eq!(m.step_stem(1, 8).unwrap(), ("max(8,5)=".to_string(), 8));
        assert!(m.step_stem(2, 8).is_none());
    }

    #[test]
    fn step_texts_match_solution_text() {
        for expr in ["7+8-5*3", "max(1,9,2,7)"] {
            let p = ChainProblem::parse_expr(expr).unwrap();
            let joined = format!("S:{};A:{}\n", p.step_texts().join(";"), p.answer());
            assert_eq!(joined, p.solution_text());
        }
    }

    #[test]
    fn with_first_reseeds_the_chain() {
        let a = ChainProblem::parse_expr("7+8-5").unwrap().with_first(2);
        assert_eq!(a.query_text(), "Q:2+8-5=?\n");
        assert_eq!(a.start(), 2);
        let m = ChainProblem::parse_expr("max(3,8,5)").unwrap().with_first(9);
        assert_eq!(m.query_text(), "Q:max(9,8,5)=?\n");
        assert_eq!(m.answer(), 9);
    }

    #[test]
    fn parse_roundtrips_query_text() {
        let mut rng = crate::util::rng::Rng::new(77, 0);
        for k in arith::MIN_OPS..=arith::MAX_OPS {
            for p in [
                ChainProblem::Arith(Problem::sample(&mut rng, k)),
                ChainProblem::Max(MaxProblem::sample(&mut rng, k)),
            ] {
                let q = p.query_text();
                let expr = q
                    .strip_prefix("Q:")
                    .and_then(|r| r.strip_suffix("=?\n"))
                    .unwrap();
                assert_eq!(ChainProblem::parse_expr(expr).unwrap(), p);
            }
        }
    }
}
