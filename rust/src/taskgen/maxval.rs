//! Max-value chain problems — the second task domain.
//!
//! A problem is "find the maximum of k+1 single digits", solved as a
//! left-to-right running-max chain of k comparison steps:
//!
//! ```text
//! query    = "Q:max(3,8,5)=?\n"
//! solution = "S:max(3,8)=8;max(8,5)=8;A:8\n"
//! ```
//!
//! The surface grammar is disambiguated from the modular-arithmetic
//! domain by the `max(` prefix, so a prompt parses as exactly one
//! domain and SimBackend's temp-0 generation stays a pure function of
//! the prompt. Comparison steps are *easier* than arithmetic steps
//! (no carry table to learn), which is the point: mixing the two
//! domains inside one agentic chain gives the router genuinely
//! heterogeneous per-step difficulty to exploit.

use crate::taskgen::arith::{MAX_OPS, MIN_OPS, MODULUS};
use crate::util::rng::Rng;

/// One running-max step: `max(lhs, rhs) = result`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MaxStep {
    pub lhs: i64,
    pub rhs: i64,
    pub result: i64,
}

impl MaxStep {
    /// Surface form without trailing separator, e.g. `max(3,8)=8`.
    pub fn text(&self) -> String {
        format!("max({},{})={}", self.lhs, self.rhs, self.result)
    }
}

/// A generated max-chain instance. `items.len() == k + 1` for
/// difficulty `k` (one comparison per additional item).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MaxProblem {
    /// The digits to take the maximum over, in presentation order.
    pub items: Vec<i64>,
}

impl MaxProblem {
    /// Sample a problem with exactly `k` comparison steps.
    pub fn sample(rng: &mut Rng, k: usize) -> MaxProblem {
        assert!((MIN_OPS..=MAX_OPS).contains(&k), "k={k} out of range");
        let items = (0..=k).map(|_| rng.range(0, MODULUS)).collect();
        MaxProblem { items }
    }

    /// Difficulty = number of comparison steps.
    pub fn difficulty(&self) -> usize {
        self.items.len().saturating_sub(1)
    }

    /// The full step-by-step evaluation.
    pub fn steps(&self) -> Vec<MaxStep> {
        let mut acc = self.items[0];
        self.items[1..]
            .iter()
            .map(|&rhs| {
                let result = acc.max(rhs);
                let step = MaxStep { lhs: acc, rhs, result };
                acc = result;
                step
            })
            .collect()
    }

    /// Ground-truth final answer.
    pub fn answer(&self) -> i64 {
        self.items.iter().copied().max().expect("non-empty items")
    }

    /// `Q:max(3,8,5)=?\n`
    pub fn query_text(&self) -> String {
        let digits: Vec<String> = self.items.iter().map(|d| d.to_string()).collect();
        format!("Q:max({})=?\n", digits.join(","))
    }

    /// `S:max(3,8)=8;max(8,5)=8;A:8\n`
    pub fn solution_text(&self) -> String {
        let mut s = String::from("S:");
        for step in self.steps() {
            s.push_str(&step.text());
            s.push(';');
        }
        s.push_str(&format!("A:{}\n", self.answer()));
        s
    }

    /// Query + solution — one LM training document.
    pub fn document(&self) -> String {
        format!("{}{}", self.query_text(), self.solution_text())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234, 0)
    }

    #[test]
    fn steps_chain_correctly() {
        let p = MaxProblem { items: vec![3, 8, 5] };
        let steps = p.steps();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].text(), "max(3,8)=8");
        assert_eq!(steps[1].text(), "max(8,5)=8");
        assert_eq!(p.answer(), 8);
    }

    #[test]
    fn surface_forms() {
        let p = MaxProblem { items: vec![3, 8, 5] };
        assert_eq!(p.query_text(), "Q:max(3,8,5)=?\n");
        assert_eq!(p.solution_text(), "S:max(3,8)=8;max(8,5)=8;A:8\n");
    }

    #[test]
    fn sample_respects_difficulty_and_alphabet() {
        let tok = crate::tokenizer::Tokenizer::new();
        let mut r = rng();
        for k in MIN_OPS..=MAX_OPS {
            for _ in 0..50 {
                let p = MaxProblem::sample(&mut r, k);
                assert_eq!(p.difficulty(), k);
                tok.encode(&p.document()).unwrap();
                for s in p.steps() {
                    assert!((0..MODULUS).contains(&s.result));
                }
            }
        }
    }

    #[test]
    fn surface_lengths_fit_engine_shapes() {
        // query must fit prefill_len (32), solution must fit gen_max_new
        // (96) and query+solution must fit prm_len (128) at the hardest
        // difficulty — see engine::backend::EngineShapes::sim_default.
        let mut r = rng();
        for _ in 0..200 {
            let p = MaxProblem::sample(&mut r, MAX_OPS);
            assert!(p.query_text().len() <= 32, "query too long");
            assert!(p.solution_text().len() <= 96, "solution too long");
            assert!(p.document().len() <= 128, "document too long");
        }
    }
}
