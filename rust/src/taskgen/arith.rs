//! Modular-arithmetic chain problems and their chain-of-thought solutions.
//!
//! A problem is `a₁ op₁ a₂ op₂ … op_k a_{k+1}` evaluated **left-to-right,
//! everything mod 10**. The canonical surface forms are:
//!
//! ```text
//! query    = "Q:7+8-5=?\n"
//! solution = "S:7+8=5;5-5=0;A:0\n"
//! ```
//!
//! Difficulty is the number of operations `k` (each is one CoT step):
//! under temperature sampling, per-step slips compound multiplicatively
//! with chain length — the difficulty gradient the paper's router
//! exploits. The per-step function is a 10×10×3 table, learnable by the
//! single-core-budget generator (DESIGN.md §2; mod-100 two-digit steps
//! defeat a model this small because the tens digit is emitted before
//! the carry is resolvable).

use crate::util::rng::Rng;

/// The modulus for all arithmetic.
pub const MODULUS: i64 = 10;

/// Supported difficulty range (number of operations / CoT steps).
pub const MIN_OPS: usize = 2;
pub const MAX_OPS: usize = 8;

/// A binary operation in the chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Add,
    Sub,
    Mul,
}

impl Op {
    pub fn symbol(self) -> char {
        match self {
            Op::Add => '+',
            Op::Sub => '-',
            Op::Mul => '*',
        }
    }

    /// Apply modulo [`MODULUS`], result always in `[0, MODULUS)`.
    pub fn apply(self, a: i64, b: i64) -> i64 {
        let r = match self {
            Op::Add => a + b,
            Op::Sub => a - b,
            Op::Mul => a * b,
        };
        r.rem_euclid(MODULUS)
    }
}

/// One CoT step: `lhs op rhs = result`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepRecord {
    pub lhs: i64,
    pub op: Op,
    pub rhs: i64,
    pub result: i64,
}

impl StepRecord {
    /// Surface form without trailing separator, e.g. `55-25=30`.
    pub fn text(&self) -> String {
        format!("{}{}{}={}", self.lhs, self.op.symbol(), self.rhs, self.result)
    }
}

/// A generated problem instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Problem {
    /// First operand.
    pub first: i64,
    /// Subsequent (op, operand) pairs; `len()` == difficulty `k`.
    pub chain: Vec<(Op, i64)>,
}

impl Problem {
    /// Sample a problem with exactly `k` operations.
    pub fn sample(rng: &mut Rng, k: usize) -> Problem {
        assert!((MIN_OPS..=MAX_OPS).contains(&k), "k={k} out of range");
        let first = rng.range(2, 10);
        let chain = (0..k)
            .map(|_| {
                // Multiplication is rarer (it is the hardest step type).
                let op = match rng.below(5) {
                    0 | 1 => Op::Add,
                    2 | 3 => Op::Sub,
                    _ => Op::Mul,
                };
                (op, rng.range(2, 10))
            })
            .collect();
        Problem { first, chain }
    }

    /// Difficulty = number of operations.
    pub fn difficulty(&self) -> usize {
        self.chain.len()
    }

    /// The full step-by-step evaluation.
    pub fn steps(&self) -> Vec<StepRecord> {
        let mut acc = self.first;
        self.chain
            .iter()
            .map(|&(op, operand)| {
                let result = op.apply(acc, operand);
                let step = StepRecord {
                    lhs: acc,
                    op,
                    rhs: operand,
                    result,
                };
                acc = result;
                step
            })
            .collect()
    }

    /// Ground-truth final answer in `[0, MODULUS)`.
    pub fn answer(&self) -> i64 {
        self.steps().last().map(|s| s.result).unwrap_or(self.first)
    }

    /// `Q:17+38-25=?\n`
    pub fn query_text(&self) -> String {
        let mut s = String::from("Q:");
        s.push_str(&self.first.to_string());
        for &(op, operand) in &self.chain {
            s.push(op.symbol());
            s.push_str(&operand.to_string());
        }
        s.push_str("=?\n");
        s
    }

    /// `S:17+38=55;55-25=30;A:30\n`
    pub fn solution_text(&self) -> String {
        let mut s = String::from("S:");
        for step in self.steps() {
            s.push_str(&step.text());
            s.push(';');
        }
        s.push_str(&format!("A:{}\n", self.answer()));
        s
    }

    /// Query + solution — one LM training document.
    pub fn document(&self) -> String {
        format!("{}{}", self.query_text(), self.solution_text())
    }
}

/// Corrupt a step result to produce PRM negatives. The corruption models
/// realistic decoding slips: off-by-one/two arithmetic or a random digit.
pub fn corrupt_result(rng: &mut Rng, correct: i64) -> i64 {
    loop {
        let wrong = match rng.below(3) {
            0 => (correct + if rng.below(2) == 0 { 1 } else { -1 }).rem_euclid(MODULUS),
            1 => (correct + if rng.below(2) == 0 { 2 } else { -2 }).rem_euclid(MODULUS),
            _ => rng.range(0, MODULUS),
        };
        if wrong != correct {
            return wrong;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234, 0)
    }

    #[test]
    fn op_apply_mod() {
        assert_eq!(Op::Add.apply(9, 5), 4);
        assert_eq!(Op::Sub.apply(3, 7), 6);
        assert_eq!(Op::Mul.apply(7, 8), 6);
        assert_eq!(Op::Sub.apply(0, 1), 9);
    }

    #[test]
    fn steps_chain_correctly() {
        let p = Problem {
            first: 7,
            chain: vec![(Op::Add, 8), (Op::Sub, 5)],
        };
        let steps = p.steps();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].text(), "7+8=5");
        assert_eq!(steps[1].text(), "5-5=0");
        assert_eq!(p.answer(), 0);
    }

    #[test]
    fn surface_forms() {
        let p = Problem {
            first: 7,
            chain: vec![(Op::Add, 8), (Op::Sub, 5)],
        };
        assert_eq!(p.query_text(), "Q:7+8-5=?\n");
        assert_eq!(p.solution_text(), "S:7+8=5;5-5=0;A:0\n");
    }

    #[test]
    fn sample_respects_difficulty_and_alphabet() {
        let tok = crate::tokenizer::Tokenizer::new();
        let mut r = rng();
        for k in MIN_OPS..=MAX_OPS {
            for _ in 0..50 {
                let p = Problem::sample(&mut r, k);
                assert_eq!(p.difficulty(), k);
                // every surface form must tokenize
                tok.encode(&p.document()).unwrap();
                // results all within [0, MODULUS)
                for s in p.steps() {
                    assert!((0..MODULUS).contains(&s.result));
                }
            }
        }
    }

    #[test]
    fn document_length_bounded() {
        // The engine compiles fixed max sequence lengths; make sure the
        // hardest problems fit with margin (see engine::shapes).
        let mut r = rng();
        let mut max_len = 0;
        for _ in 0..500 {
            let p = Problem::sample(&mut r, MAX_OPS);
            max_len = max_len.max(p.document().len());
            assert!(p.query_text().len() <= 32, "query too long");
        }
        assert!(max_len <= 80, "max document length {max_len}");
    }

    #[test]
    fn corrupt_result_differs_and_in_range() {
        let mut r = rng();
        for v in 0..MODULUS {
            for _ in 0..8 {
                let w = corrupt_result(&mut r, v);
                assert_ne!(w, v);
                assert!((0..MODULUS).contains(&w));
            }
        }
    }

    #[test]
    fn operands_single_digit() {
        let mut r = rng();
        for _ in 0..300 {
            let p = Problem::sample(&mut r, 5);
            assert!((2..10).contains(&p.first));
            for (_, operand) in &p.chain {
                assert!((2..10).contains(operand));
            }
        }
    }
}
