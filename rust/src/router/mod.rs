//! The utility-maximizing router (paper §2.2–§2.4).
//!
//! For each query `x` the router evaluates every strategy `s ∈ S`:
//!
//! ```text
//! U_s(x) = â_s(x) − λ_T · T̂_s(x) − λ_L · L̂_s(x)
//! s*(x)  = argmax_s U_s(x)
//! ```
//!
//! `â` comes from the Platt-calibrated probe (one embed call + one batched
//! probe-forward over all strategies), `T̂`/`L̂` from the per-strategy cost
//! model. [`select_offline`] is the same argmax over precomputed tables —
//! used by every figure sweep so that λ grids cost microseconds per point.

use crate::costmodel::{CostEstimate, CostModel};
use crate::engine::EngineHandle;
use crate::error::Result;
use crate::probe::{CalibratedProbe, FeatureBuilder};
use crate::strategies::Strategy;
use crate::tokenizer::Tokenizer;

/// Scored strategy for one query.
#[derive(Debug, Clone)]
pub struct StrategyScore {
    pub strategy: Strategy,
    /// Calibrated accuracy prediction â_s(x).
    pub acc_hat: f64,
    pub cost: CostEstimate,
    pub utility: f64,
}

/// Penalty weights (user preference knobs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lambdas {
    /// λ_T — per generated token.
    pub token: f64,
    /// λ_L — per millisecond of latency.
    pub latency: f64,
}

impl Lambdas {
    pub fn new(token: f64, latency: f64) -> Lambdas {
        Lambdas { token, latency }
    }

    pub fn utility(&self, acc_hat: f64, cost: &CostEstimate) -> f64 {
        acc_hat - self.token * cost.tokens - self.latency * cost.latency_ms
    }
}

/// The query-adaptive router.
pub struct Router {
    pub strategies: Vec<Strategy>,
    pub probe: CalibratedProbe,
    pub costs: CostModel,
    pub features: FeatureBuilder,
    /// Pre-rendered strategy ids (parallel to `strategies`): cost-model
    /// keys on the per-request hot path — rendering an id consults the
    /// decoding-method registry, which must not happen per request.
    ids: Vec<String>,
    tokenizer: Tokenizer,
}

impl Router {
    pub fn new(
        strategies: Vec<Strategy>,
        probe: CalibratedProbe,
        costs: CostModel,
        features: FeatureBuilder,
    ) -> Router {
        let ids = strategies.iter().map(|s| s.id()).collect();
        Router {
            strategies,
            probe,
            costs,
            features,
            ids,
            tokenizer: Tokenizer::new(),
        }
    }

    /// Score every strategy for a query (probe â + cost model).
    pub fn score_all(
        &self,
        engine: &EngineHandle,
        query: &str,
        lambdas: Lambdas,
    ) -> Result<Vec<StrategyScore>> {
        let query_ids = self.tokenizer.encode(query)?;
        let emb = engine
            .embed(self.probe.embed_kind, vec![query_ids.clone()])?
            .pop()
            .expect("one embedding for one query");
        let feats: Vec<Vec<f32>> = self
            .strategies
            .iter()
            .map(|s| self.features.build(&emb, s, query_ids.len()))
            .collect();
        let probs = self.probe.predict(engine, feats)?;
        self.strategies
            .iter()
            .zip(&self.ids)
            .zip(probs)
            .map(|((s, id), acc_hat)| {
                let cost = self.costs.get(id)?;
                Ok(StrategyScore {
                    strategy: s.clone(),
                    acc_hat,
                    cost,
                    utility: lambdas.utility(acc_hat, &cost),
                })
            })
            .collect()
    }

    /// `s*(x)` — the utility argmax (paper §2.3).
    pub fn select(
        &self,
        engine: &EngineHandle,
        query: &str,
        lambdas: Lambdas,
    ) -> Result<StrategyScore> {
        let scores = self.score_all(engine, query, lambdas)?;
        Ok(pick_max(&scores))
    }
}

fn pick_max(scores: &[StrategyScore]) -> StrategyScore {
    assert!(!scores.is_empty());
    scores
        .iter()
        .max_by(|a, b| {
            a.utility
                .partial_cmp(&b.utility)
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap()
        .clone()
}

/// Offline argmax over precomputed per-strategy (â, cost) tables — the
/// figure-sweep hot path. Returns the winning index.
pub fn select_offline(probs: &[f64], costs: &[CostEstimate], lambdas: Lambdas) -> usize {
    debug_assert_eq!(probs.len(), costs.len());
    let mut best = 0;
    let mut best_u = f64::NEG_INFINITY;
    for i in 0..probs.len() {
        let u = lambdas.utility(probs[i], &costs[i]);
        if u > best_u {
            best_u = u;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};

    fn est(tokens: f64, latency_ms: f64) -> CostEstimate {
        CostEstimate { tokens, latency_ms }
    }

    #[test]
    fn utility_formula() {
        let l = Lambdas::new(0.001, 0.0001);
        let u = l.utility(0.8, &est(100.0, 1000.0));
        assert!((u - (0.8 - 0.1 - 0.1)).abs() < 1e-12);
    }

    #[test]
    fn zero_penalty_picks_highest_accuracy() {
        let probs = [0.3, 0.9, 0.5];
        let costs = [est(10.0, 10.0), est(9999.0, 99999.0), est(1.0, 1.0)];
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(0.0, 0.0)), 1);
    }

    #[test]
    fn high_token_penalty_prefers_cheap() {
        let probs = [0.5, 0.9];
        let costs = [est(10.0, 10.0), est(1000.0, 10.0)];
        // Δacc = 0.4; Δtokens = 990 → switch at λ_T ≈ 0.000404
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(1e-5, 0.0)), 1);
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(1e-3, 0.0)), 0);
    }

    #[test]
    fn latency_penalty_independent_of_tokens() {
        let probs = [0.5, 0.9];
        // same tokens, very different latency (the beam-search signature)
        let costs = [est(100.0, 100.0), est(100.0, 10_000.0)];
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(0.0, 0.0)), 1);
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(0.0, 1e-4)), 0);
    }

    #[test]
    fn prop_selected_utility_is_max() {
        forall(
            "offline argmax is argmax",
            200,
            |rng| {
                let n = rng.range(1, 12) as usize;
                let probs = gen_vec(rng, n..n + 1, |r| r.f64());
                let costs = gen_vec(rng, n..n + 1, |r| {
                    est(r.f64() * 1000.0, r.f64() * 10000.0)
                });
                let l = Lambdas::new(rng.f64() * 1e-2, rng.f64() * 1e-3);
                (probs, costs, l)
            },
            |(probs, costs, l)| {
                let idx = select_offline(probs, costs, *l);
                let u_star = l.utility(probs[idx], &costs[idx]);
                for i in 0..probs.len() {
                    let u = l.utility(probs[i], &costs[i]);
                    prop_assert(
                        u <= u_star + 1e-12,
                        format!("strategy {i} has utility {u} > selected {u_star}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_monotone_penalty_never_increases_cost() {
        // raising λ_T can only weakly decrease the token cost of the
        // selected strategy (a classic envelope argument — and a real
        // invariant the paper's Fig 2 relies on).
        forall(
            "selection cost monotone in λ_T",
            150,
            |rng| {
                let n = rng.range(2, 10) as usize;
                let probs = gen_vec(rng, n..n + 1, |r| r.f64());
                let costs = gen_vec(rng, n..n + 1, |r| {
                    est(r.f64() * 1000.0, r.f64() * 10000.0)
                });
                (probs, costs)
            },
            |(probs, costs)| {
                let grid = [0.0, 1e-5, 1e-4, 1e-3, 1e-2];
                let mut prev_tokens = f64::INFINITY;
                for &lt in &grid {
                    let idx = select_offline(probs, costs, Lambdas::new(lt, 0.0));
                    prop_assert(
                        costs[idx].tokens <= prev_tokens + 1e-9,
                        format!("tokens increased from {prev_tokens} at λ_T={lt}"),
                    )?;
                    prev_tokens = costs[idx].tokens;
                }
                Ok(())
            },
        );
    }
}
