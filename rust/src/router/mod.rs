//! The utility-maximizing router (paper §2.2–§2.4).
//!
//! For each query `x` the router evaluates every strategy `s ∈ S`:
//!
//! ```text
//! U_s(x) = â_s(x) − λ_T · T̂_s(x) − λ_L · L̂_s(x)
//! s*(x)  = argmax_s U_s(x)
//! ```
//!
//! `â` comes from the Platt-calibrated probe (one embed call + one batched
//! probe-forward over all strategies), `T̂`/`L̂` from the per-strategy cost
//! model. [`select_offline`] is the same argmax over precomputed tables —
//! used by every figure sweep so that λ grids cost microseconds per point.
//!
//! With a per-request deadline the λ_L sweep becomes a *constraint*
//! ([`Router::select_budgeted`]): costs come from the budget-bucket
//! table ([`CostModel::get_budgeted`]), and [`pick_feasible`] excludes
//! strategies whose predicted (truncated) latency still exceeds the
//! deadline whenever a feasible alternative exists — falling back to the
//! lowest-latency strategy when nothing fits.

use crate::costmodel::{CostEstimate, CostModel};
use crate::engine::EngineHandle;
use crate::error::Result;
use crate::probe::{CalibratedProbe, FeatureBuilder};
use crate::strategies::{Budget, Strategy};
use crate::tokenizer::Tokenizer;

/// Scored strategy for one query.
#[derive(Debug, Clone)]
pub struct StrategyScore {
    pub strategy: Strategy,
    /// Calibrated accuracy prediction â_s(x) — fitted on *untruncated*
    /// runs, which is why feasibility filters on `full_latency_ms`, not
    /// on the (possibly truncated) `cost`.
    pub acc_hat: f64,
    /// Cost under the request's deadline bucket (equals the unbudgeted
    /// mean when there is no deadline).
    pub cost: CostEstimate,
    /// Unbudgeted predicted latency — how long the strategy needs to
    /// complete its *configured* work. The deadline-feasibility filter
    /// uses this: a strategy that only "fits" because preemption will
    /// cut its work short would realize far less accuracy than â
    /// predicts.
    pub full_latency_ms: f64,
    pub utility: f64,
}

/// Penalty weights (user preference knobs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lambdas {
    /// λ_T — per generated token.
    pub token: f64,
    /// λ_L — per millisecond of latency.
    pub latency: f64,
}

impl Lambdas {
    pub fn new(token: f64, latency: f64) -> Lambdas {
        Lambdas { token, latency }
    }

    pub fn utility(&self, acc_hat: f64, cost: &CostEstimate) -> f64 {
        acc_hat - self.token * cost.tokens - self.latency * cost.latency_ms
    }
}

/// The query-adaptive router.
pub struct Router {
    pub strategies: Vec<Strategy>,
    pub probe: CalibratedProbe,
    pub costs: CostModel,
    pub features: FeatureBuilder,
    /// Pre-rendered strategy ids (parallel to `strategies`): cost-model
    /// keys on the per-request hot path — rendering an id consults the
    /// decoding-method registry, which must not happen per request.
    ids: Vec<String>,
    tokenizer: Tokenizer,
}

impl Router {
    pub fn new(
        strategies: Vec<Strategy>,
        probe: CalibratedProbe,
        costs: CostModel,
        features: FeatureBuilder,
    ) -> Router {
        let ids = strategies.iter().map(|s| s.id()).collect();
        Router {
            strategies,
            probe,
            costs,
            features,
            ids,
            tokenizer: Tokenizer::new(),
        }
    }

    /// Score every strategy for a query (probe â + cost model). With a
    /// deadline, costs come from the budget-bucket table.
    pub fn score_all(
        &self,
        engine: &EngineHandle,
        query: &str,
        lambdas: Lambdas,
        deadline_ms: Option<f64>,
    ) -> Result<Vec<StrategyScore>> {
        let query_ids = self.tokenizer.encode(query)?;
        let emb = engine
            .embed(self.probe.embed_kind, vec![query_ids.clone()])?
            .pop()
            .expect("one embedding for one query");
        let feats: Vec<Vec<f32>> = self
            .strategies
            .iter()
            .map(|s| self.features.build(&emb, s, query_ids.len()))
            .collect();
        let probs = self.probe.predict(engine, feats)?;
        self.strategies
            .iter()
            .zip(&self.ids)
            .zip(probs)
            .map(|((s, id), acc_hat)| {
                let cost = self.costs.get_budgeted(id, deadline_ms)?;
                let full_latency_ms = self.costs.get(id)?.latency_ms;
                Ok(StrategyScore {
                    strategy: s.clone(),
                    acc_hat,
                    cost,
                    full_latency_ms,
                    utility: lambdas.utility(acc_hat, &cost),
                })
            })
            .collect()
    }

    /// `s*(x)` — the utility argmax (paper §2.3), no budget constraint.
    pub fn select(
        &self,
        engine: &EngineHandle,
        query: &str,
        lambdas: Lambdas,
    ) -> Result<StrategyScore> {
        let scores = self.score_all(engine, query, lambdas, None)?;
        Ok(pick_max(&scores))
    }

    /// Budget-aware `s*(x)`: utilities use the budget-bucket cost table
    /// and strategies whose predicted latency exceeds the request
    /// deadline are excluded whenever a feasible alternative exists.
    pub fn select_budgeted(
        &self,
        engine: &EngineHandle,
        query: &str,
        lambdas: Lambdas,
        budget: &Budget,
    ) -> Result<StrategyScore> {
        let scores = self.score_all(engine, query, lambdas, budget.deadline_ms)?;
        Ok(pick_feasible(&scores, budget.deadline_ms))
    }
}

fn pick_max(scores: &[StrategyScore]) -> StrategyScore {
    assert!(!scores.is_empty());
    scores
        .iter()
        .max_by(|a, b| {
            a.utility
                .partial_cmp(&b.utility)
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap()
        .clone()
}

/// Deadline-constrained argmax: the best-utility strategy among those
/// predicted to *complete their configured work* within the deadline
/// (`full_latency_ms ≤ d` — the probe's â is fitted on untruncated
/// runs, so a strategy that merely gets preempted into "fitting" would
/// realize far less accuracy than its utility claims). When nothing
/// fits, fall back to the lowest full predicted latency (best-effort
/// degradation — the engine preempts it mid-call anyway); without a
/// deadline this is exactly [`pick_max`]. Pure — benched and
/// property-tested offline.
pub fn pick_feasible(scores: &[StrategyScore], deadline_ms: Option<f64>) -> StrategyScore {
    assert!(!scores.is_empty());
    let Some(d) = deadline_ms else {
        return pick_max(scores);
    };
    let feasible: Vec<StrategyScore> = scores
        .iter()
        .filter(|s| s.full_latency_ms <= d)
        .cloned()
        .collect();
    if feasible.is_empty() {
        return scores
            .iter()
            .min_by(|a, b| {
                a.full_latency_ms
                    .partial_cmp(&b.full_latency_ms)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap()
            .clone();
    }
    pick_max(&feasible)
}

/// A leftover-budget grant to one still-running request (the online
/// half of the paper's per-query allocation): the serving layer applies
/// it *between* strategy steps by extending the machine's existing
/// limits. A grant never adds a limit a request didn't have — extending
/// an unlimited budget is meaningless, and imposing a new deadline
/// would restrict, not grant.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Grant {
    /// Extra milliseconds added to the request's relative deadline.
    pub extra_ms: f64,
    /// Extra tokens added to the request's token cap.
    pub extra_tokens: usize,
}

impl Grant {
    pub fn is_empty(&self) -> bool {
        self.extra_ms <= 0.0 && self.extra_tokens == 0
    }
}

/// The budget a finished request left on the table.
#[derive(Debug, Clone)]
pub struct FinishedRequest<'a> {
    /// Strategy id of the finished request.
    pub strategy_id: &'a str,
    /// Deadline headroom at completion (deadline minus finish time; 0
    /// for unlimited or overrun requests).
    pub leftover_ms: f64,
    /// Unspent tokens under the request's cap (0 when uncapped).
    pub leftover_tokens: usize,
}

/// Read-only view of one still-running step machine, for reallocation
/// decisions.
#[derive(Debug)]
pub struct RunningView<'a> {
    pub strategy_id: &'a str,
    pub budget: &'a Budget,
    /// Time this request has been running, ms.
    pub elapsed_ms: f64,
}

/// Between-steps budget reallocation: when a request finishes with
/// leftover budget, decide what each still-running request is granted.
/// Called by the continuation executor
/// ([`crate::strategies::stepper::Stepper`]) every time a machine
/// completes; the returned vector is parallel to `running` (shorter is
/// allowed — missing tails get nothing). Implementations must be cheap:
/// this runs on the serving hot path.
pub trait Reallocator: Send {
    fn reallocate(
        &mut self,
        finished: &FinishedRequest<'_>,
        running: &[RunningView<'_>],
    ) -> Vec<Grant>;
}

/// Even-share pool: a finished request's leftover deadline headroom is
/// split evenly across the running requests that carry a deadline, and
/// its unspent token cap across those that carry a token cap — the
/// simplest defensible policy, and deliberately conservative: requests
/// with unlimited budgets take (and need) nothing.
#[derive(Debug, Default)]
pub struct EvenShareReallocator;

impl Reallocator for EvenShareReallocator {
    fn reallocate(
        &mut self,
        finished: &FinishedRequest<'_>,
        running: &[RunningView<'_>],
    ) -> Vec<Grant> {
        let ms_takers = running
            .iter()
            .filter(|r| r.budget.deadline_ms.is_some())
            .count();
        let tok_takers = running
            .iter()
            .filter(|r| r.budget.max_tokens.is_some())
            .count();
        running
            .iter()
            .map(|r| Grant {
                extra_ms: if r.budget.deadline_ms.is_some() && ms_takers > 0 {
                    finished.leftover_ms / ms_takers as f64
                } else {
                    0.0
                },
                extra_tokens: if r.budget.max_tokens.is_some() && tok_takers > 0 {
                    finished.leftover_tokens / tok_takers
                } else {
                    0
                },
            })
            .collect()
    }
}

/// Splits ONE chain-level budget across a chain's dependent steps and
/// re-splits the remainder after every completion — the cross-step
/// sibling of [`Reallocator`] (which moves leftover budget *between*
/// unrelated requests; this moves it *along* one chain).
///
/// At construction the chain totals (deadline headroom relative to the
/// chain's start, token cap) are divided over all steps proportionally
/// to their difficulty weights, and those *nominal* shares are frozen.
/// Each [`ChainAllocator::slice`] call instead divides what is actually
/// left — total minus elapsed wall-clock, total minus charged tokens —
/// over the *remaining* steps, so a step that under-spends banks its
/// surplus for every later step. The positive excess of a slice over
/// its frozen nominal share is reported as a [`Grant`] and counted:
/// routed through `Router::select_budgeted`, a widened slice can make a
/// stronger strategy feasible for a later, harder step.
#[derive(Debug, Clone)]
pub struct ChainAllocator {
    /// Chain-wide deadline (ms, relative to chain start); `None` = none.
    total_ms: Option<f64>,
    /// Chain-wide token cap; `None` = uncapped.
    total_tokens: Option<usize>,
    /// Per-step difficulty weights (all > 0).
    weights: Vec<f64>,
    /// Per-step shares of the static split, frozen at construction.
    nominal_ms: Vec<f64>,
    nominal_tokens: Vec<usize>,
    spent_tokens: usize,
    /// Number of slices that exceeded their nominal share.
    pub grants: usize,
    /// Total deadline headroom granted beyond nominal shares, ms.
    pub granted_ms: f64,
    /// Total tokens granted beyond nominal shares.
    pub granted_tokens: usize,
}

impl ChainAllocator {
    /// `budget` carries the chain totals (an unlimited budget yields
    /// unlimited slices and no grants); `weights` is one positive
    /// difficulty weight per step.
    pub fn new(budget: &Budget, weights: &[f64]) -> ChainAllocator {
        assert!(!weights.is_empty(), "a chain has at least one step");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "step weights must be positive"
        );
        let wsum: f64 = weights.iter().sum();
        let nominal_ms = weights
            .iter()
            .map(|w| budget.deadline_ms.map_or(0.0, |t| t * w / wsum))
            .collect();
        let nominal_tokens = weights
            .iter()
            .map(|w| {
                budget
                    .max_tokens
                    .map_or(0, |t| ((t as f64) * w / wsum).floor() as usize)
            })
            .collect();
        ChainAllocator {
            total_ms: budget.deadline_ms,
            total_tokens: budget.max_tokens,
            weights: weights.to_vec(),
            nominal_ms,
            nominal_tokens,
            spent_tokens: 0,
            grants: 0,
            granted_ms: 0.0,
            granted_tokens: 0,
        }
    }

    /// Number of steps this allocator splits over.
    pub fn steps(&self) -> usize {
        self.weights.len()
    }

    /// The current slice for `step`, given the chain's elapsed
    /// wall-clock: the remaining pool divided over the remaining steps
    /// by weight (the final step takes the whole remainder). Pure in
    /// its inputs apart from the grant counters. The returned deadline
    /// is relative to the *step machine's* start, which is how
    /// [`Budget`] deadlines are interpreted everywhere.
    pub fn slice(&mut self, step: usize, elapsed_ms: f64) -> (Budget, Grant) {
        assert!(step < self.weights.len(), "step {step} out of range");
        let wsum: f64 = self.weights[step..].iter().sum();
        let frac = self.weights[step] / wsum;
        let last = step + 1 == self.weights.len();
        let mut budget = Budget::unlimited();
        let mut grant = Grant::default();
        if let Some(total) = self.total_ms {
            let remaining = (total - elapsed_ms).max(0.0);
            let share = remaining * frac;
            budget = budget.with_deadline_ms(share);
            let excess = share - self.nominal_ms[step];
            if excess > 1e-9 {
                grant.extra_ms = excess;
            }
        }
        if let Some(total) = self.total_tokens {
            let remaining = total.saturating_sub(self.spent_tokens);
            let share = if last {
                remaining
            } else {
                ((remaining as f64) * frac).floor() as usize
            };
            budget = budget.with_max_tokens(share);
            if share > self.nominal_tokens[step] {
                grant.extra_tokens = share - self.nominal_tokens[step];
            }
        }
        if !grant.is_empty() {
            self.grants += 1;
            self.granted_ms += grant.extra_ms;
            self.granted_tokens += grant.extra_tokens;
        }
        (budget, grant)
    }

    /// Charge a completed step's token spend against the chain pool.
    pub fn charge(&mut self, tokens: usize) {
        self.spent_tokens = self.spent_tokens.saturating_add(tokens);
    }

    /// True once the chain pool is spent — past the chain deadline or
    /// out of tokens. An exhausted chain admits no further steps and
    /// reports partial completion with `budget_exhausted`.
    pub fn exhausted(&self, elapsed_ms: f64) -> bool {
        self.total_ms.is_some_and(|t| elapsed_ms >= t)
            || self.total_tokens.is_some_and(|t| self.spent_tokens >= t)
    }

    /// The frozen static split for one step — what the step would get
    /// with no cross-step reallocation. The equal-total-budget baseline
    /// the chain tier's accuracy tests compare against.
    pub fn nominal_budget(&self, step: usize) -> Budget {
        let mut b = Budget::unlimited();
        if self.total_ms.is_some() {
            b = b.with_deadline_ms(self.nominal_ms[step]);
        }
        if self.total_tokens.is_some() {
            b = b.with_max_tokens(self.nominal_tokens[step]);
        }
        b
    }
}

/// Offline argmax over precomputed per-strategy (â, cost) tables — the
/// figure-sweep hot path. Returns the winning index.
pub fn select_offline(probs: &[f64], costs: &[CostEstimate], lambdas: Lambdas) -> usize {
    debug_assert_eq!(probs.len(), costs.len());
    let mut best = 0;
    let mut best_u = f64::NEG_INFINITY;
    for i in 0..probs.len() {
        let u = lambdas.utility(probs[i], &costs[i]);
        if u > best_u {
            best_u = u;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};

    fn est(tokens: f64, latency_ms: f64) -> CostEstimate {
        CostEstimate { tokens, latency_ms }
    }

    #[test]
    fn utility_formula() {
        let l = Lambdas::new(0.001, 0.0001);
        let u = l.utility(0.8, &est(100.0, 1000.0));
        assert!((u - (0.8 - 0.1 - 0.1)).abs() < 1e-12);
    }

    #[test]
    fn zero_penalty_picks_highest_accuracy() {
        let probs = [0.3, 0.9, 0.5];
        let costs = [est(10.0, 10.0), est(9999.0, 99999.0), est(1.0, 1.0)];
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(0.0, 0.0)), 1);
    }

    #[test]
    fn high_token_penalty_prefers_cheap() {
        let probs = [0.5, 0.9];
        let costs = [est(10.0, 10.0), est(1000.0, 10.0)];
        // Δacc = 0.4; Δtokens = 990 → switch at λ_T ≈ 0.000404
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(1e-5, 0.0)), 1);
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(1e-3, 0.0)), 0);
    }

    #[test]
    fn latency_penalty_independent_of_tokens() {
        let probs = [0.5, 0.9];
        // same tokens, very different latency (the beam-search signature)
        let costs = [est(100.0, 100.0), est(100.0, 10_000.0)];
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(0.0, 0.0)), 1);
        assert_eq!(select_offline(&probs, &costs, Lambdas::new(0.0, 1e-4)), 0);
    }

    #[test]
    fn prop_selected_utility_is_max() {
        forall(
            "offline argmax is argmax",
            200,
            |rng| {
                let n = rng.range(1, 12) as usize;
                let probs = gen_vec(rng, n..n + 1, |r| r.f64());
                let costs = gen_vec(rng, n..n + 1, |r| {
                    est(r.f64() * 1000.0, r.f64() * 10000.0)
                });
                let l = Lambdas::new(rng.f64() * 1e-2, rng.f64() * 1e-3);
                (probs, costs, l)
            },
            |(probs, costs, l)| {
                let idx = select_offline(probs, costs, *l);
                let u_star = l.utility(probs[idx], &costs[idx]);
                for i in 0..probs.len() {
                    let u = l.utility(probs[i], &costs[i]);
                    prop_assert(
                        u <= u_star + 1e-12,
                        format!("strategy {i} has utility {u} > selected {u_star}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    fn score(n: usize, acc_hat: f64, cost: CostEstimate) -> StrategyScore {
        StrategyScore {
            strategy: Strategy::mv(n),
            acc_hat,
            // unbudgeted latency = the cost estimate's latency (no
            // truncation in these synthetic tables)
            full_latency_ms: cost.latency_ms,
            cost,
            utility: acc_hat, // λ = 0 shape: utility is accuracy
        }
    }

    #[test]
    fn feasible_alternative_excludes_slow_strategy() {
        // the slow strategy has the best utility but cannot meet the
        // deadline; a feasible alternative exists → it must win
        let scores = vec![
            score(2, 0.5, est(100.0, 80.0)),
            score(16, 0.9, est(2000.0, 5000.0)),
        ];
        let picked = pick_feasible(&scores, Some(100.0));
        assert_eq!(picked.strategy, Strategy::mv(2));
        // without a deadline the slow one wins on utility
        assert_eq!(pick_feasible(&scores, None).strategy, Strategy::mv(16));
    }

    #[test]
    fn truncated_into_fitting_is_still_infeasible() {
        // a heavily-truncated expensive strategy whose *bucketed* cost
        // fits the deadline must not beat a strategy that completes its
        // configured work in time — â is fitted on untruncated runs
        let cheap_complete = score(2, 0.6, est(100.0, 80.0));
        let mut truncated_beam = score(16, 0.9, est(0.0, 0.0)); // 0 rounds fit
        truncated_beam.full_latency_ms = 3000.0;
        let scores = vec![cheap_complete, truncated_beam];
        let picked = pick_feasible(&scores, Some(200.0));
        assert_eq!(picked.strategy, Strategy::mv(2));
    }

    #[test]
    fn nothing_feasible_falls_back_to_fastest() {
        let scores = vec![
            score(4, 0.7, est(500.0, 900.0)),
            score(8, 0.9, est(900.0, 1800.0)),
        ];
        let picked = pick_feasible(&scores, Some(10.0));
        assert_eq!(picked.strategy, Strategy::mv(4));
    }

    #[test]
    fn prop_never_picks_infeasible_when_feasible_exists() {
        forall(
            "feasible-alternative constraint",
            200,
            |rng| {
                let n = rng.range(1, 10) as usize;
                let scores: Vec<(f64, f64, f64)> = gen_vec(rng, n..n + 1, |r| {
                    (r.f64(), r.f64() * 1000.0, r.f64() * 10000.0)
                });
                let d = rng.f64() * 10000.0;
                (scores, d)
            },
            |(raw, d)| {
                let scores: Vec<StrategyScore> = raw
                    .iter()
                    .enumerate()
                    .map(|(i, &(a, t, l))| score(i + 1, a, est(t, l)))
                    .collect();
                let picked = pick_feasible(&scores, Some(*d));
                let any_feasible = scores.iter().any(|s| s.full_latency_ms <= *d);
                if any_feasible {
                    prop_assert(
                        picked.full_latency_ms <= *d,
                        format!(
                            "picked latency {} exceeds deadline {d} with a feasible \
                             alternative present",
                            picked.full_latency_ms
                        ),
                    )?;
                    // and it is the utility argmax among feasible ones
                    for s in scores.iter().filter(|s| s.full_latency_ms <= *d) {
                        prop_assert(
                            s.utility <= picked.utility + 1e-12,
                            "not the feasible argmax".to_string(),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn even_share_splits_among_limited_budgets_only() {
        let with_deadline = Budget::unlimited().with_deadline_ms(500.0);
        let with_cap = Budget::unlimited().with_max_tokens(100);
        let unlimited = Budget::unlimited();
        let running = [
            RunningView { strategy_id: "beam@4x2c12", budget: &with_deadline, elapsed_ms: 10.0 },
            RunningView { strategy_id: "beam@4x2c12", budget: &with_deadline, elapsed_ms: 20.0 },
            RunningView { strategy_id: "mv_early@16", budget: &with_cap, elapsed_ms: 5.0 },
            RunningView { strategy_id: "majority_vote@4", budget: &unlimited, elapsed_ms: 1.0 },
        ];
        let finished = FinishedRequest {
            strategy_id: "majority_vote@2",
            leftover_ms: 100.0,
            leftover_tokens: 60,
        };
        let grants = EvenShareReallocator.reallocate(&finished, &running);
        assert_eq!(grants.len(), 4);
        // deadline headroom split between the two deadline-carrying
        // requests, tokens to the one capped request, nothing to the
        // unlimited one
        assert_eq!(grants[0], Grant { extra_ms: 50.0, extra_tokens: 0 });
        assert_eq!(grants[1], Grant { extra_ms: 50.0, extra_tokens: 0 });
        assert_eq!(grants[2], Grant { extra_ms: 0.0, extra_tokens: 60 });
        assert!(grants[3].is_empty());
    }

    #[test]
    fn even_share_no_takers_grants_nothing() {
        let unlimited = Budget::unlimited();
        let running = [RunningView {
            strategy_id: "mv@2",
            budget: &unlimited,
            elapsed_ms: 0.0,
        }];
        let finished = FinishedRequest {
            strategy_id: "beam@4x2c12",
            leftover_ms: 1000.0,
            leftover_tokens: 1000,
        };
        let grants = EvenShareReallocator.reallocate(&finished, &running);
        assert!(grants.iter().all(Grant::is_empty));
        // and an empty running set is fine
        assert!(EvenShareReallocator.reallocate(&finished, &[]).is_empty());
    }

    #[test]
    fn prop_even_share_conserves_budget() {
        // grants never exceed what the finished request left over
        forall(
            "reallocation conserves the pool",
            200,
            |rng| {
                let n = rng.range(0, 8) as usize;
                let kinds: Vec<u64> = gen_vec(rng, n..n + 1, |r| r.below(3));
                let leftover_ms = rng.f64() * 1000.0;
                let leftover_tokens = rng.below(500) as usize;
                (kinds, leftover_ms, leftover_tokens)
            },
            |(kinds, leftover_ms, leftover_tokens)| {
                let budgets: Vec<Budget> = kinds
                    .iter()
                    .map(|k| match k {
                        0 => Budget::unlimited(),
                        1 => Budget::unlimited().with_deadline_ms(100.0),
                        _ => Budget::unlimited()
                            .with_deadline_ms(100.0)
                            .with_max_tokens(64),
                    })
                    .collect();
                let running: Vec<RunningView<'_>> = budgets
                    .iter()
                    .map(|b| RunningView {
                        strategy_id: "s",
                        budget: b,
                        elapsed_ms: 0.0,
                    })
                    .collect();
                let finished = FinishedRequest {
                    strategy_id: "f",
                    leftover_ms: *leftover_ms,
                    leftover_tokens: *leftover_tokens,
                };
                let grants = EvenShareReallocator.reallocate(&finished, &running);
                let ms: f64 = grants.iter().map(|g| g.extra_ms).sum();
                let toks: usize = grants.iter().map(|g| g.extra_tokens).sum();
                prop_assert(
                    ms <= leftover_ms + 1e-9 && toks <= *leftover_tokens,
                    format!("granted ms {ms} / tokens {toks} exceed the pool"),
                )?;
                // and grants only go to requests that carry the limit
                for (g, b) in grants.iter().zip(&budgets) {
                    prop_assert(
                        (g.extra_ms == 0.0 || b.deadline_ms.is_some())
                            && (g.extra_tokens == 0 || b.max_tokens.is_some()),
                        "grant to a request without that limit".to_string(),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn chain_allocator_nominal_split_and_banking() {
        let budget = Budget::unlimited()
            .with_deadline_ms(3000.0)
            .with_max_tokens(600);
        let mut a = ChainAllocator::new(&budget, &[1.0, 1.0, 1.0]);
        assert_eq!(a.steps(), 3);
        let (b0, g0) = a.slice(0, 0.0);
        assert!((b0.deadline_ms.unwrap() - 1000.0).abs() < 1e-9);
        assert_eq!(b0.max_tokens, Some(200));
        assert!(g0.is_empty(), "first slice at t=0 is exactly nominal");
        // step 0 finishes early and cheap: banks 600 ms and 100 tokens
        a.charge(100);
        let (b1, g1) = a.slice(1, 400.0);
        assert!((b1.deadline_ms.unwrap() - 1300.0).abs() < 1e-9);
        assert_eq!(b1.max_tokens, Some(250));
        assert!((g1.extra_ms - 300.0).abs() < 1e-9);
        assert_eq!(g1.extra_tokens, 50);
        assert_eq!(a.grants, 1);
        // the final step takes the whole remainder
        a.charge(250);
        let (b2, _) = a.slice(2, 1700.0);
        assert!((b2.deadline_ms.unwrap() - 1300.0).abs() < 1e-9);
        assert_eq!(b2.max_tokens, Some(250));
        assert!((a.granted_ms - 600.0).abs() < 1e-9);
        assert_eq!(a.granted_tokens, 100);
    }

    #[test]
    fn chain_allocator_overrun_and_exhaustion() {
        let mut a =
            ChainAllocator::new(&Budget::unlimited().with_deadline_ms(1000.0), &[1.0, 1.0]);
        assert!(!a.exhausted(999.0));
        // blowing the chain deadline leaves a zero slice, not a negative one
        let (b, g) = a.slice(1, 1500.0);
        assert_eq!(b.deadline_ms, Some(0.0));
        assert!(g.is_empty());
        assert!(a.exhausted(1500.0));
        // token-side exhaustion
        let mut t = ChainAllocator::new(&Budget::unlimited().with_max_tokens(100), &[1.0]);
        assert!(!t.exhausted(0.0));
        t.charge(100);
        assert!(t.exhausted(0.0));
    }

    #[test]
    fn chain_allocator_unlimited_budget_slices_unlimited() {
        let mut a = ChainAllocator::new(&Budget::unlimited(), &[1.0, 2.0]);
        let (b, g) = a.slice(0, 123.0);
        assert!(b.deadline_ms.is_none() && b.max_tokens.is_none());
        assert!(g.is_empty());
        assert_eq!(a.grants, 0);
    }

    #[test]
    fn prop_chain_allocator_conserves_and_banks() {
        // Running each step inside its slice must (a) never let the
        // chain exceed its totals and (b) never shrink a later slice
        // below its frozen nominal share — under-spending can only buy
        // later steps more, which is the whole point of the banking.
        forall(
            "chain slices conserve the pool",
            200,
            |rng| {
                let n = rng.range(1, 6) as usize;
                let weights = gen_vec(rng, n..n + 1, |r| 0.5 + r.f64() * 2.0);
                let total_ms = 500.0 + rng.f64() * 5000.0;
                let total_tokens = 100 + rng.below(2000) as usize;
                // per-step fraction of its slice actually spent
                let spend = gen_vec(rng, n..n + 1, |r| r.f64());
                (weights, total_ms, total_tokens, spend)
            },
            |(weights, total_ms, total_tokens, spend)| {
                let budget = Budget::unlimited()
                    .with_deadline_ms(*total_ms)
                    .with_max_tokens(*total_tokens);
                let mut a = ChainAllocator::new(&budget, weights);
                let mut elapsed = 0.0f64;
                let mut spent = 0usize;
                for (i, frac) in spend.iter().enumerate() {
                    let (b, grant) = a.slice(i, elapsed);
                    let slice_ms = b.deadline_ms.expect("deadline slice");
                    let slice_toks = b.max_tokens.expect("token slice");
                    let nominal = a.nominal_budget(i);
                    prop_assert(
                        slice_ms >= nominal.deadline_ms.unwrap() - 1e-9,
                        "under-spending predecessors shrank a later ms slice".to_string(),
                    )?;
                    prop_assert(
                        slice_toks >= nominal.max_tokens.unwrap(),
                        "under-spending predecessors shrank a later token slice".to_string(),
                    )?;
                    prop_assert(
                        grant.extra_tokens == slice_toks - nominal.max_tokens.unwrap(),
                        "token grant must equal the excess over nominal".to_string(),
                    )?;
                    let used_ms = slice_ms * frac;
                    let used_toks = ((slice_toks as f64) * frac) as usize;
                    elapsed += used_ms;
                    spent += used_toks;
                    a.charge(used_toks);
                }
                prop_assert(
                    elapsed <= *total_ms + 1e-6,
                    format!("chain wall-clock {elapsed} exceeds total {total_ms}"),
                )?;
                prop_assert(
                    spent <= *total_tokens,
                    format!("chain tokens {spent} exceed cap {total_tokens}"),
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn prop_monotone_penalty_never_increases_cost() {
        // raising λ_T can only weakly decrease the token cost of the
        // selected strategy (a classic envelope argument — and a real
        // invariant the paper's Fig 2 relies on).
        forall(
            "selection cost monotone in λ_T",
            150,
            |rng| {
                let n = rng.range(2, 10) as usize;
                let probs = gen_vec(rng, n..n + 1, |r| r.f64());
                let costs = gen_vec(rng, n..n + 1, |r| {
                    est(r.f64() * 1000.0, r.f64() * 10000.0)
                });
                (probs, costs)
            },
            |(probs, costs)| {
                let grid = [0.0, 1e-5, 1e-4, 1e-3, 1e-2];
                let mut prev_tokens = f64::INFINITY;
                for &lt in &grid {
                    let idx = select_offline(probs, costs, Lambdas::new(lt, 0.0));
                    prop_assert(
                        costs[idx].tokens <= prev_tokens + 1e-9,
                        format!("tokens increased from {prev_tokens} at λ_T={lt}"),
                    )?;
                    prev_tokens = costs[idx].tokens;
                }
                Ok(())
            },
        );
    }
}
