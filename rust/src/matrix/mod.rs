//! The evaluation matrix: `M[query, strategy, repeat] → (correct, tokens,
//! latency)`.
//!
//! One expensive collection pass per split feeds everything downstream:
//! probe soft labels (train split), Platt calibration (calib split) and
//! every figure sweep (test split) are *offline recomputations* over this
//! matrix — no figure re-runs generation. Collection appends each record
//! to the output JSONL as it lands, so an interrupted run resumes where
//! it stopped.

use crate::data::Query;
use crate::error::Result;
use crate::strategies::{Executor, Strategy};
use crate::util::json::Value;
use crate::util::stats;
use crate::log_info;
use std::collections::{HashMap, HashSet};
use std::path::Path;

/// One strategy run on one query.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixEntry {
    pub query_id: String,
    pub split: String,
    pub strategy: String,
    pub repeat: usize,
    /// Query difficulty (CoT steps).
    pub k: usize,
    pub correct: bool,
    pub tokens: usize,
    pub latency_ms: f64,
    /// Completed generation rounds (1 for single-batch parallel methods).
    /// Feeds the budget-bucket cost model's rounds-completed prediction
    /// for the beam family. Old matrices without the field load as 1.
    pub rounds: usize,
}

impl MatrixEntry {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("query_id", self.query_id.as_str())
            .with("split", self.split.as_str())
            .with("strategy", self.strategy.as_str())
            .with("repeat", self.repeat)
            .with("k", self.k)
            .with("correct", self.correct)
            .with("tokens", self.tokens)
            .with("latency_ms", self.latency_ms)
            .with("rounds", self.rounds)
    }

    pub fn from_json(v: &Value) -> Result<MatrixEntry> {
        Ok(MatrixEntry {
            query_id: v.req_str("query_id")?.to_string(),
            split: v.req_str("split")?.to_string(),
            strategy: v.req_str("strategy")?.to_string(),
            repeat: v.req_usize("repeat")?,
            k: v.req_usize("k")?,
            correct: v.opt_bool("correct", false),
            tokens: v.req_usize("tokens")?,
            latency_ms: v.req_f64("latency_ms")?,
            rounds: v.get("rounds").and_then(Value::as_usize).unwrap_or(1),
        })
    }
}

/// Aggregate over repeats of one (query, strategy) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellAgg {
    /// Empirical success probability (soft label).
    pub acc: f64,
    pub tokens: f64,
    pub latency_ms: f64,
    pub repeats: usize,
}

/// A loaded matrix with cell aggregation.
#[derive(Debug, Default)]
pub struct Matrix {
    pub entries: Vec<MatrixEntry>,
}

impl Matrix {
    pub fn load(path: &Path) -> Result<Matrix> {
        if !path.exists() {
            return Ok(Matrix::default());
        }
        let entries = crate::data::read_jsonl(path)?
            .iter()
            .map(MatrixEntry::from_json)
            .collect::<Result<_>>()?;
        Ok(Matrix { entries })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Existing (query, strategy, repeat) triples — for resume.
    pub fn existing_keys(&self) -> HashSet<(String, String, usize)> {
        self.entries
            .iter()
            .map(|e| (e.query_id.clone(), e.strategy.clone(), e.repeat))
            .collect()
    }

    /// Aggregate to (query_id, strategy) cells.
    pub fn cells(&self) -> HashMap<(String, String), CellAgg> {
        let mut groups: HashMap<(String, String), Vec<&MatrixEntry>> = HashMap::new();
        for e in &self.entries {
            groups
                .entry((e.query_id.clone(), e.strategy.clone()))
                .or_default()
                .push(e);
        }
        groups
            .into_iter()
            .map(|(key, es)| {
                let accs: Vec<f64> = es.iter().map(|e| e.correct as u8 as f64).collect();
                let toks: Vec<f64> = es.iter().map(|e| e.tokens as f64).collect();
                let lats: Vec<f64> = es.iter().map(|e| e.latency_ms).collect();
                (
                    key,
                    CellAgg {
                        acc: stats::mean(&accs),
                        tokens: stats::mean(&toks),
                        latency_ms: stats::mean(&lats),
                        repeats: es.len(),
                    },
                )
            })
            .collect()
    }

    /// All strategy ids present, sorted.
    pub fn strategy_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self
            .entries
            .iter()
            .map(|e| e.strategy.clone())
            .collect::<HashSet<_>>()
            .into_iter()
            .collect();
        ids.sort();
        ids
    }
}

/// Collect (or resume) the matrix for one split, appending to `out`.
pub fn collect(
    executor: &Executor,
    queries: &[Query],
    split: &str,
    strategies: &[Strategy],
    repeats: usize,
    out: &Path,
) -> Result<Matrix> {
    let mut matrix = Matrix::load(out)?;
    let done = matrix.existing_keys();
    let total = queries.len() * strategies.len() * repeats;
    let mut completed = matrix.entries.len();
    log_info!(
        "collect[{split}]: {} queries × {} strategies × {repeats} repeats = {total} runs \
         ({completed} already done)",
        queries.len(),
        strategies.len()
    );

    // Warmup: run every strategy once on a throwaway query so lazy
    // executable compilation (seconds per module) never pollutes the
    // latency measurements of real cells.
    if completed < total {
        if let Some(q) = queries.first() {
            log_info!("collect[{split}]: warmup over {} strategies", strategies.len());
            for strategy in strategies {
                let _ = executor.run(strategy, &q.query)?;
            }
        }
    }
    let t0 = std::time::Instant::now();

    // Interleave strategies per query so partial runs cover the whole
    // space (better for early probe experiments on interrupted data).
    for repeat in 0..repeats {
        for query in queries {
            for strategy in strategies {
                let key = (query.id.clone(), strategy.id(), repeat);
                if done.contains(&key) {
                    continue;
                }
                let outcome = executor.run(strategy, &query.query)?;
                let entry = MatrixEntry {
                    query_id: query.id.clone(),
                    split: split.to_string(),
                    strategy: strategy.id(),
                    repeat,
                    k: query.k,
                    correct: outcome.is_correct(&query.answer),
                    tokens: outcome.tokens,
                    latency_ms: outcome.latency_ms,
                    rounds: outcome.rounds.max(1),
                };
                crate::data::append_jsonl(out, &[entry.to_json()])?;
                matrix.entries.push(entry);
                completed += 1;
                if completed % 100 == 0 {
                    let rate = completed as f64 / t0.elapsed().as_secs_f64().max(1e-9);
                    log_info!(
                        "collect[{split}]: {completed}/{total} runs ({rate:.1}/s, \
                         eta {:.0}s)",
                        (total - completed) as f64 / rate.max(1e-9)
                    );
                }
            }
        }
    }
    Ok(matrix)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(q: &str, s: &str, rep: usize, correct: bool, tokens: usize) -> MatrixEntry {
        MatrixEntry {
            query_id: q.into(),
            split: "test".into(),
            strategy: s.into(),
            repeat: rep,
            k: 3,
            correct,
            tokens,
            latency_ms: tokens as f64 * 2.0,
            rounds: 1,
        }
    }

    #[test]
    fn json_roundtrip() {
        let e = entry("q1", "mv@4", 0, true, 120);
        assert_eq!(MatrixEntry::from_json(&e.to_json()).unwrap(), e);
    }

    #[test]
    fn legacy_entries_without_rounds_load_as_one() {
        let v = crate::util::json::parse(
            r#"{"query_id":"q","split":"test","strategy":"mv@4","repeat":0,
                "k":2,"correct":true,"tokens":10,"latency_ms":5.0}"#,
        )
        .unwrap();
        assert_eq!(MatrixEntry::from_json(&v).unwrap().rounds, 1);
    }

    #[test]
    fn cells_aggregate_repeats() {
        let m = Matrix {
            entries: vec![
                entry("q1", "mv@4", 0, true, 100),
                entry("q1", "mv@4", 1, false, 140),
                entry("q1", "mv@4", 2, true, 120),
                entry("q2", "mv@4", 0, false, 80),
            ],
        };
        let cells = m.cells();
        let c = cells[&("q1".to_string(), "mv@4".to_string())];
        assert!((c.acc - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.tokens - 120.0).abs() < 1e-12);
        assert_eq!(c.repeats, 3);
        assert_eq!(cells[&("q2".to_string(), "mv@4".to_string())].repeats, 1);
    }

    #[test]
    fn load_save_resume_keys() {
        let path = std::env::temp_dir().join(format!("ttc_matrix_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let e = entry("q1", "mv@4", 0, true, 100);
        crate::data::append_jsonl(&path, &[e.to_json()]).unwrap();
        let m = Matrix::load(&path).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert!(m
            .existing_keys()
            .contains(&("q1".to_string(), "mv@4".to_string(), 0)));
        assert_eq!(m.strategy_ids(), vec!["mv@4".to_string()]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_empty_matrix() {
        let m = Matrix::load(Path::new("/nonexistent/matrix.jsonl")).unwrap();
        assert!(m.is_empty());
    }
}
