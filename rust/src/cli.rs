//! Hand-rolled command-line parsing (clap is unavailable offline).
//!
//! Grammar: `ttc <subcommand> [--key value]... [--flag]...`
//! Flags may be given as `--key=value` or `--key value`. Unknown flags are
//! errors. Each subcommand declares its accepted keys up front so typos
//! fail fast.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    values: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw process args (excluding argv[0]) against a declaration of
    /// accepted `--key value` options and boolean `--flag`s.
    pub fn parse(
        raw: &[String],
        accepted_values: &[&str],
        accepted_flags: &[&str],
    ) -> Result<Args> {
        let mut iter = raw.iter().peekable();
        let subcommand = iter
            .next()
            .cloned()
            .ok_or_else(|| Error::Config("missing subcommand".into()))?;
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        while let Some(arg) = iter.next() {
            let stripped = arg
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --option, got '{arg}'")))?;
            let (key, inline_value) = match stripped.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            if accepted_flags.contains(&key.as_str()) {
                if inline_value.is_some() {
                    return Err(Error::Config(format!("flag --{key} takes no value")));
                }
                flags.push(key);
            } else if accepted_values.contains(&key.as_str()) {
                let value = match inline_value {
                    Some(v) => v,
                    None => iter
                        .next()
                        .cloned()
                        .ok_or_else(|| Error::Config(format!("--{key} requires a value")))?,
                };
                values.insert(key, value);
            } else {
                return Err(Error::Config(format!(
                    "unknown option --{key} for '{subcommand}'"
                )));
            }
        }
        Ok(Args {
            subcommand,
            values,
            flags,
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt_str(name).unwrap_or(default)
    }

    pub fn req_str(&self, name: &str) -> Result<&str> {
        self.opt_str(name)
            .ok_or_else(|| Error::Config(format!("missing required option --{name}")))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name} must be an integer, got '{s}'"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name} must be an integer, got '{s}'"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name} must be a number, got '{s}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse(
            &raw(&["serve", "--port", "8080", "--verbose", "--rate=2.5"]),
            &["port", "rate"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.usize_or("port", 0).unwrap(), 8080);
        assert_eq!(a.f64_or("rate", 0.0).unwrap(), 2.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(Args::parse(&raw(&["x", "--bogus", "1"]), &["ok"], &[]).is_err());
        assert!(Args::parse(&raw(&["x", "positional"]), &[], &[]).is_err());
        assert!(Args::parse(&raw(&["x", "--need-value"]), &["need-value"], &[]).is_err());
        assert!(Args::parse(&raw(&[]), &[], &[]).is_err());
    }

    #[test]
    fn type_errors_are_clear() {
        let a = Args::parse(&raw(&["x", "--n", "abc"]), &["n"], &[]).unwrap();
        let err = a.usize_or("n", 0).unwrap_err().to_string();
        assert!(err.contains("--n") && err.contains("abc"), "{err}");
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&raw(&["x"]), &["n"], &[]).unwrap();
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.str_or("missing", "d"), "d");
        assert!(a.req_str("missing").is_err());
    }
}
