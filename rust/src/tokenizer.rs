//! Character-level tokenizer shared between the rust request path and the
//! build-time python training stack.
//!
//! The synthetic math domain (see [`crate::taskgen`]) needs only a tiny
//! closed alphabet, so tokenization is a fixed char↔id table. Rust is the
//! system of record: [`Tokenizer::vocab_json`] is written to
//! `artifacts/vocab.json` by `ttc taskgen` and the python trainer loads it,
//! guaranteeing both sides agree exactly.
//!
//! Conventions:
//! * id 0 is `<pad>` (never produced by `encode`);
//! * `\n` doubles as the end-of-sequence marker — the generator emits it
//!   after the final answer and the engine stops decoding on it.

use crate::error::{Error, Result};
use crate::util::json::Value;

/// The fixed alphabet, in id order. Index = token id.
pub const ALPHABET: &[char] = &[
    '\0', // 0: <pad>
    '\n', // 1: end of sequence
    '0', '1', '2', '3', '4', '5', '6', '7', '8', '9', // 2..=11
    '+',  // 12
    '-',  // 13
    '*',  // 14
    '=',  // 15
    '?',  // 16
    ';',  // 17
    ':',  // 18
    'Q',  // 19
    'S',  // 20
    'A',  // 21
    // The max-value domain (taskgen::maxval) extends the alphabet
    // *append-only*: existing ids above are frozen (the python side
    // hard-depends on them via vocab.json), new surface forms take the
    // next free ids. Artifacts lowered against the 22-entry vocab fail
    // the `check_vocab_json` size check with a clear regen message.
    'm',  // 22
    'a',  // 23
    'x',  // 24
    '(',  // 25
    ')',  // 26
    ',',  // 27
];

/// Token id of the padding token.
pub const PAD_ID: u32 = 0;
/// Token id of the end-of-sequence (newline) token.
pub const EOS_ID: u32 = 1;

/// Char-level tokenizer over the fixed alphabet.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// char → id, indexed by the char's position in a small lookup.
    to_id: [u32; 128],
    to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let mut to_id = [u32::MAX; 128];
        for (i, &c) in ALPHABET.iter().enumerate() {
            if i == 0 {
                continue; // pad has no surface form
            }
            to_id[c as usize] = i as u32;
        }
        Tokenizer {
            to_id,
            to_char: ALPHABET.to_vec(),
        }
    }

    /// Number of tokens (including pad).
    pub fn vocab_size(&self) -> usize {
        self.to_char.len()
    }

    /// Encode text. Errors on characters outside the alphabet.
    pub fn encode(&self, text: &str) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(text.len());
        for c in text.chars() {
            let idx = c as usize;
            let id = if idx < 128 { self.to_id[idx] } else { u32::MAX };
            if id == u32::MAX {
                return Err(Error::internal(format!(
                    "character {c:?} not in tokenizer alphabet"
                )));
            }
            out.push(id);
        }
        Ok(out)
    }

    /// Decode ids back to text. Pad tokens are skipped; unknown ids error.
    pub fn decode(&self, ids: &[u32]) -> Result<String> {
        let mut s = String::with_capacity(ids.len());
        for &id in ids {
            if id == PAD_ID {
                continue;
            }
            let c = self
                .to_char
                .get(id as usize)
                .ok_or_else(|| Error::internal(format!("token id {id} out of range")))?;
            s.push(*c);
        }
        Ok(s)
    }

    /// Vocab manifest consumed by the python training stack.
    pub fn vocab_json(&self) -> Value {
        let tokens: Vec<Value> = self
            .to_char
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                if i == 0 {
                    Value::Str("<pad>".to_string())
                } else {
                    Value::Str(c.to_string())
                }
            })
            .collect();
        Value::obj()
            .with("vocab_size", self.vocab_size())
            .with("pad_id", PAD_ID as usize)
            .with("eos_id", EOS_ID as usize)
            .with("tokens", Value::Arr(tokens))
    }

    /// Validate that a vocab.json matches this tokenizer (artifact check).
    pub fn check_vocab_json(&self, v: &Value) -> Result<()> {
        let size = v.req_usize("vocab_size")?;
        if size != self.vocab_size() {
            return Err(Error::artifact(format!(
                "vocab size mismatch: artifact {size}, tokenizer {}",
                self.vocab_size()
            )));
        }
        let tokens = v.req_arr("tokens")?;
        for (i, t) in tokens.iter().enumerate() {
            let s = t
                .as_str()
                .ok_or_else(|| Error::artifact("vocab tokens must be strings"))?;
            let expected = if i == 0 {
                "<pad>".to_string()
            } else {
                self.to_char[i].to_string()
            };
            if s != expected {
                return Err(Error::artifact(format!(
                    "vocab token {i} mismatch: artifact {s:?}, tokenizer {expected:?}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let text = "Q:7+8-5=?\nS:7+8=5;5-5=0;A:0\n";
        let ids = t.encode(text).unwrap();
        assert_eq!(t.decode(&ids).unwrap(), text);
    }

    #[test]
    fn rejects_unknown_chars() {
        let t = Tokenizer::new();
        assert!(t.encode("hello").is_err());
        assert!(t.encode("Q:1+1=?").is_ok());
    }

    #[test]
    fn pad_skipped_in_decode() {
        let t = Tokenizer::new();
        let mut ids = t.encode("A:5").unwrap();
        ids.push(PAD_ID);
        ids.insert(0, PAD_ID);
        assert_eq!(t.decode(&ids).unwrap(), "A:5");
    }

    #[test]
    fn vocab_json_self_check() {
        let t = Tokenizer::new();
        let v = t.vocab_json();
        t.check_vocab_json(&v).unwrap();
        assert_eq!(v.req_usize("vocab_size").unwrap(), ALPHABET.len());
    }

    #[test]
    fn ids_are_stable() {
        // The python side hard-depends on these ids via vocab.json; make
        // accidental reordering a test failure.
        let t = Tokenizer::new();
        assert_eq!(t.encode("0").unwrap(), vec![2]);
        assert_eq!(t.encode("9").unwrap(), vec![11]);
        assert_eq!(t.encode("+").unwrap(), vec![12]);
        assert_eq!(t.encode("\n").unwrap(), vec![EOS_ID]);
        assert_eq!(t.encode("Q").unwrap(), vec![19]);
        // max-domain extension chars are append-only after the frozen ids
        assert_eq!(t.encode("m").unwrap(), vec![22]);
        assert_eq!(t.encode(",").unwrap(), vec![27]);
    }

    #[test]
    fn max_domain_roundtrip() {
        let t = Tokenizer::new();
        let text = "Q:max(3,8,5)=?\nS:max(3,8)=8;max(8,5)=8;A:8\n";
        let ids = t.encode(text).unwrap();
        assert_eq!(t.decode(&ids).unwrap(), text);
    }
}
