//! Typed configuration for the whole system.
//!
//! A single [`Config`] flows from the CLI into every component. Defaults
//! are production values for this testbed; any field can be overridden by
//! a JSON config file (`--config path.json`) whose structure mirrors the
//! structs below, and a handful of high-traffic fields also have direct
//! CLI flags (see [`crate::cli`]).

use crate::error::{Error, Result};
use crate::util::json::{parse, Value};
use std::path::{Path, PathBuf};

/// Filesystem layout.
#[derive(Debug, Clone)]
pub struct Paths {
    /// AOT artifacts (HLO text, weights, vocab, data). `make artifacts`.
    pub artifacts: PathBuf,
    /// Experiment outputs (matrices, probe checkpoints, figures).
    pub results: PathBuf,
}

impl Paths {
    pub fn data_dir(&self) -> PathBuf {
        self.artifacts.join("data")
    }
    pub fn hlo_dir(&self) -> PathBuf {
        self.artifacts.join("hlo")
    }
}

/// Which execution backend the engine threads drive (see
/// `docs/backends.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The PJRT device path: AOT'd executables + weights (`make
    /// artifacts`).
    Device,
    /// The deterministic artifact-free emulator
    /// ([`crate::engine::backend::SimBackend`]); latencies come from the
    /// sim clock's cost model.
    Sim,
    /// A [`crate::net::RemoteBackend`] per engine slot, slot `i` mapped
    /// to `engine.remote_addrs[i % len]`; slots aimed at the same host
    /// share one multiplexed connection — the client side of `ttc
    /// engine-serve` (see `docs/remote.md`).
    Remote,
}

impl BackendKind {
    /// Parse a CLI/config spelling (`device` | `sim` | `remote`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "device" => Ok(BackendKind::Device),
            "sim" => Ok(BackendKind::Sim),
            "remote" => Ok(BackendKind::Remote),
            other => Err(Error::Config(format!(
                "unknown backend '{other}' (expected 'device', 'sim' or 'remote')"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Device => "device",
            BackendKind::Sim => "sim",
            BackendKind::Remote => "remote",
        }
    }
}

/// Which payload codec the remote wire's data plane prefers (see
/// `docs/remote.md`). The actual codec is negotiated per connection in
/// the hello/ack handshake, so mixed fleets interoperate: a `binary`
/// peer talking to a `json`-only peer falls back to JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCodec {
    /// JSON only — the PR 6 wire format, and the control-plane codec in
    /// every configuration.
    Json,
    /// Advertise the TTCB binary codec for the data plane (falls back
    /// to JSON when the peer doesn't speak it).
    Binary,
}

impl WireCodec {
    /// Parse a CLI/config spelling (`json` | `binary`).
    pub fn parse(s: &str) -> Result<WireCodec> {
        match s {
            "json" => Ok(WireCodec::Json),
            "binary" => Ok(WireCodec::Binary),
            other => Err(Error::Config(format!(
                "unknown wire codec '{other}' (expected 'json' or 'binary')"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WireCodec::Json => "json",
            WireCodec::Binary => "binary",
        }
    }
}

/// Engine / batching parameters. Shapes here must agree with the buckets
/// lowered by `python/compile/aot.py` (checked at artifact load).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// KV-cache capacity per sequence (max total tokens incl. prompt).
    pub max_seq: usize,
    /// Padded prompt length for prefill executables.
    pub prefill_len: usize,
    /// Padded length for PRM scoring executables.
    pub prm_len: usize,
    /// Batch-size buckets compiled for decode/prefill/scoring.
    pub buckets: Vec<usize>,
    /// Sampling temperature for candidate generation.
    pub temperature: f32,
    /// Hard cap on generated tokens per candidate.
    pub max_new_tokens: usize,
    /// Use the simulated clock (deterministic latency model) instead of
    /// wall time.
    pub sim_clock: bool,
    /// Micro-batch wait window (ms) for the continuous batcher.
    pub batch_window_ms: f64,
    /// Iteration-level (continuous-batching) decode scheduling: retire
    /// finished/expired rows between decode steps and admit new arrivals
    /// into the freed slots mid-decode. Only takes effect on steppable
    /// backends (sim, device); adapter backends (remote) always use the
    /// round-based path. `false` forces round-based scheduling everywhere
    /// (the equivalence baseline).
    pub continuous: bool,
    /// Execution backend the engine threads drive.
    pub backend: BackendKind,
    /// Engines in the pool (`ttc serve --engines N`); 1 = the classic
    /// single-engine path, placement bypassed.
    pub engines: usize,
    /// `ttc engine-serve` addresses for [`BackendKind::Remote`]; engine
    /// slot `i` dials `remote_addrs[i % len]`.
    pub remote_addrs: Vec<String>,
    /// Per-call read timeout for remote backends (wall-clock ms).
    pub remote_timeout_ms: f64,
    /// Same-shard retries per remote call before the pool's failover
    /// takes over.
    pub remote_retries: usize,
    /// Bound on concurrently in-flight calls per multiplexed remote
    /// connection ([`crate::net::MuxTransport`]); submitters past the
    /// bound block (counted in `NetMetrics.mux_backpressure_waits`)
    /// until a reply frees a slot. Generous by default — a safety net
    /// against a slow engine absorbing unbounded queued work, not a
    /// throughput knob.
    pub mux_max_inflight: usize,
    /// Preferred data-plane codec for the remote wire (`--wire-codec`);
    /// negotiated down to JSON when the peer doesn't speak it.
    pub wire_codec: WireCodec,
    /// Cross-request cache tier (`docs/caching.md`); default-off so
    /// every existing path stays byte-identical unless opted in.
    pub cache: CacheConfig,
}

/// The cross-request cache tier
/// ([`crate::engine::cache::EngineCache`]): prefix-trie generation
/// reuse + sharded PRM/embed score cache, shared by every engine of a
/// pool. CLI: `ttc serve`/`ttc engine-serve`
/// `--cache [--cache-entries N] [--cache-shards N]`.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Off by default: the engine carries no cache at all and every
    /// code path is byte-identical to the pre-cache engine.
    pub enabled: bool,
    /// Entry bound for the generation store and the score store (each
    /// is bounded to `max_entries` independently, LRU-evicted).
    pub max_entries: usize,
    /// Lock shards per store (per-shard capacity is
    /// `max_entries / shards`).
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: false,
            max_entries: 4096,
            shards: 8,
        }
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_seq: 160,
            prefill_len: 32,
            prm_len: 128,
            buckets: vec![1, 4, 8, 16, 32],
            temperature: 0.8,
            max_new_tokens: 96,
            sim_clock: false,
            batch_window_ms: 0.3,
            continuous: true,
            backend: BackendKind::Device,
            engines: 1,
            remote_addrs: Vec::new(),
            remote_timeout_ms: 30_000.0,
            remote_retries: 2,
            mux_max_inflight: 256,
            wire_codec: WireCodec::Json,
            cache: CacheConfig::default(),
        }
    }
}

/// The strategy space `S` the router selects from (paper §2.1).
#[derive(Debug, Clone)]
pub struct SpaceConfig {
    /// N values for majority voting.
    pub mv_ns: Vec<usize>,
    /// N values for best-of-N (both naive and weighted).
    pub bon_ns: Vec<usize>,
    /// Beam-search configs `(n_beams, width, chunk_tokens)`.
    pub beam: Vec<(usize, usize, usize)>,
    /// Early-stop majority configs `(n, wave)`: wave size per vote
    /// checkpoint, searchable like beam's W; `wave <= 1` = the method's
    /// auto default `max(2, n/4)`.
    pub mv_early: Vec<(usize, usize)>,
    /// Max expansion rounds for beam search (depth bound D).
    pub beam_max_rounds: usize,
    /// Additional strategies by id (`"<method>@<params>"`), resolved
    /// against the decoding-method registry — the extension point for
    /// methods beyond the four hard-wired families above. Ids are
    /// validated at config-merge time.
    pub extra: Vec<String>,
}

impl Default for SpaceConfig {
    fn default() -> Self {
        // 18 strategies — sized so the full evaluation matrix fits the
        // single-core budget while spanning the paper's qualitative space
        // (cheap→expensive within each method family). mv_early's wave
        // size is part of the searched space (auto plus one explicit
        // wave point); beam_latency rides the registry-driven `extra`
        // door.
        SpaceConfig {
            mv_ns: vec![1, 2, 4, 8, 16],
            bon_ns: vec![4, 8, 16],
            beam: vec![(2, 2, 12), (4, 2, 12), (4, 4, 12)],
            mv_early: vec![(8, 1), (16, 1), (16, 4)],
            beam_max_rounds: 10,
            extra: vec!["beam_latency@4x2c12".into()],
        }
    }
}

/// λ grids for the accuracy–cost sweeps (Figs 1, 2, 5–8).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Fine λ_T grid (per-token penalty).
    pub lambda_t: Vec<f64>,
    /// Fine λ_L grid (per-ms penalty).
    pub lambda_l: Vec<f64>,
    /// Coarse fixed λ_L values for Fig 1a-style panels.
    pub fixed_lambda_l: Vec<f64>,
    /// Coarse fixed λ_T values for Fig 1b-style panels.
    pub fixed_lambda_t: Vec<f64>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        // Token counts per strategy run are O(10²..10³) and latencies
        // O(10²..10⁴) ms; accuracy is O(1). Grids bracket the regime where
        // the penalty term crosses the accuracy differences.
        fn log_grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
            let mut g = vec![0.0];
            let ratio = (hi / lo).powf(1.0 / (n as f64 - 1.0));
            let mut x = lo;
            for _ in 0..n {
                g.push(x);
                x *= ratio;
            }
            g
        }
        SweepConfig {
            lambda_t: log_grid(1e-6, 3e-3, 16),
            lambda_l: log_grid(1e-7, 3e-4, 16),
            fixed_lambda_l: vec![0.0, 1e-5, 1e-4],
            fixed_lambda_t: vec![0.0, 1e-4, 1e-3],
        }
    }
}

/// Evaluation-matrix collection parameters.
#[derive(Debug, Clone)]
pub struct CollectConfig {
    /// Repeats per (query, strategy) on the probe-training split.
    pub repeats_train: usize,
    /// Repeats per (query, strategy) on calib/test splits.
    pub repeats_eval: usize,
}

impl Default for CollectConfig {
    fn default() -> Self {
        CollectConfig {
            repeats_train: 3,
            repeats_eval: 2,
        }
    }
}

/// Probe training hyperparameters (mirrors the paper's appendix A.1).
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub patience: usize,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        ProbeConfig {
            epochs: 40,
            batch_size: 64,
            patience: 4,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub paths: PathsOpt,
    pub engine: EngineConfig,
    pub space: SpaceConfig,
    pub sweep: SweepConfig,
    pub collect: CollectConfig,
    pub probe: ProbeConfig,
    pub seed: u64,
}

/// Paths with defaults resolved lazily (so `Config::default()` needs no IO).
#[derive(Debug, Clone)]
pub struct PathsOpt {
    pub artifacts: PathBuf,
    pub results: PathBuf,
}

impl Default for PathsOpt {
    fn default() -> Self {
        PathsOpt {
            artifacts: PathBuf::from("artifacts"),
            results: PathBuf::from("results"),
        }
    }
}

impl Config {
    pub fn paths(&self) -> Paths {
        Paths {
            artifacts: self.paths.artifacts.clone(),
            results: self.paths.results.clone(),
        }
    }

    /// Load from a JSON file and merge over defaults.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        let v = parse(&text)?;
        let mut cfg = Config::default();
        cfg.merge_json(&v)?;
        Ok(cfg)
    }

    /// Merge a JSON object over this config. Unknown keys are errors (to
    /// catch typos in experiment configs).
    pub fn merge_json(&mut self, v: &Value) -> Result<()> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Config("config root must be an object".into()))?;
        for (key, val) in obj {
            match key.as_str() {
                "seed" => {
                    self.seed = val
                        .as_i64()
                        .ok_or_else(|| Error::Config("seed must be an integer".into()))?
                        as u64
                }
                "artifacts" => {
                    self.paths.artifacts = PathBuf::from(
                        val.as_str()
                            .ok_or_else(|| Error::Config("artifacts must be a string".into()))?,
                    )
                }
                "results" => {
                    self.paths.results = PathBuf::from(
                        val.as_str()
                            .ok_or_else(|| Error::Config("results must be a string".into()))?,
                    )
                }
                "engine" => self.merge_engine(val)?,
                "space" => self.merge_space(val)?,
                "sweep" => self.merge_sweep(val)?,
                "collect" => {
                    self.collect.repeats_train =
                        val.opt_usize("repeats_train", self.collect.repeats_train);
                    self.collect.repeats_eval =
                        val.opt_usize("repeats_eval", self.collect.repeats_eval);
                }
                "probe" => {
                    self.probe.epochs = val.opt_usize("epochs", self.probe.epochs);
                    self.probe.batch_size = val.opt_usize("batch_size", self.probe.batch_size);
                    self.probe.patience = val.opt_usize("patience", self.probe.patience);
                }
                other => return Err(Error::Config(format!("unknown config key '{other}'"))),
            }
        }
        Ok(())
    }

    fn merge_engine(&mut self, v: &Value) -> Result<()> {
        let e = &mut self.engine;
        e.max_seq = v.opt_usize("max_seq", e.max_seq);
        e.prefill_len = v.opt_usize("prefill_len", e.prefill_len);
        e.prm_len = v.opt_usize("prm_len", e.prm_len);
        e.temperature = v.opt_f64("temperature", e.temperature as f64) as f32;
        e.max_new_tokens = v.opt_usize("max_new_tokens", e.max_new_tokens);
        e.sim_clock = v.opt_bool("sim_clock", e.sim_clock);
        e.batch_window_ms = v.opt_f64("batch_window_ms", e.batch_window_ms);
        e.continuous = v.opt_bool("continuous", e.continuous);
        e.engines = v.opt_usize("engines", e.engines);
        e.remote_timeout_ms = v.opt_f64("remote_timeout_ms", e.remote_timeout_ms);
        e.remote_retries = v.opt_usize("remote_retries", e.remote_retries);
        e.mux_max_inflight = v.opt_usize("mux_max_inflight", e.mux_max_inflight);
        if let Some(addrs) = v.get("remote_addrs") {
            e.remote_addrs = addrs
                .as_arr()
                .ok_or_else(|| Error::Config("engine.remote_addrs must be an array".into()))?
                .iter()
                .map(|a| {
                    a.as_str().map(str::to_string).ok_or_else(|| {
                        Error::Config("engine.remote_addrs entry must be a string".into())
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(b) = v.get("backend") {
            e.backend = BackendKind::parse(
                b.as_str()
                    .ok_or_else(|| Error::Config("engine.backend must be a string".into()))?,
            )?;
        }
        if let Some(c) = v.get("wire_codec") {
            e.wire_codec = WireCodec::parse(
                c.as_str()
                    .ok_or_else(|| Error::Config("engine.wire_codec must be a string".into()))?,
            )?;
        }
        if let Some(c) = v.get("cache") {
            e.cache.enabled = c.opt_bool("enabled", e.cache.enabled);
            e.cache.max_entries = c.opt_usize("max_entries", e.cache.max_entries);
            e.cache.shards = c.opt_usize("shards", e.cache.shards);
        }
        if let Some(buckets) = v.get("buckets") {
            e.buckets = buckets
                .as_arr()
                .ok_or_else(|| Error::Config("engine.buckets must be an array".into()))?
                .iter()
                .map(|b| {
                    b.as_usize()
                        .ok_or_else(|| Error::Config("bucket must be an integer".into()))
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    }

    fn merge_space(&mut self, v: &Value) -> Result<()> {
        if let Some(ns) = v.get("mv_ns") {
            self.space.mv_ns = usize_arr(ns, "space.mv_ns")?;
        }
        if let Some(ns) = v.get("bon_ns") {
            self.space.bon_ns = usize_arr(ns, "space.bon_ns")?;
        }
        self.space.beam_max_rounds = v.opt_usize("beam_max_rounds", self.space.beam_max_rounds);
        if let Some(extra) = v.get("extra") {
            let ids = extra
                .as_arr()
                .ok_or_else(|| Error::Config("space.extra must be an array".into()))?;
            self.space.extra = ids
                .iter()
                .map(|id| {
                    let id = id
                        .as_str()
                        .ok_or_else(|| Error::Config("space.extra entry must be a string".into()))?;
                    if crate::strategies::Strategy::parse(id).is_none() {
                        return Err(Error::Config(format!(
                            "space.extra entry '{id}' does not name a registered method"
                        )));
                    }
                    Ok(id.to_string())
                })
                .collect::<Result<_>>()?;
        }
        if let Some(beam) = v.get("beam") {
            let arr = beam
                .as_arr()
                .ok_or_else(|| Error::Config("space.beam must be an array".into()))?;
            self.space.beam = arr
                .iter()
                .map(|triple| {
                    let t = triple
                        .as_arr()
                        .filter(|t| t.len() == 3)
                        .ok_or_else(|| Error::Config("beam entry must be [n, w, chunk]".into()))?;
                    Ok((
                        t[0].as_usize().ok_or_else(|| Error::Config("beam n".into()))?,
                        t[1].as_usize().ok_or_else(|| Error::Config("beam w".into()))?,
                        t[2].as_usize().ok_or_else(|| Error::Config("beam chunk".into()))?,
                    ))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(me) = v.get("mv_early") {
            let arr = me
                .as_arr()
                .ok_or_else(|| Error::Config("space.mv_early must be an array".into()))?;
            self.space.mv_early = arr
                .iter()
                .map(|pair| {
                    let t = pair
                        .as_arr()
                        .filter(|t| t.len() == 2)
                        .ok_or_else(|| {
                            Error::Config("mv_early entry must be [n, wave]".into())
                        })?;
                    Ok((
                        t[0].as_usize()
                            .ok_or_else(|| Error::Config("mv_early n".into()))?,
                        t[1].as_usize()
                            .ok_or_else(|| Error::Config("mv_early wave".into()))?,
                    ))
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    }

    fn merge_sweep(&mut self, v: &Value) -> Result<()> {
        if let Some(g) = v.get("lambda_t") {
            self.sweep.lambda_t = f64_arr(g, "sweep.lambda_t")?;
        }
        if let Some(g) = v.get("lambda_l") {
            self.sweep.lambda_l = f64_arr(g, "sweep.lambda_l")?;
        }
        if let Some(g) = v.get("fixed_lambda_l") {
            self.sweep.fixed_lambda_l = f64_arr(g, "sweep.fixed_lambda_l")?;
        }
        if let Some(g) = v.get("fixed_lambda_t") {
            self.sweep.fixed_lambda_t = f64_arr(g, "sweep.fixed_lambda_t")?;
        }
        Ok(())
    }
}

fn usize_arr(v: &Value, what: &str) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| Error::Config(format!("{what} must be an array")))?
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| Error::Config(format!("{what} element must be an integer")))
        })
        .collect()
}

fn f64_arr(v: &Value, what: &str) -> Result<Vec<f64>> {
    v.as_arr()
        .ok_or_else(|| Error::Config(format!("{what} must be an array")))?
        .iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| Error::Config(format!("{what} element must be a number")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert!(c.engine.max_seq >= c.engine.prefill_len + c.engine.max_new_tokens);
        assert!(c.engine.buckets.windows(2).all(|w| w[0] < w[1]));
        assert!(!c.space.mv_ns.is_empty());
        assert!(c.sweep.lambda_t[0] == 0.0, "grid must include zero penalty");
    }

    #[test]
    fn merge_overrides() {
        let mut c = Config::default();
        let v = parse(
            r#"{"seed": 99, "engine": {"temperature": 0.5, "buckets": [1, 2]},
                "space": {"mv_ns": [1, 3], "beam": [[2, 2, 8]],
                          "mv_early": [[8, 2], [16, 1]],
                          "extra": ["mv_early@4w2", "beam_latency@2x2c8"]},
                "sweep": {"lambda_t": [0, 0.1]}}"#,
        )
        .unwrap();
        c.merge_json(&v).unwrap();
        assert_eq!(c.seed, 99);
        assert_eq!(c.engine.temperature, 0.5);
        assert_eq!(c.engine.buckets, vec![1, 2]);
        assert_eq!(c.space.mv_ns, vec![1, 3]);
        assert_eq!(c.space.beam, vec![(2, 2, 8)]);
        assert_eq!(c.space.mv_early, vec![(8, 2), (16, 1)]);
        assert_eq!(
            c.space.extra,
            vec!["mv_early@4w2".to_string(), "beam_latency@2x2c8".to_string()]
        );
        assert_eq!(c.sweep.lambda_t, vec![0.0, 0.1]);
    }

    #[test]
    fn backend_and_engines_merge() {
        let mut c = Config::default();
        assert_eq!(c.engine.backend, BackendKind::Device);
        assert_eq!(c.engine.engines, 1);
        let v = parse(r#"{"engine": {"backend": "sim", "engines": 4}}"#).unwrap();
        c.merge_json(&v).unwrap();
        assert_eq!(c.engine.backend, BackendKind::Sim);
        assert_eq!(c.engine.engines, 4);
        let bad = parse(r#"{"engine": {"backend": "gpu"}}"#).unwrap();
        assert!(c.merge_json(&bad).is_err());
        assert!(BackendKind::parse("device").is_ok());
        assert_eq!(BackendKind::Sim.as_str(), "sim");
    }

    #[test]
    fn remote_backend_merge() {
        let mut c = Config::default();
        assert!(c.engine.remote_addrs.is_empty());
        let v = parse(
            r#"{"engine": {"backend": "remote",
                           "remote_addrs": ["h1:7070", "h2:7070"],
                           "remote_timeout_ms": 500, "remote_retries": 1}}"#,
        )
        .unwrap();
        c.merge_json(&v).unwrap();
        assert_eq!(c.engine.backend, BackendKind::Remote);
        assert_eq!(c.engine.remote_addrs, vec!["h1:7070", "h2:7070"]);
        assert_eq!(c.engine.remote_timeout_ms, 500.0);
        assert_eq!(c.engine.remote_retries, 1);
        assert_eq!(c.engine.mux_max_inflight, 256, "generous default bound");
        let v = parse(r#"{"engine": {"mux_max_inflight": 8}}"#).unwrap();
        c.merge_json(&v).unwrap();
        assert_eq!(c.engine.mux_max_inflight, 8);
        assert_eq!(BackendKind::parse("remote").unwrap().as_str(), "remote");
        let bad = parse(r#"{"engine": {"remote_addrs": [7]}}"#).unwrap();
        assert!(c.merge_json(&bad).is_err());
    }

    #[test]
    fn wire_codec_merge() {
        let mut c = Config::default();
        assert_eq!(c.engine.wire_codec, WireCodec::Json, "json must be the default");
        let v = parse(r#"{"engine": {"wire_codec": "binary"}}"#).unwrap();
        c.merge_json(&v).unwrap();
        assert_eq!(c.engine.wire_codec, WireCodec::Binary);
        assert_eq!(WireCodec::Binary.as_str(), "binary");
        assert_eq!(WireCodec::parse("json").unwrap(), WireCodec::Json);
        let bad = parse(r#"{"engine": {"wire_codec": "msgpack"}}"#).unwrap();
        assert!(c.merge_json(&bad).is_err());
        let bad = parse(r#"{"engine": {"wire_codec": 2}}"#).unwrap();
        assert!(c.merge_json(&bad).is_err());
    }

    #[test]
    fn continuous_merge() {
        let mut c = Config::default();
        assert!(c.engine.continuous, "continuous must be the default");
        let v = parse(r#"{"engine": {"continuous": false}}"#).unwrap();
        c.merge_json(&v).unwrap();
        assert!(!c.engine.continuous);
    }

    #[test]
    fn cache_config_merge() {
        let mut c = Config::default();
        assert!(!c.engine.cache.enabled, "cache must be default-off");
        assert_eq!(c.engine.cache.max_entries, 4096);
        assert_eq!(c.engine.cache.shards, 8);
        let v = parse(
            r#"{"engine": {"cache": {"enabled": true, "max_entries": 128, "shards": 2}}}"#,
        )
        .unwrap();
        c.merge_json(&v).unwrap();
        assert!(c.engine.cache.enabled);
        assert_eq!(c.engine.cache.max_entries, 128);
        assert_eq!(c.engine.cache.shards, 2);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        let v = parse(r#"{"typo_key": 1}"#).unwrap();
        assert!(c.merge_json(&v).is_err());
    }

    #[test]
    fn bad_extra_strategy_id_rejected() {
        let mut c = Config::default();
        let v = parse(r#"{"space": {"extra": ["no_such_method@4"]}}"#).unwrap();
        let err = c.merge_json(&v).unwrap_err().to_string();
        assert!(err.contains("no_such_method"), "{err}");
        let v = parse(r#"{"space": {"extra": ["beam_latency@oops"]}}"#).unwrap();
        assert!(c.merge_json(&v).is_err());
    }
}
