//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are plain `main()` binaries (`harness = false`)
//! built on this: warmup, timed iterations, mean/p50/p95 reporting, and
//! an environment knob (`TTC_BENCH_SECONDS`) for run length. Output is
//! line-oriented (`bench,<name>,<iters>,<mean_ns>,<p50_ns>,<p95_ns>`)
//! so `bench_output.txt` is machine-parseable.

use crate::util::stats;
use std::time::Instant;

/// Target seconds per benchmark (after warmup).
fn target_seconds() -> f64 {
    std::env::var("TTC_BENCH_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0)
}

/// Benchmark a closure; prints one summary line and returns mean ns.
pub fn bench(name: &str, mut f: impl FnMut()) -> f64 {
    // warmup: run until 10% of budget or 3 iterations
    let warmup_until = target_seconds() * 0.1;
    let t0 = Instant::now();
    let mut warmups = 0;
    while t0.elapsed().as_secs_f64() < warmup_until || warmups < 3 {
        f();
        warmups += 1;
        if warmups > 1_000_000 {
            break;
        }
    }
    // measure
    let budget = target_seconds();
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < budget && samples.len() < 5_000_000 {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_nanos() as f64);
    }
    let mean = stats::mean(&samples);
    let p50 = stats::percentile(&samples, 50.0);
    let p95 = stats::percentile(&samples, 95.0);
    println!(
        "bench,{name},{},{:.0},{:.0},{:.0}",
        samples.len(),
        mean,
        p50,
        p95
    );
    mean
}

/// Pretty header for a bench binary.
pub fn header(binary: &str) {
    println!("# {binary} — columns: bench,name,iters,mean_ns,p50_ns,p95_ns");
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("TTC_BENCH_SECONDS", "0.05");
        let mean = super::bench("noop_sum", || {
            let s: u64 = (0..100).sum();
            std::hint::black_box(s);
        });
        assert!(mean > 0.0);
        std::env::remove_var("TTC_BENCH_SECONDS");
    }
}
