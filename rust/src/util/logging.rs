//! Minimal leveled logger (no `log`/`tracing` facade available).
//!
//! Level is taken from the `TTC_LOG` environment variable
//! (`error|warn|info|debug|trace`, default `info`). Output goes to stderr
//! with millisecond timestamps relative to process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn max_level() -> Level {
    let raw = MAX_LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let level = std::env::var("TTC_LOG")
            .map(|s| Level::from_str(&s))
            .unwrap_or(Level::Info);
        MAX_LEVEL.store(level as u8, Ordering::Relaxed);
        return level;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically (tests, CLI flag).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True if `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Core emit function — use the `log_*!` macros instead.
pub fn emit(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:10.3}s {} {module}] {args}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
