//! Small statistics helpers used across the cost model, calibration and
//! figure generation.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy. `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Numerically-stable logistic function.
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        let e = (-z).exp();
        1.0 / (1.0 + e)
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Binary cross-entropy between a label in [0,1] and a probability,
/// clipped for stability.
pub fn bce(label: f64, prob: f64) -> f64 {
    let p = prob.clamp(1e-7, 1.0 - 1e-7);
    -(label * p.ln() + (1.0 - label) * (1.0 - p).ln())
}

/// Pearson correlation; 0.0 when degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

/// Expected calibration error over equal-width probability bins.
/// Inputs: (predicted probability, empirical label in [0,1]) pairs.
pub fn ece(pairs: &[(f64, f64)], bins: usize) -> f64 {
    if pairs.is_empty() || bins == 0 {
        return 0.0;
    }
    let mut sums = vec![(0.0f64, 0.0f64, 0usize); bins];
    for &(p, y) in pairs {
        let b = ((p * bins as f64) as usize).min(bins - 1);
        sums[b].0 += p;
        sums[b].1 += y;
        sums[b].2 += 1;
    }
    let n = pairs.len() as f64;
    sums.iter()
        .filter(|(_, _, c)| *c > 0)
        .map(|(ps, ys, c)| {
            let cf = *c as f64;
            (cf / n) * ((ps / cf) - (ys / cf)).abs()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn sigmoid_stable() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!(sigmoid(1000.0) <= 1.0);
        assert!((sigmoid(2.0) + sigmoid(-2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bce_basic() {
        assert!(bce(1.0, 0.99) < bce(1.0, 0.5));
        assert!(bce(0.0, 0.01) < bce(0.0, 0.5));
        assert!(bce(1.0, 0.0).is_finite());
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ece_perfectly_calibrated() {
        let pairs: Vec<(f64, f64)> = (0..100).map(|i| (i as f64 / 100.0, i as f64 / 100.0)).collect();
        assert!(ece(&pairs, 10) < 0.05);
        let bad: Vec<(f64, f64)> = (0..100).map(|i| (i as f64 / 100.0, 0.0)).collect();
        assert!(ece(&bad, 10) > 0.3);
    }
}
