//! Minimal JSON implementation (parser + serializer + accessors).
//!
//! serde is not available in this environment (no network for cargo), so
//! this module provides the subset of JSON the system needs: datasets
//! (JSONL), artifact manifests, figure outputs and config files.
//!
//! Design notes:
//! * Objects preserve insertion order (`Vec<(String, Value)>`) so emitted
//!   manifests are stable and diffable.
//! * Numbers are `f64` (JSON's own model); integer accessors check that
//!   the value round-trips.
//! * The parser is a straightforward recursive-descent over bytes with a
//!   depth limit; errors carry byte offsets.

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// Zero-copy lazy field access over serialized JSON (see `json/lazy.rs`).
#[path = "json/lazy.rs"]
pub mod lazy;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    // ---- constructors ----
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Builder-style insert for objects; panics if `self` is not an object.
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(fields) => fields.push((key.to_string(), v.into())),
            _ => panic!("Value::with on non-object"),
        }
        self
    }

    /// Insert or replace a key in an object.
    pub fn set(&mut self, key: &str, v: impl Into<Value>) {
        if let Value::Obj(fields) = self {
            if let Some(slot) = fields.iter_mut().find(|(k, _)| k == key) {
                slot.1 = v.into();
            } else {
                fields.push((key.to_string(), v.into()));
            }
        } else {
            panic!("Value::set on non-object");
        }
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that returns a schema error mentioning the key when missing.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() <= i64::MAX as f64 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    // ---- typed `req` helpers (error messages carry the key) ----
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("key '{key}' is not a string")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("key '{key}' is not a number")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("key '{key}' is not a non-negative integer")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::Json(format!("key '{key}' is not an array")))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Array of f64, with schema check.
    pub fn req_f64_arr(&self, key: &str) -> Result<Vec<f64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| Error::Json(format!("array '{key}' has non-number element")))
            })
            .collect()
    }

    // ---- serialization ----
    /// Compact single-line serialization.
    pub fn dumps(&self) -> String {
        let mut out = String::with_capacity(64);
        self.write_to(&mut out, None, 0);
        out
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write_to(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    /// Byte length `dumps()` would produce, computed without allocating.
    /// The wire layer uses this to report how many bytes the binary codec
    /// saved versus the JSON encoding of the same envelope.
    pub fn encoded_len(&self) -> usize {
        let mut counter = CountWriter(0);
        self.write_to(&mut counter, None, 0);
        counter.0
    }

    fn write_to<W: std::fmt::Write>(&self, out: &mut W, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => {
                let _ = out.write_str("null");
            }
            Value::Bool(true) => {
                let _ = out.write_str("true");
            }
            Value::Bool(false) => {
                let _ = out.write_str("false");
            }
            Value::Num(n) => write_number(out, *n),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                let _ = out.write_char('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        let _ = out.write_char(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write_to(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                let _ = out.write_char(']');
            }
            Value::Obj(fields) => {
                let _ = out.write_char('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        let _ = out.write_char(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    let _ = out.write_char(':');
                    if indent.is_some() {
                        let _ = out.write_char(' ');
                    }
                    v.write_to(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                let _ = out.write_char('}');
            }
        }
    }
}

/// `fmt::Write` sink that only counts bytes; backs `Value::encoded_len`.
struct CountWriter(usize);

impl std::fmt::Write for CountWriter {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        self.0 += s.len();
        Ok(())
    }
}

fn newline_indent<W: std::fmt::Write>(out: &mut W, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        let _ = out.write_char('\n');
        for _ in 0..(w * depth) {
            let _ = out.write_char(' ');
        }
    }
}

fn write_number<W: std::fmt::Write>(out: &mut W, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null (matches python json.dumps default
        // behaviour closely enough for metric outputs, and parses back).
        let _ = out.write_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped<W: std::fmt::Write>(out: &mut W, s: &str) {
    let _ = out.write_char('"');
    for c in s.chars() {
        match c {
            '"' => {
                let _ = out.write_str("\\\"");
            }
            '\\' => {
                let _ = out.write_str("\\\\");
            }
            '\n' => {
                let _ = out.write_str("\\n");
            }
            '\r' => {
                let _ = out.write_str("\\r");
            }
            '\t' => {
                let _ = out.write_str("\\t");
            }
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => {
                let _ = out.write_char(c);
            }
        }
    }
    let _ = out.write_char('"');
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<i32> for Value {
    fn from(n: i32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Value {
    fn from(v: &[f64]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x)).collect())
    }
}
impl From<&[f32]> for Value {
    fn from(v: &[f32]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect())
    }
}

/// Parse a JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON document"));
    }
    Ok(v)
}

/// [`parse`] with a hard input-size ceiling — the variant for
/// *adversarial* inputs (network payloads): a peer can then cost at most
/// `max_bytes` of parse work/memory per document. Local artifacts and
/// configs keep using [`parse`] unbounded.
pub fn parse_bounded(text: &str, max_bytes: usize) -> Result<Value> {
    if text.len() > max_bytes {
        return Err(Error::Json(format!(
            "document of {} bytes exceeds the {max_bytes}-byte parse limit",
            text.len()
        )));
    }
    parse(text)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("max nesting depth exceeded"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{lit}')")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
        self.depth -= 1;
        Ok(Value::Obj(fields))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    break;
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
        self.depth -= 1;
        Ok(Value::Arr(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate pair"))?,
                                    );
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                s.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                            continue; // hex4 advanced pos past the escape
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = text.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("unescaped control character"));
                    }
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bytes[self.pos];
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit in \\u escape"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n = text
            .parse::<f64>()
            .map_err(|_| self.err("invalid number"))?;
        // "1e999999" parses to +inf; JSON has no non-finite numbers, and
        // letting one in here would silently become `null` on re-dump
        if !n.is_finite() {
            return Err(self.err("number overflows f64"));
        }
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.dumps()).unwrap();
            assert_eq!(v, back, "roundtrip failed for {src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c\nd"}], "e": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c\nd")
        );
        assert_eq!(v.get("e"), Some(&Value::Null));
    }

    #[test]
    fn object_order_preserved() {
        let v = Value::obj().with("z", 1.0).with("a", 2.0).with("m", 3.0);
        assert_eq!(v.dumps(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn escapes() {
        let v = Value::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.dumps();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'a':1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
    }

    #[test]
    fn integer_fidelity() {
        let v = parse("1234567890123").unwrap();
        assert_eq!(v.as_i64(), Some(1234567890123));
        assert_eq!(v.dumps(), "1234567890123");
    }

    #[test]
    fn req_errors_name_the_key() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
        let err = v.req_str("a").unwrap_err().to_string();
        assert!(err.contains("'a'"), "{err}");
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Value::Num(f64::NAN).dumps(), "null");
    }

    #[test]
    fn nonfinite_numbers_are_rejected() {
        // "1e999999" parses to +inf under f64 — must not become a Value
        assert!(parse("1e999999").is_err());
        assert!(parse("-1e999999").is_err());
        assert!(parse("[1, 2e308]").is_err());
        // extreme but finite magnitudes are fine
        assert!(parse("1e308").is_ok());
        assert!(parse("5e-324").is_ok());
        assert!(parse("0.00000000000000000000001").is_ok());
    }

    #[test]
    fn parse_bounded_enforces_the_ceiling() {
        let doc = r#"{"a": [1, 2, 3]}"#;
        assert_eq!(parse_bounded(doc, doc.len()).unwrap(), parse(doc).unwrap());
        let err = parse_bounded(doc, doc.len() - 1).unwrap_err().to_string();
        assert!(err.contains("parse limit"), "{err}");
        // the limit is on input bytes, not parse progress: a huge doc is
        // rejected without any parsing work
        let big = format!("[{}]", "0,".repeat(10_000) + "0");
        assert!(parse_bounded(&big, 64).is_err());
    }

    #[test]
    fn deep_object_nesting_is_rejected() {
        let deep = r#"{"a":"#.repeat(200) + "1" + &"}".repeat(200);
        assert!(parse(&deep).is_err());
        // and mixed nesting
        let mixed = r#"[{"a":"#.repeat(100) + "1" + &"}]".repeat(100);
        assert!(parse(&mixed).is_err());
    }

    #[test]
    fn escape_torture() {
        // every single-char escape plus a surrogate pair
        let v = parse(r#""\"\\\/\b\f\n\r\tA😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\"\\/\u{8}\u{c}\n\r\tA😀"));
        // malformed escapes must error, not panic or mis-decode
        for bad in [
            r#""\x""#,     // unknown escape
            r#""\u12""#,   // truncated hex
            r#""\uZZZZ""#, // bad hex digits
            r#""\ud800""#, // lone high surrogate
            "\"a\u{1}b\"", // unescaped control char
            r#""unterminated"#,
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    /// A random JSON value with bounded depth/width; every number is
    /// finite and every string exercises escapes and unicode.
    fn gen_value(rng: &mut crate::util::rng::Rng, depth: usize) -> Value {
        let roll = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match roll {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => match rng.below(4) {
                0 => Value::Num(rng.range(-1_000_000, 1_000_000) as f64),
                1 => Value::Num(rng.range(-1000, 1000) as f64 / 64.0),
                2 => Value::Num(rng.range(1, 1_000_000) as f64 * 1e-12),
                _ => Value::Num(rng.range(-1_000_000, 1_000_000) as f64 * 1e9),
            },
            3 => {
                let s: String = (0..rng.below(12))
                    .map(|_| match rng.below(8) {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => '\u{1}',
                        4 => 'é',
                        5 => '😀',
                        _ => (b'a' + rng.below(26) as u8) as char,
                    })
                    .collect();
                Value::Str(s)
            }
            4 => Value::Arr(
                (0..rng.below(4))
                    .map(|_| gen_value(rng, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut obj = Value::obj();
                for i in 0..rng.below(4) {
                    let v = gen_value(rng, depth - 1);
                    obj = obj.with(&format!("k{i}"), v);
                }
                obj
            }
        }
    }

    #[test]
    fn prop_random_values_roundtrip_exactly() {
        crate::testkit::forall(
            "json roundtrip",
            200,
            |rng| gen_value(rng, 3),
            |v| {
                let text = v.dumps();
                let back = parse(&text)
                    .map_err(|e| format!("re-parse of {text:?} failed: {e}"))?;
                crate::testkit::prop_assert(
                    &back == v,
                    format!("roundtrip changed the value: {text:?}"),
                )?;
                crate::testkit::prop_assert(
                    v.encoded_len() == text.len(),
                    format!(
                        "encoded_len {} != dumps len {} for {text:?}",
                        v.encoded_len(),
                        text.len()
                    ),
                )?;
                // bounded parse agrees with unbounded on in-limit docs
                let bounded = parse_bounded(&text, text.len())
                    .map_err(|e| format!("parse_bounded rejected its own dump: {e}"))?;
                crate::testkit::prop_assert(bounded == back, "bounded parse differs".to_string())
            },
        );
    }

    #[test]
    fn prop_truncated_documents_always_error() {
        crate::testkit::forall(
            "json truncation",
            150,
            // root is an array, so every strict prefix leaves an
            // unclosed bracket and must be rejected
            |rng| Value::Arr(vec![gen_value(rng, 3)]).dumps(),
            |text| {
                for cut in 0..text.len() {
                    if !text.is_char_boundary(cut) {
                        continue;
                    }
                    crate::testkit::prop_assert(
                        parse(&text[..cut]).is_err(),
                        format!("prefix {:?} of {text:?} parsed", &text[..cut]),
                    )?;
                }
                Ok(())
            },
        );
    }
}
