//! Zero-copy lazy access over a serialized JSON object.
//!
//! [`LazyDoc::index`] scans a JSON document once and records a borrowed
//! byte span per top-level field, without materializing any values. The
//! server accept loop and `engine-serve` control-plane paths (hello,
//! info, metrics) use it to peek at one or two routing fields (`type`,
//! `op`, `id`) and only pay a full [`super::parse`] for the envelopes
//! that actually need it.
//!
//! Scope and limitations, by design:
//! * The document root must be an object — the only shape the wire
//!   protocol sends.
//! * Field lookup compares the *raw* key bytes between the quotes, so a
//!   key written with escape sequences (`"type"`) will not match a
//!   literal lookup name. The protocol only emits plain ASCII keys.
//! * The scanner validates structure (brackets, strings, delimiters)
//!   but not scalar spelling; a malformed number inside a field is only
//!   caught if that field is materialized with [`LazyDoc::field`].

use super::Value;
use crate::error::{Error, Result};

/// A lazily indexed view of a serialized JSON object.
pub struct LazyDoc<'a> {
    /// `(raw key bytes, raw value slice)` in document order.
    fields: Vec<(&'a str, &'a str)>,
}

impl<'a> LazyDoc<'a> {
    /// Index the top-level fields of a serialized JSON object.
    pub fn index(text: &'a str) -> Result<LazyDoc<'a>> {
        let mut s = Scan {
            text,
            bytes: text.as_bytes(),
            pos: 0,
        };
        s.skip_ws();
        s.expect(b'{', "an object")?;
        let mut fields = Vec::new();
        s.skip_ws();
        if s.peek() == Some(b'}') {
            s.pos += 1;
        } else {
            loop {
                s.skip_ws();
                let key = s.scan_string()?;
                // strip the surrounding quotes: raw key bytes only
                let key = &key[1..key.len() - 1];
                s.skip_ws();
                s.expect(b':', "':' after a key")?;
                s.skip_ws();
                let val = s.scan_value()?;
                fields.push((key, val));
                s.skip_ws();
                match s.next_byte() {
                    Some(b',') => continue,
                    Some(b'}') => break,
                    _ => return Err(s.fail("expected ',' or '}' after a field")),
                }
            }
        }
        s.skip_ws();
        if s.pos != s.bytes.len() {
            return Err(s.fail("trailing data after the object"));
        }
        Ok(LazyDoc { fields })
    }

    /// Like [`LazyDoc::index`] with a size cap, mirroring
    /// [`super::parse_bounded`].
    pub fn index_bounded(text: &'a str, max_bytes: usize) -> Result<LazyDoc<'a>> {
        if text.len() > max_bytes {
            return Err(Error::Json(format!(
                "lazy: document is {} bytes, limit {max_bytes}",
                text.len()
            )));
        }
        LazyDoc::index(text)
    }

    /// Number of top-level fields.
    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    /// Top-level keys in document order (raw bytes between the quotes).
    pub fn keys(&self) -> impl Iterator<Item = &'a str> + '_ {
        self.fields.iter().map(|(k, _)| *k)
    }

    pub fn has(&self, key: &str) -> bool {
        self.raw(key).is_some()
    }

    /// The raw serialized slice of a field's value, if present.
    pub fn raw(&self, key: &str) -> Option<&'a str> {
        self.fields
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
    }

    /// Borrowed string value of a field — the fast path. Returns `None`
    /// if the field is missing, not a string, or contains escape
    /// sequences (the caller falls back to [`LazyDoc::field`] then).
    pub fn str_of(&self, key: &str) -> Option<&'a str> {
        let raw = self.raw(key)?;
        if raw.len() >= 2 && raw.starts_with('"') && !raw.contains('\\') {
            Some(&raw[1..raw.len() - 1])
        } else {
            None
        }
    }

    /// Numeric value of a field, parsed in place.
    pub fn num(&self, key: &str) -> Option<f64> {
        let raw = self.raw(key)?;
        match raw.as_bytes().first() {
            Some(b'-') | Some(b'0'..=b'9') => raw.parse::<f64>().ok().filter(|n| n.is_finite()),
            _ => None,
        }
    }

    /// Integer value of a field (round-trip checked, like
    /// [`Value::as_usize`]).
    pub fn usize_of(&self, key: &str) -> Option<usize> {
        let n = self.num(key)?;
        if n.fract() == 0.0 && n >= 0.0 && n <= u64::MAX as f64 {
            Some(n as usize)
        } else {
            None
        }
    }

    pub fn bool_of(&self, key: &str) -> Option<bool> {
        match self.raw(key) {
            Some("true") => Some(true),
            Some("false") => Some(false),
            _ => None,
        }
    }

    /// Materialize a single field through the eager parser. Error
    /// messages match [`Value::req`] so callers can switch between the
    /// lazy and eager paths without changing their error contract.
    pub fn field(&self, key: &str) -> Result<Value> {
        let raw = self
            .raw(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))?;
        super::parse(raw)
    }
}

/// Byte scanner that finds value spans without building anything.
struct Scan<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn next_byte(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, want: u8, what: &str) -> Result<()> {
        if self.peek() == Some(want) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.fail(&format!("expected {what}")))
        }
    }

    fn fail(&self, msg: &str) -> Error {
        Error::Json(format!("lazy: {msg} at byte {}", self.pos))
    }

    /// Scan a string (cursor on the opening quote); returns the slice
    /// including both quotes.
    fn scan_string(&mut self) -> Result<&'a str> {
        let start = self.pos;
        self.expect(b'"', "a string")?;
        loop {
            match self.next_byte() {
                Some(b'"') => return Ok(&self.text[start..self.pos]),
                // skip the escaped byte; multi-byte escapes (\uXXXX) are
                // plain ASCII after the backslash, so byte-stepping is safe
                Some(b'\\') => {
                    self.pos += 1;
                }
                Some(_) => {}
                None => return Err(self.fail("unterminated string")),
            }
        }
    }

    /// Scan one value of any shape; returns its serialized slice.
    fn scan_value(&mut self) -> Result<&'a str> {
        let start = self.pos;
        match self.peek() {
            Some(b'"') => {
                self.scan_string()?;
            }
            Some(b'{') | Some(b'[') => {
                // non-recursive bracket matcher, string-aware and
                // kind-aware (a '}' cannot close a '[')
                let mut stack: Vec<u8> = Vec::new();
                loop {
                    match self.peek() {
                        Some(b'"') => {
                            self.scan_string()?;
                        }
                        Some(open @ (b'{' | b'[')) => {
                            stack.push(open);
                            self.pos += 1;
                        }
                        Some(close @ (b'}' | b']')) => {
                            let want = if close == b'}' { b'{' } else { b'[' };
                            if stack.pop() != Some(want) {
                                return Err(self.fail("mismatched bracket"));
                            }
                            self.pos += 1;
                            if stack.is_empty() {
                                break;
                            }
                        }
                        Some(_) => {
                            self.pos += 1;
                        }
                        None => return Err(self.fail("unterminated container")),
                    }
                }
            }
            Some(b't') => self.literal("true")?,
            Some(b'f') => self.literal("false")?,
            Some(b'n') => self.literal("null")?,
            Some(b'-') | Some(b'0'..=b'9') => {
                self.pos += 1;
                while matches!(
                    self.peek(),
                    Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                ) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.fail("expected a value")),
        }
        Ok(&self.text[start..self.pos])
    }

    fn literal(&mut self, word: &str) -> Result<()> {
        if self.text[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.fail(&format!("expected '{word}'")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{parse, Value};
    use super::*;

    #[test]
    fn indexes_a_hello_without_materializing() {
        let text = r#"{"type":"hello","protocol":1,"probe_layout":{"layout_version":1,"n_methods":5},"client":"ttc","codecs":[1,2],"mux":true}"#;
        let doc = LazyDoc::index(text).unwrap();
        assert_eq!(doc.str_of("type"), Some("hello"));
        assert_eq!(doc.num("protocol"), Some(1.0));
        assert_eq!(doc.bool_of("mux"), Some(true));
        assert!(doc.has("codecs"));
        assert!(!doc.has("nope"));
        // only probe_layout gets materialized
        let layout = doc.field("probe_layout").unwrap();
        assert_eq!(layout.req_usize("layout_version").unwrap(), 1);
        let err = doc.field("missing").unwrap_err().to_string();
        assert!(err.contains("missing key"), "{err}");
    }

    #[test]
    fn raw_spans_cover_nested_values() {
        let text = r#"{ "a" : [1, {"b": "}]"}], "c": "x\"y", "d": -1.5e3 }"#;
        let doc = LazyDoc::index(text).unwrap();
        assert_eq!(doc.raw("a"), Some(r#"[1, {"b": "}]"}]"#));
        assert_eq!(doc.raw("c"), Some(r#""x\"y""#));
        // escaped string: fast path declines, field() materializes
        assert_eq!(doc.str_of("c"), None);
        assert_eq!(doc.field("c").unwrap().as_str(), Some("x\"y"));
        assert_eq!(doc.num("d"), Some(-1.5e3));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "[1]",
            "{",
            r#"{"a"}"#,
            r#"{"a":}"#,
            r#"{"a":1,}"#,
            r#"{"a":1} extra"#,
            r#"{"a":"unterminated"#,
            r#"{"a":[1,2}"#,
        ] {
            assert!(LazyDoc::index(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn bounded_index_enforces_the_cap() {
        let text = r#"{"a":1}"#;
        assert!(LazyDoc::index_bounded(text, text.len()).is_ok());
        assert!(LazyDoc::index_bounded(text, text.len() - 1).is_err());
    }

    /// Random top-level object with plain keys and arbitrary nested
    /// values (every scalar shape, escape-heavy strings).
    fn gen_doc(rng: &mut crate::util::rng::Rng) -> Value {
        fn gen(rng: &mut crate::util::rng::Rng, depth: usize) -> Value {
            let roll = if depth == 0 {
                rng.below(4)
            } else {
                rng.below(6)
            };
            match roll {
                0 => Value::Null,
                1 => Value::Bool(rng.below(2) == 0),
                2 => match rng.below(3) {
                    0 => Value::Num(rng.range(-1_000_000, 1_000_000) as f64),
                    1 => Value::Num(rng.range(-1000, 1000) as f64 / 64.0),
                    _ => Value::Num(rng.range(1, 1_000_000) as f64 * 1e-9),
                },
                3 => {
                    let s: String = (0..rng.below(10))
                        .map(|_| match rng.below(8) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => '\u{1}',
                            4 => 'é',
                            5 => '😀',
                            _ => (b'a' + rng.below(26) as u8) as char,
                        })
                        .collect();
                    Value::Str(s)
                }
                4 => Value::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => {
                    let mut obj = Value::obj();
                    for i in 0..rng.below(4) {
                        let v = gen(rng, depth - 1);
                        obj = obj.with(&format!("n{i}"), v);
                    }
                    obj
                }
            }
        }
        let mut obj = Value::obj();
        for i in 0..1 + rng.below(5) {
            let v = gen(rng, 3);
            obj = obj.with(&format!("k{i}"), v);
        }
        obj
    }

    #[test]
    fn prop_lazy_fields_agree_with_eager_parse() {
        crate::testkit::forall(
            "lazy vs eager",
            200,
            |rng| gen_doc(rng),
            |v| {
                let text = v.dumps();
                let doc = LazyDoc::index(&text)
                    .map_err(|e| format!("index of {text:?} failed: {e}"))?;
                let fields = v.as_obj().expect("gen_doc returns objects");
                crate::testkit::prop_assert(
                    doc.len() == fields.len(),
                    format!("field count {} != {}", doc.len(), fields.len()),
                )?;
                for (key, want) in fields {
                    let got = doc
                        .field(key)
                        .map_err(|e| format!("field '{key}' of {text:?} failed: {e}"))?;
                    crate::testkit::prop_assert(
                        &got == want,
                        format!("field '{key}' of {text:?}: lazy {got:?} != eager {want:?}"),
                    )?;
                    if let Some(s) = doc.str_of(key) {
                        crate::testkit::prop_assert(
                            want.as_str() == Some(s),
                            format!("str_of '{key}' returned {s:?}"),
                        )?;
                    }
                    if let Some(n) = doc.num(key) {
                        crate::testkit::prop_assert(
                            want.as_f64() == Some(n),
                            format!("num '{key}' returned {n}"),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_truncated_and_mutated_docs_never_panic() {
        crate::testkit::forall(
            "lazy adversarial",
            150,
            |rng| {
                let text = gen_doc(rng).dumps();
                let flip = rng.below(text.len().max(1));
                (text, flip, rng.below(256) as u8)
            },
            |(text, flip, byte)| {
                // every strict prefix must be rejected (root is an object)
                for cut in 0..text.len() {
                    if !text.is_char_boundary(cut) {
                        continue;
                    }
                    crate::testkit::prop_assert(
                        LazyDoc::index(&text[..cut]).is_err(),
                        format!("prefix {:?} of {text:?} indexed", &text[..cut]),
                    )?;
                }
                // single-byte mutation: indexing must not panic; if it
                // succeeds, materializing every field must not panic
                let mut bytes = text.clone().into_bytes();
                if !bytes.is_empty() {
                    bytes[*flip] = *byte;
                }
                if let Ok(mutated) = String::from_utf8(bytes) {
                    if let Ok(doc) = LazyDoc::index(&mutated) {
                        let keys: Vec<&str> = doc.keys().collect();
                        for key in keys {
                            let _ = doc.field(key);
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
