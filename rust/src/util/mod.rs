//! Hand-rolled substrates.
//!
//! This build environment has no network access for cargo and only the
//! `xla` crate (plus `anyhow`/`thiserror`) in the local registry cache, so
//! the usual ecosystem crates (serde, rand, clap, criterion, tokio) are
//! unavailable. Everything the coordinator needs from them is implemented
//! here from scratch, with tests.

pub mod bench;
pub mod clock;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
