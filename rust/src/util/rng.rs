//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is unavailable in this environment, so this module
//! implements PCG-XSH-RR 64/32 (O'Neill 2014) seeded through SplitMix64,
//! which is plenty for workload generation and token sampling. Every
//! consumer of randomness in the system takes an explicit [`Rng`] so runs
//! are reproducible from a single root seed.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 — used to derive well-mixed seeds from small integers.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed and a stream id. Distinct stream ids
    /// give statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Rng {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let mut sm2 = stream.wrapping_add(0xDA3E39CB94B95BDB);
        let inc = splitmix64(&mut sm2) | 1; // must be odd
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s0);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-request streams).
    pub fn split(&mut self) -> Rng {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Rng::new(seed, stream)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method
    /// (unbiased).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)` (half-open).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "Rng::range empty interval");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (for Poisson arrival processes).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Uniformly choose one element.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "Rng::choice on empty slice");
        &items[self.below(items.len() as u64) as usize]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "Rng::weighted with zero total weight");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from a categorical given logits with a temperature, using
    /// numerically-stable softmax. `temperature == 0` is argmax.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        debug_assert!(!logits.is_empty());
        if temperature <= 0.0 {
            return argmax(logits);
        }
        let inv_t = 1.0 / temperature as f64;
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut probs: Vec<f64> = logits
            .iter()
            .map(|&l| ((l as f64 - max) * inv_t).exp())
            .collect();
        let total: f64 = probs.iter().sum();
        if !(total > 0.0) {
            return argmax(logits);
        }
        for p in probs.iter_mut() {
            *p /= total;
        }
        self.weighted(&probs)
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7, 0);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3, 0);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5, 0);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_logits_temperature_zero_is_argmax() {
        let mut r = Rng::new(9, 0);
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        for _ in 0..10 {
            assert_eq!(r.sample_logits(&logits, 0.0), 1);
        }
    }

    #[test]
    fn sample_logits_respects_distribution() {
        let mut r = Rng::new(13, 0);
        // logit gap of ln(9) => p ≈ [0.9, 0.1]
        let logits = [9.0f32.ln(), 0.0];
        let n = 20_000;
        let ones = (0..n)
            .filter(|_| r.sample_logits(&logits, 1.0) == 1)
            .count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.1).abs() < 0.015, "frac {frac}");
    }

    #[test]
    fn split_independent() {
        let mut root = Rng::new(1, 0);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
