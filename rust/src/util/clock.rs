//! Clocks: real wall-clock and a deterministic simulated clock.
//!
//! Latency is a first-class cost in this system (the paper's `λ_L` term),
//! so all timing flows through the [`Clock`] trait:
//!
//! * [`RealClock`] measures actual wall-time — used for all reported
//!   figures (the engine genuinely executes batched generate/score calls,
//!   so parallel-vs-incremental latency structure is real).
//! * [`SimClock`] advances a virtual clock according to a calibrated
//!   [`LatencyModel`] — used in tests (deterministic) and to emulate a
//!   higher-parallelism accelerator (an A100-like device where batched
//!   generation scales sublinearly with batch size).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// An engine-level timing event, charged to the clock in sim mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CostEvent {
    /// One batched prefill call: batch size and (padded) sequence length.
    Prefill { batch: usize, len: usize },
    /// One batched single-token decode step.
    DecodeStep { batch: usize },
    /// One batched PRM scoring call.
    PrmScore { batch: usize, len: usize },
    /// One batched embedding call.
    Embed { batch: usize },
    /// One probe forward/train call.
    Probe { batch: usize },
}

/// Clock abstraction. Millisecond f64 timestamps since clock start.
pub trait Clock: Send + Sync {
    /// Current time in milliseconds.
    fn now_ms(&self) -> f64;
    /// Charge a compute event (no-op for the real clock, which observes
    /// actual elapsed time instead).
    fn charge(&self, event: CostEvent);
    /// True if this clock is simulated (affects how callers measure spans).
    fn is_sim(&self) -> bool {
        false
    }
}

/// Wall-clock time since construction.
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> RealClock {
        RealClock {
            start: Instant::now(),
        }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
    fn charge(&self, _event: CostEvent) {}
}

/// Calibrated linear cost model for the simulated clock, in milliseconds.
///
/// The default constants model an accelerator where a batched call costs
/// `fixed + per_token·tokens·batch^α` with α < 1 capturing batch
/// parallelism: doubling the number of parallel candidates costs far less
/// than 2× latency — exactly the effect that makes best-of-N latency-cheap
/// relative to beam search in the paper.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Fixed per-call launch overhead (ms).
    pub call_overhead_ms: f64,
    /// Cost per token per "effective batch row" for prefill (ms).
    pub prefill_per_token_ms: f64,
    /// Cost per decode step per effective batch row (ms).
    pub decode_step_ms: f64,
    /// Cost per token per effective row for PRM scoring (ms).
    pub prm_per_token_ms: f64,
    /// Cost of one batched embed call per effective row (ms).
    pub embed_ms: f64,
    /// Cost of one probe call (ms).
    pub probe_ms: f64,
    /// Batch-parallelism exponent in [0, 1]: effective rows = batch^alpha.
    pub batch_alpha: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        // Rough A100-class shape for a small model: decode step ~9ms
        // regardless of modest batch growth, prefill ~0.02 ms/token.
        LatencyModel {
            call_overhead_ms: 2.0,
            prefill_per_token_ms: 0.02,
            decode_step_ms: 9.0,
            prm_per_token_ms: 0.015,
            embed_ms: 3.0,
            probe_ms: 0.2,
            batch_alpha: 0.15,
        }
    }
}

impl LatencyModel {
    fn effective_rows(&self, batch: usize) -> f64 {
        (batch.max(1) as f64).powf(self.batch_alpha)
    }

    /// Milliseconds charged for an event.
    pub fn cost_ms(&self, event: CostEvent) -> f64 {
        match event {
            CostEvent::Prefill { batch, len } => {
                self.call_overhead_ms
                    + self.prefill_per_token_ms * len as f64 * self.effective_rows(batch)
            }
            CostEvent::DecodeStep { batch } => {
                self.call_overhead_ms + self.decode_step_ms * self.effective_rows(batch)
            }
            CostEvent::PrmScore { batch, len } => {
                self.call_overhead_ms
                    + self.prm_per_token_ms * len as f64 * self.effective_rows(batch)
            }
            CostEvent::Embed { batch } => {
                self.call_overhead_ms + self.embed_ms * self.effective_rows(batch)
            }
            CostEvent::Probe { .. } => self.probe_ms,
        }
    }
}

/// Deterministic virtual clock driven by a [`LatencyModel`].
///
/// Time is stored as nanoseconds in an atomic so the clock can be shared
/// across threads without locks.
pub struct SimClock {
    ns: AtomicU64,
    model: LatencyModel,
}

impl SimClock {
    pub fn new(model: LatencyModel) -> SimClock {
        SimClock {
            ns: AtomicU64::new(0),
            model,
        }
    }

    pub fn model(&self) -> &LatencyModel {
        &self.model
    }
}

impl Clock for SimClock {
    fn now_ms(&self) -> f64 {
        self.ns.load(Ordering::SeqCst) as f64 / 1e6
    }

    fn charge(&self, event: CostEvent) {
        let add_ns = (self.model.cost_ms(event) * 1e6) as u64;
        self.ns.fetch_add(add_ns, Ordering::SeqCst);
    }

    fn is_sim(&self) -> bool {
        true
    }
}

/// Shared clock handle.
pub type SharedClock = Arc<dyn Clock>;

/// Convenience constructor for the default real clock.
pub fn real_clock() -> SharedClock {
    Arc::new(RealClock::new())
}

/// Convenience constructor for a simulated clock with the default model.
pub fn sim_clock() -> SharedClock {
    Arc::new(SimClock::new(LatencyModel::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }

    #[test]
    fn sim_clock_charges_deterministically() {
        let c = SimClock::new(LatencyModel::default());
        assert_eq!(c.now_ms(), 0.0);
        c.charge(CostEvent::DecodeStep { batch: 1 });
        let t1 = c.now_ms();
        c.charge(CostEvent::DecodeStep { batch: 1 });
        let t2 = c.now_ms();
        assert!((t2 - 2.0 * t1).abs() < 1e-9);
    }

    #[test]
    fn batch_parallelism_sublinear() {
        let m = LatencyModel::default();
        let one = m.cost_ms(CostEvent::DecodeStep { batch: 1 });
        let sixteen = m.cost_ms(CostEvent::DecodeStep { batch: 16 });
        assert!(sixteen < 4.0 * one, "batched decode should be sublinear");
        assert!(sixteen > one, "but not free");
    }

    #[test]
    fn beam_vs_parallel_latency_structure() {
        // The structural claim from the paper: generating N candidates in
        // one batched pass is much cheaper in *latency* than N sequential
        // rounds, even at equal token counts.
        let m = LatencyModel::default();
        let steps = 50;
        let parallel: f64 = (0..steps)
            .map(|_| m.cost_ms(CostEvent::DecodeStep { batch: 16 }))
            .sum();
        let sequential: f64 = (0..4 * steps)
            .map(|_| m.cost_ms(CostEvent::DecodeStep { batch: 4 }))
            .sum();
        assert!(sequential > 2.0 * parallel);
    }
}
