//! The strategy space `S` the router selects from.

use crate::config::SpaceConfig;

/// Inference-scaling method families (paper §2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    MajorityVote,
    BestOfNNaive,
    BestOfNWeighted,
    Beam,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::MajorityVote => "majority_vote",
            Method::BestOfNNaive => "bon_naive",
            Method::BestOfNWeighted => "bon_weighted",
            Method::Beam => "beam",
        }
    }

    /// One-hot index for probe features (order fixed — see
    /// `python/compile/model.py::PROBE_FEATURES`).
    pub fn one_hot_index(self) -> usize {
        match self {
            Method::MajorityVote => 0,
            Method::BestOfNNaive => 1,
            Method::BestOfNWeighted => 2,
            Method::Beam => 3,
        }
    }
}

/// A fully-parameterized decoding strategy `s = (m, θ_m)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Strategy {
    pub method: Method,
    /// Candidates (parallel methods) or active beams (beam search).
    pub n: usize,
    /// Branching factor (beam search; 1 otherwise).
    pub width: usize,
    /// Max tokens per beam-search round (0 for parallel methods).
    pub chunk: usize,
}

impl Strategy {
    pub fn mv(n: usize) -> Strategy {
        Strategy {
            method: Method::MajorityVote,
            n,
            width: 1,
            chunk: 0,
        }
    }

    pub fn bon_naive(n: usize) -> Strategy {
        Strategy {
            method: Method::BestOfNNaive,
            n,
            width: 1,
            chunk: 0,
        }
    }

    pub fn bon_weighted(n: usize) -> Strategy {
        Strategy {
            method: Method::BestOfNWeighted,
            n,
            width: 1,
            chunk: 0,
        }
    }

    pub fn beam(n: usize, width: usize, chunk: usize) -> Strategy {
        Strategy {
            method: Method::Beam,
            n,
            width,
            chunk,
        }
    }

    /// Stable identifier used in matrices, figures and logs.
    pub fn id(&self) -> String {
        match self.method {
            Method::Beam => format!("beam@{}x{}c{}", self.n, self.width, self.chunk),
            m => format!("{}@{}", m.name(), self.n),
        }
    }

    /// Parse an id produced by [`Strategy::id`].
    pub fn parse(id: &str) -> Option<Strategy> {
        let (name, params) = id.split_once('@')?;
        match name {
            "beam" => {
                let (n, rest) = params.split_once('x')?;
                let (w, c) = rest.split_once('c')?;
                Some(Strategy::beam(
                    n.parse().ok()?,
                    w.parse().ok()?,
                    c.parse().ok()?,
                ))
            }
            "majority_vote" => Some(Strategy::mv(params.parse().ok()?)),
            "bon_naive" => Some(Strategy::bon_naive(params.parse().ok()?)),
            "bon_weighted" => Some(Strategy::bon_weighted(params.parse().ok()?)),
            _ => None,
        }
    }

    /// Enumerate the full space from config.
    pub fn enumerate(space: &SpaceConfig) -> Vec<Strategy> {
        let mut out = Vec::new();
        for &n in &space.mv_ns {
            out.push(Strategy::mv(n));
        }
        for &n in &space.bon_ns {
            out.push(Strategy::bon_naive(n));
        }
        for &n in &space.bon_ns {
            out.push(Strategy::bon_weighted(n));
        }
        for &(n, w, c) in &space.beam {
            out.push(Strategy::beam(n, w, c));
        }
        out
    }

    /// Beam-search-only sub-space (Fig 9).
    pub fn enumerate_beam_only(space: &SpaceConfig) -> Vec<Strategy> {
        space
            .beam
            .iter()
            .map(|&(n, w, c)| Strategy::beam(n, w, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_roundtrip() {
        let space = SpaceConfig::default();
        for s in Strategy::enumerate(&space) {
            let parsed = Strategy::parse(&s.id()).expect("parse");
            assert_eq!(parsed, s, "id {}", s.id());
        }
    }

    #[test]
    fn enumerate_counts() {
        let space = SpaceConfig::default();
        let all = Strategy::enumerate(&space);
        assert_eq!(
            all.len(),
            space.mv_ns.len() + 2 * space.bon_ns.len() + space.beam.len()
        );
        // ids unique
        let mut ids: Vec<String> = all.iter().map(|s| s.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), all.len());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Strategy::parse("nope@3").is_none());
        assert!(Strategy::parse("beam@ax2c3").is_none());
        assert!(Strategy::parse("majority_vote").is_none());
    }
}
