//! The strategy space `S` the router selects from.
//!
//! A [`Strategy`] names a registered [`crate::strategies::DecodingMethod`]
//! by its stable id and carries the hyperparameters `θ_m`. Ids round-trip
//! through [`Strategy::id`] / [`Strategy::parse`] for *any* registered
//! method — matrices, cost-model keys, probe features, figures and the
//! CLI all resolve methods by name, never by enum arm, so growing the
//! method set never touches them.

use crate::config::SpaceConfig;
use crate::strategies::method::StrategyParams;
use crate::strategies::registry;

/// A fully-parameterized decoding strategy `s = (m, θ_m)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Strategy {
    /// Stable id of the registered decoding method.
    pub method: &'static str,
    /// Candidates (parallel methods) or active beams (beam family).
    pub n: usize,
    /// Branching factor (beam family; 1 otherwise).
    pub width: usize,
    /// Max tokens per beam-search round (0 for parallel methods).
    pub chunk: usize,
}

impl Strategy {
    pub fn new(method: &'static str, params: StrategyParams) -> Strategy {
        Strategy {
            method,
            n: params.n,
            width: params.width,
            chunk: params.chunk,
        }
    }

    pub fn mv(n: usize) -> Strategy {
        Strategy::new("majority_vote", StrategyParams::parallel(n))
    }

    pub fn bon_naive(n: usize) -> Strategy {
        Strategy::new("bon_naive", StrategyParams::parallel(n))
    }

    pub fn bon_weighted(n: usize) -> Strategy {
        Strategy::new("bon_weighted", StrategyParams::parallel(n))
    }

    pub fn beam(n: usize, width: usize, chunk: usize) -> Strategy {
        Strategy::new("beam", StrategyParams::beam(n, width, chunk))
    }

    pub fn mv_early(n: usize) -> Strategy {
        Strategy::new("mv_early", StrategyParams::parallel(n))
    }

    /// `mv_early` with an explicit wave size (`wave <= 1` = auto); the
    /// wave rides in `width` like beam's W.
    pub fn mv_early_wave(n: usize, wave: usize) -> Strategy {
        Strategy::new("mv_early", StrategyParams::waves(n, wave))
    }

    pub fn beam_latency(n: usize, width: usize, chunk: usize) -> Strategy {
        Strategy::new("beam_latency", StrategyParams::beam(n, width, chunk))
    }

    /// The hyperparameters `θ_m` as passed to the decoding method.
    pub fn params(&self) -> StrategyParams {
        StrategyParams {
            n: self.n,
            width: self.width,
            chunk: self.chunk,
        }
    }

    /// Is the method round-based (beam family)? Drives the rounds probe
    /// feature and the round-structured figures.
    pub fn uses_rounds(&self) -> bool {
        registry::get(self.method).is_some_and(|m| m.uses_rounds())
    }

    /// Stable identifier used in matrices, figures and logs — the
    /// method's registry id plus its formatted `θ_m`.
    pub fn id(&self) -> String {
        match registry::get(self.method) {
            Some(m) => format!("{}@{}", self.method, m.format_params(&self.params())),
            None => format!("{}@{}", self.method, self.n),
        }
    }

    /// Parse an id produced by [`Strategy::id`] — resolves the method in
    /// the registry, so newly registered methods parse with no changes
    /// here.
    pub fn parse(id: &str) -> Option<Strategy> {
        let (name, params) = id.split_once('@')?;
        let method = registry::get(name)?;
        Some(Strategy::new(method.name(), method.parse_params(params)?))
    }

    /// Enumerate the full space from config. `extra` ids are validated at
    /// config-merge time; anything unparseable here is skipped.
    pub fn enumerate(space: &SpaceConfig) -> Vec<Strategy> {
        let mut out = Vec::new();
        for &n in &space.mv_ns {
            out.push(Strategy::mv(n));
        }
        for &n in &space.bon_ns {
            out.push(Strategy::bon_naive(n));
        }
        for &n in &space.bon_ns {
            out.push(Strategy::bon_weighted(n));
        }
        for &(n, w, c) in &space.beam {
            out.push(Strategy::beam(n, w, c));
        }
        for &(n, wave) in &space.mv_early {
            out.push(Strategy::mv_early_wave(n, wave));
        }
        for id in &space.extra {
            if let Some(s) = Strategy::parse(id) {
                if !out.contains(&s) {
                    out.push(s);
                }
            }
        }
        out
    }

    /// Beam-search-only sub-space (Fig 9).
    pub fn enumerate_beam_only(space: &SpaceConfig) -> Vec<Strategy> {
        space
            .beam
            .iter()
            .map(|&(n, w, c)| Strategy::beam(n, w, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_roundtrip() {
        let space = SpaceConfig::default();
        for s in Strategy::enumerate(&space) {
            let parsed = Strategy::parse(&s.id()).expect("parse");
            assert_eq!(parsed, s, "id {}", s.id());
        }
    }

    #[test]
    fn every_registered_method_roundtrips() {
        // Registry round-trip: `Strategy::parse(id) == strategy` for
        // every registered method at several parameter points.
        for m in registry::all() {
            for params in [
                m.default_params(),
                StrategyParams { n: 1, ..m.default_params() },
                StrategyParams { n: 16, ..m.default_params() },
            ] {
                let s = Strategy::new(m.name(), params);
                let parsed = Strategy::parse(&s.id());
                assert_eq!(parsed, Some(s.clone()), "id {}", s.id());
            }
        }
    }

    #[test]
    fn enumerate_counts() {
        let space = SpaceConfig::default();
        let all = Strategy::enumerate(&space);
        assert_eq!(
            all.len(),
            space.mv_ns.len()
                + 2 * space.bon_ns.len()
                + space.beam.len()
                + space.mv_early.len()
                + space.extra.len()
        );
        // default space exercises both new methods, including an
        // explicit-wave mv_early point the router can pick
        assert!(all.iter().any(|s| s.method == "mv_early"));
        assert!(all.iter().any(|s| s.id() == "mv_early@16w4"));
        assert!(all.iter().any(|s| s.method == "beam_latency"));
        // ids unique
        let mut ids: Vec<String> = all.iter().map(|s| s.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), all.len());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Strategy::parse("nope@3").is_none());
        assert!(Strategy::parse("beam@ax2c3").is_none());
        assert!(Strategy::parse("majority_vote").is_none());
        assert!(Strategy::parse("mv_early@").is_none());
        assert!(Strategy::parse("beam_latency@2x2").is_none());
    }

    #[test]
    fn beam_family_ids_carry_full_params() {
        assert_eq!(Strategy::beam(4, 2, 12).id(), "beam@4x2c12");
        assert_eq!(Strategy::beam_latency(4, 2, 12).id(), "beam_latency@4x2c12");
        assert_eq!(Strategy::mv_early(8).id(), "mv_early@8");
        assert!(Strategy::beam_latency(4, 2, 12).uses_rounds());
        assert!(!Strategy::mv_early(8).uses_rounds());
    }

    #[test]
    fn mv_early_wave_ids_roundtrip() {
        let s = Strategy::mv_early_wave(16, 4);
        assert_eq!(s.id(), "mv_early@16w4");
        assert_eq!(Strategy::parse("mv_early@16w4"), Some(s));
        // auto wave (<= 1) keeps the legacy id shape
        assert_eq!(Strategy::mv_early_wave(16, 1).id(), "mv_early@16");
        assert_eq!(
            Strategy::parse("mv_early@16"),
            Some(Strategy::mv_early(16))
        );
        assert!(Strategy::parse("mv_early@16wx").is_none());
    }
}
