//! The continuation executor: many in-flight step machines, one thread.
//!
//! The blocking serving shape (PR 1–3) pinned every request to a driver
//! worker thread that sat inside `DecodingMethod::run` until the
//! strategy finished — so the engine's coalescing scheduler could only
//! merge work that happened to be concurrently in flight across
//! threads, and concurrency was capped by thread count. The [`Stepper`]
//! replaces that with continuations: each request is a
//! [`StrategyState`] machine, and one event loop
//!
//! 1. **advances** every machine whose input is ready, *submitting* the
//!    engine work it yields without blocking
//!    ([`crate::engine::EngineHandle::submit_generate`] /
//!    `submit_prm_score`) — all
//!    runnable machines' submissions land on the engine channel before
//!    anyone waits, so the scheduler drains them into one coalescing
//!    round (N concurrent beam requests' round-k expansions become
//!    shared bucket-shaped calls);
//! 2. **blocks** for the oldest outstanding reply only when nothing is
//!    runnable, then harvests every other reply that has also arrived;
//! 3. on completion, runs the between-steps [`Reallocator`] hook: the
//!    finished request's leftover budget (deadline headroom, unspent
//!    token cap) is granted to still-running machines by extending
//!    their budgets — machines re-read `ctx.budget` every step, so a
//!    grant takes effect at the next loop head (an extended beam
//!    deadline fits more rounds, a raised token cap widens what the
//!    remaining `mv_early` waves may keep).
//!
//! Errors are request-fatal and stepper-fatal: the serving layers above
//! treat any strategy error as a failed run (same contract as the
//! blocking driver), so [`Stepper::advance`] propagates the first one.

use crate::engine::{GenResult, PendingReply};
use crate::error::{Error, Result};
use crate::metrics::StepperMetrics;
use crate::router::{FinishedRequest, Reallocator, RunningView};
use crate::strategies::executor::{resolve, Executor};
use crate::strategies::method::{Budget, Outcome, StepInput, StepYield, StrategyState};
use crate::strategies::space::Strategy;
use std::time::Duration;

/// One request handed to the stepper.
pub struct Ticket {
    /// Full query text (incl. the trailing `\n`).
    pub query: String,
    pub strategy: Strategy,
    pub budget: Budget,
    /// Caller correlation id, returned on the [`Completion`].
    pub tag: u64,
}

/// A finished request.
#[derive(Debug)]
pub struct Completion {
    pub tag: u64,
    /// Pre-rendered strategy id (rendering consults the registry; done
    /// once at admission, not per completion consumer).
    pub strategy_id: String,
    pub outcome: Outcome,
}

/// What [`Stepper::advance`] accomplished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Progress {
    /// No machines in flight — admit work or stop.
    Idle,
    /// At least one machine stepped or became runnable.
    Stepped,
    /// Waited `wait` without any reply arriving (lets the caller admit
    /// newly arrived requests on time).
    TimedOut,
}

/// What a machine is waiting on between steps.
enum Waiting {
    /// Input ready — runnable on the next advance.
    Ready(StepInput),
    /// A generate call is in flight.
    Generate(PendingReply<Vec<crate::engine::GenResult>>),
    /// A [`StepYield::GenerateEach`] fan-out is in flight: one
    /// single-job engine request per row, harvested independently so
    /// the machine's [`StrategyState::on_row_result`] hook fires the
    /// moment each row finishes (that is what lets `mv_early` stop the
    /// rest of a wave mid-decode). Flips to `Ready(Generated)` once
    /// every row is in.
    GenerateMulti {
        /// Outstanding replies by row; harvested slots become `None`.
        pending: Vec<Option<PendingReply<Vec<GenResult>>>>,
        /// Arrived results by row, awaiting assembly.
        results: Vec<Option<GenResult>>,
        outstanding: usize,
    },
    /// A PRM scoring call is in flight.
    Score(PendingReply<Vec<f32>>),
}

/// One in-flight request: its machine plus everything needed to rebuild
/// the step context (the query is owned here; the budget is owned here
/// *so the reallocation hook can extend it between steps*).
struct Active {
    tag: u64,
    query: String,
    strategy_id: String,
    budget: Budget,
    /// Admission time on the engine clock — elapsed/leftover accounting
    /// for reallocation.
    t0: f64,
    state: Box<dyn StrategyState>,
    waiting: Waiting,
}

/// Multiplexes many in-flight [`StrategyState`] machines onto one
/// engine. Single-threaded by design: strategy-side compute between
/// yields (voting, tokenizing, selection) is microseconds against
/// engine calls, so one pump thread drives arbitrarily many requests.
pub struct Stepper {
    executor: Executor,
    reallocator: Option<Box<dyn Reallocator>>,
    pub metrics: StepperMetrics,
    active: Vec<Active>,
    done: Vec<Completion>,
}

impl Stepper {
    pub fn new(executor: Executor) -> Stepper {
        Stepper {
            executor,
            reallocator: None,
            metrics: StepperMetrics::new(),
            active: Vec::new(),
            done: Vec::new(),
        }
    }

    /// Install a between-steps budget reallocation policy (e.g.
    /// [`crate::router::EvenShareReallocator`]).
    pub fn with_reallocator(mut self, reallocator: Box<dyn Reallocator>) -> Stepper {
        self.reallocator = Some(reallocator);
        self
    }

    /// Requests currently in flight.
    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    /// Take every completion recorded since the last drain.
    pub fn drain_completed(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Admit one request: start its step machine (anchored at the
    /// current engine-clock time) and mark it runnable. The machine
    /// issues no engine work until the next [`Stepper::advance`].
    pub fn admit(&mut self, ticket: Ticket) -> Result<()> {
        let method = resolve(ticket.strategy.method)?;
        let strategy_id = ticket.strategy.id();
        let params = ticket.strategy.params();
        let t0 = self.executor.clock.now_ms();
        let state = {
            let ctx = self.executor.ctx(&ticket.query, ticket.budget.clone());
            method.start(&ctx, &params)?
        };
        self.metrics.machines_admitted.inc();
        self.active.push(Active {
            tag: ticket.tag,
            query: ticket.query,
            strategy_id,
            budget: ticket.budget,
            t0,
            state,
            waiting: Waiting::Ready(StepInput::Start),
        });
        Ok(())
    }

    /// One scheduling round: step every runnable machine (submitting
    /// yielded engine work without blocking), and if none was runnable,
    /// block up to `wait` for the oldest outstanding engine reply.
    pub fn advance(&mut self, wait: Option<Duration>) -> Result<Progress> {
        if self.active.is_empty() {
            return Ok(Progress::Idle);
        }
        let mut stepped = false;
        let mut i = 0;
        while i < self.active.len() {
            if !matches!(self.active[i].waiting, Waiting::Ready(_)) {
                i += 1;
                continue;
            }
            let input = match std::mem::replace(
                &mut self.active[i].waiting,
                Waiting::Ready(StepInput::Start),
            ) {
                Waiting::Ready(input) => input,
                _ => unreachable!("checked Ready above"),
            };
            stepped = true;
            self.metrics.steps.inc();
            let yielded = {
                let m = &mut self.active[i];
                let ctx = self.executor.ctx(&m.query, m.budget.clone());
                m.state.step(&ctx, input)?
            };
            match yielded {
                StepYield::Generate { jobs, deadline_ms } => {
                    let reply = self.executor.engine.submit_generate(jobs, deadline_ms)?;
                    self.metrics.engine_submits.inc();
                    self.active[i].waiting = Waiting::Generate(reply);
                    i += 1;
                }
                StepYield::GenerateEach { jobs, deadline_ms } => {
                    let n = jobs.len();
                    let mut pending = Vec::with_capacity(n);
                    for job in jobs {
                        pending.push(Some(self.executor.engine.submit_generate(vec![job], deadline_ms)?));
                        self.metrics.engine_submits.inc();
                    }
                    self.active[i].waiting = if n == 0 {
                        Waiting::Ready(StepInput::Generated(Vec::new()))
                    } else {
                        Waiting::GenerateMulti {
                            pending,
                            results: (0..n).map(|_| None).collect(),
                            outstanding: n,
                        }
                    };
                    i += 1;
                }
                StepYield::PrmScore(prefixes) => {
                    let reply = self.executor.engine.submit_prm_score(prefixes)?;
                    self.metrics.engine_submits.inc();
                    self.active[i].waiting = Waiting::Score(reply);
                    i += 1;
                }
                StepYield::Done(outcome) => {
                    // swap_remove: the machine that took this slot gets
                    // revisited because `i` does not advance
                    let m = self.active.swap_remove(i);
                    self.metrics.machines_completed.inc();
                    self.reallocate_on_finish(&m, &outcome);
                    self.done.push(Completion {
                        tag: m.tag,
                        strategy_id: m.strategy_id,
                        outcome,
                    });
                }
            }
        }
        if stepped {
            return Ok(Progress::Stepped);
        }
        if self.active.is_empty() {
            return Ok(Progress::Idle);
        }

        // Nothing runnable: poll every in-flight reply first, so one
        // slow call in slot 0 never head-of-line-blocks machines whose
        // replies already arrived…
        if self.harvest_replies()? {
            return Ok(Progress::Stepped);
        }
        // …and only then block for slot 0's reply.
        if matches!(self.active[0].waiting, Waiting::GenerateMulti { .. }) {
            // Block on the fan-out's first outstanding row; even a
            // partial arrival is progress (the per-row hook ran), but
            // only a fully-assembled set makes the machine runnable.
            let became_ready = poll_generate_multi(&self.executor, &mut self.active[0], Some(wait))?;
            if became_ready || self.harvest_replies()? {
                return Ok(Progress::Stepped);
            }
            return Ok(Progress::TimedOut);
        }
        let ready = match &self.active[0].waiting {
            Waiting::Generate(reply) => reply
                .wait_timeout(wait)
                .map(|r| r.map(StepInput::Generated)),
            Waiting::Score(reply) => reply.wait_timeout(wait).map(|r| r.map(StepInput::Scored)),
            Waiting::GenerateMulti { .. } => unreachable!("handled above"),
            Waiting::Ready(_) => unreachable!("no machine was runnable"),
        };
        match ready {
            None => {
                // Replies may have landed on other machines while we
                // waited — a timeout must still make their progress.
                if self.harvest_replies()? {
                    return Ok(Progress::Stepped);
                }
                return Ok(Progress::TimedOut);
            }
            Some(input) => self.active[0].waiting = Waiting::Ready(input?),
        }
        // Harvest every other reply that has also arrived, so the next
        // sweep advances as many machines as possible together (their
        // follow-up submissions coalesce).
        self.harvest_replies()?;
        Ok(Progress::Stepped)
    }

    /// Non-blocking pass over every in-flight machine, turning arrived
    /// replies into runnable inputs. Returns whether any machine became
    /// runnable.
    fn harvest_replies(&mut self) -> Result<bool> {
        let mut any = false;
        let executor = &self.executor;
        for m in self.active.iter_mut() {
            if matches!(m.waiting, Waiting::GenerateMulti { .. }) {
                if poll_generate_multi(executor, m, None)? {
                    any = true;
                }
                continue;
            }
            let harvested = match &m.waiting {
                Waiting::Generate(reply) => {
                    reply.try_wait().map(|r| r.map(StepInput::Generated))
                }
                Waiting::Score(reply) => reply.try_wait().map(|r| r.map(StepInput::Scored)),
                Waiting::GenerateMulti { .. } => unreachable!("handled above"),
                Waiting::Ready(_) => None,
            };
            if let Some(input) = harvested {
                m.waiting = Waiting::Ready(input?);
                any = true;
            }
        }
        Ok(any)
    }

    /// Pump until every admitted machine has completed.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.advance(None)? != Progress::Idle {}
        Ok(())
    }

    /// The between-steps reallocation hook: compute what the finished
    /// request left on the table and let the policy grant it to the
    /// still-running machines. Grants only ever *extend* limits a
    /// machine already has (the [`crate::router::Grant`] contract).
    fn reallocate_on_finish(&mut self, finished: &Active, outcome: &Outcome) {
        let Some(reallocator) = self.reallocator.as_mut() else {
            return;
        };
        if self.active.is_empty() {
            return;
        }
        let now = self.executor.clock.now_ms();
        let leftover_ms = match finished.budget.deadline_ms {
            Some(d) => (finished.t0 + d - now).max(0.0),
            None => 0.0,
        };
        let leftover_tokens = finished
            .budget
            .max_tokens
            .map_or(0, |cap| cap.saturating_sub(outcome.tokens));
        if leftover_ms <= 0.0 && leftover_tokens == 0 {
            return;
        }
        let running: Vec<RunningView<'_>> = self
            .active
            .iter()
            .map(|m| RunningView {
                strategy_id: &m.strategy_id,
                budget: &m.budget,
                elapsed_ms: now - m.t0,
            })
            .collect();
        let grants = reallocator.reallocate(
            &FinishedRequest {
                strategy_id: &finished.strategy_id,
                leftover_ms,
                leftover_tokens,
            },
            &running,
        );
        drop(running);
        let mut any = false;
        for (m, g) in self.active.iter_mut().zip(grants) {
            let mut granted = false;
            if g.extra_ms > 0.0 {
                if let Some(d) = m.budget.deadline_ms {
                    m.budget.deadline_ms = Some(d + g.extra_ms);
                    self.metrics.realloc_us_granted.add((g.extra_ms * 1e3) as u64);
                    granted = true;
                }
            }
            if g.extra_tokens > 0 {
                if let Some(cap) = m.budget.max_tokens {
                    m.budget.max_tokens = Some(cap + g.extra_tokens);
                    self.metrics
                        .realloc_tokens_granted
                        .add(g.extra_tokens as u64);
                    granted = true;
                }
            }
            if granted {
                self.metrics.realloc_grants.inc();
                any = true;
            }
        }
        if any {
            self.metrics.realloc_events.inc();
        }
    }
}

/// Poll one [`Waiting::GenerateMulti`] fan-out: harvest every arrived
/// row (firing the machine's [`StrategyState::on_row_result`] hook as
/// each lands). `block` is two-level: `None` = non-blocking sweep only
/// (the harvest pass); `Some(wait)` = first block on the earliest
/// outstanding reply with [`PendingReply::wait_timeout`] semantics
/// (inner `None` = indefinitely). Returns whether the machine became
/// runnable (all rows in → `Ready(Generated)` in row order). A free
/// function — not a method — so callers can hold `&executor` and
/// `&mut active[i]` as disjoint field borrows.
fn poll_generate_multi(
    executor: &Executor,
    m: &mut Active,
    block: Option<Option<Duration>>,
) -> Result<bool> {
    let Active {
        query,
        budget,
        state,
        waiting,
        ..
    } = m;
    let Waiting::GenerateMulti {
        pending,
        results,
        outstanding,
    } = waiting
    else {
        return Ok(false);
    };
    let ctx = executor.ctx(query, budget.clone());
    let settle = |reply: Result<Vec<GenResult>>| -> Result<GenResult> {
        reply?
            .into_iter()
            .next()
            .ok_or_else(|| Error::internal("engine returned no rows for a single-job request"))
    };
    // Blocking pass first (the caller had nothing runnable)…
    if let Some(wait) = block {
        if let Some(row) = pending.iter().position(Option::is_some) {
            let reply = pending[row].as_ref().expect("position found Some");
            if let Some(r) = reply.wait_timeout(wait) {
                let result = settle(r)?;
                state.on_row_result(&ctx, row, &result);
                results[row] = Some(result);
                pending[row] = None;
                *outstanding -= 1;
            }
        }
    }
    // …then sweep the rest non-blockingly.
    for (row, slot) in pending.iter_mut().enumerate() {
        let Some(reply) = slot else { continue };
        if let Some(r) = reply.try_wait() {
            let result = settle(r)?;
            state.on_row_result(&ctx, row, &result);
            results[row] = Some(result);
            *slot = None;
            *outstanding -= 1;
        }
    }
    if *outstanding == 0 {
        let collected: Vec<GenResult> = results
            .iter_mut()
            .map(|r| r.take().expect("all rows arrived"))
            .collect();
        *waiting = Waiting::Ready(StepInput::Generated(collected));
        return Ok(true);
    }
    Ok(false)
}

#[cfg(test)]
mod tests {
    //! Machine-level tests against the sim execution backend: step
    //! machines never touch `ctx.engine` directly (work is expressed as
    //! yields), so most tests drive them with synthetic `GenResult`s —
    //! and because the backend is the artifact-free
    //! [`crate::engine::SimBackend`], the same harness also runs
    //! machines engine-full through `run_to_completion`.

    use super::*;
    use crate::config::{BackendKind, Config};
    use crate::engine::{Engine, GenResult};
    use crate::strategies::method::StrategyParams;
    use crate::tokenizer::Tokenizer;

    fn harness() -> (Engine, Executor) {
        let mut cfg = Config::default();
        cfg.engine.backend = BackendKind::Sim;
        cfg.engine.sim_clock = true;
        let engine = Engine::start(&cfg).unwrap();
        let executor = Executor::new(engine.handle(), engine.clock.clone(), 0.0);
        (engine, executor)
    }

    fn gen_result(tok: &Tokenizer, text: &str) -> GenResult {
        GenResult {
            tokens: tok.encode(text).unwrap(),
            call_ms: 1.0,
            batch_size: 1,
            preempted: false,
        }
    }

    /// Drive one machine by hand, answering Generate yields with
    /// `answers` in order; panics if the machine wants more scoring
    /// rounds than `scores` provides.
    fn drive_with(
        executor: &Executor,
        strategy: &Strategy,
        budget: Budget,
        answers: &mut dyn Iterator<Item = Vec<GenResult>>,
        scores: &mut dyn Iterator<Item = Vec<f32>>,
    ) -> Outcome {
        let query = "Q:1+2=?\n";
        let ctx = executor.ctx(query, budget);
        let method = resolve(strategy.method).unwrap();
        let mut state = method.start(&ctx, &strategy.params()).unwrap();
        let mut input = StepInput::Start;
        loop {
            match state.step(&ctx, input).unwrap() {
                StepYield::Generate { jobs, .. } => {
                    let batch = answers.next().expect("machine wanted another wave");
                    assert_eq!(jobs.len(), batch.len(), "job/result count mismatch");
                    input = StepInput::Generated(batch);
                }
                StepYield::GenerateEach { jobs, .. } => {
                    let batch = answers.next().expect("machine wanted another wave");
                    assert_eq!(jobs.len(), batch.len(), "job/result count mismatch");
                    for (row, result) in batch.iter().enumerate() {
                        state.on_row_result(&ctx, row, result);
                    }
                    input = StepInput::Generated(batch);
                }
                StepYield::PrmScore(prefixes) => {
                    let s = scores.next().expect("machine wanted scores");
                    assert_eq!(prefixes.len(), s.len());
                    input = StepInput::Scored(s);
                }
                StepYield::Done(outcome) => return outcome,
            }
        }
    }

    #[test]
    fn majority_vote_machine_generates_then_finishes() {
        let (_engine, ex) = harness();
        let tok = Tokenizer::new();
        let mut answers =
            std::iter::once(vec![gen_result(&tok, "1+2=3;A:3\n"), gen_result(&tok, "1+2=3;A:3\n")]);
        let mut scores = std::iter::empty::<Vec<f32>>();
        let o = drive_with(
            &ex,
            &Strategy::mv(2),
            Budget::unlimited(),
            &mut answers,
            &mut scores,
        );
        assert_eq!(o.answer.as_deref(), Some("3"));
        assert_eq!(o.engine_calls, 1);
        assert_eq!(o.rounds, 1);
        assert!(!o.budget_exhausted && !o.preempted && !o.stopped_early);
        assert!(o.tokens > 0);
    }

    #[test]
    fn bon_machine_yields_prm_and_uses_scores() {
        let (_engine, ex) = harness();
        let tok = Tokenizer::new();
        let mut answers =
            std::iter::once(vec![gen_result(&tok, "1+2=4;A:4\n"), gen_result(&tok, "1+2=3;A:3\n")]);
        // second candidate scores higher → wins
        let mut scores = std::iter::once(vec![0.1f32, 0.9]);
        let o = drive_with(
            &ex,
            &Strategy::bon_naive(2),
            Budget::unlimited(),
            &mut answers,
            &mut scores,
        );
        assert_eq!(o.answer.as_deref(), Some("3"));
        assert_eq!(o.engine_calls, 2);
    }

    #[test]
    fn mv_early_machine_stops_when_wave_margin_decides() {
        let (_engine, ex) = harness();
        let tok = Tokenizer::new();
        // N=8, wave=2 → the first wave's 2-0 margin cannot be beaten
        // only when lead > second + remaining; with 6 remaining it can,
        // so feed three unanimous waves: after wave 3 lead=6 > 0 + 2.
        let wave = || vec![gen_result(&tok, "1+2=3;A:3\n"), gen_result(&tok, "1+2=3;A:3\n")];
        let mut answers = vec![wave(), wave(), wave()].into_iter();
        let mut scores = std::iter::empty::<Vec<f32>>();
        let o = drive_with(
            &ex,
            &Strategy::mv_early_wave(8, 2),
            Budget::unlimited(),
            &mut answers,
            &mut scores,
        );
        assert!(o.stopped_early, "unanimous waves must stop early");
        assert_eq!(o.engine_calls, 3);
        assert_eq!(o.rounds, 3);
        assert_eq!(o.answer.as_deref(), Some("3"));
        assert!(answers.next().is_none(), "no fourth wave issued");
    }

    #[test]
    fn mv_early_machine_token_cap_reports_budget() {
        let (_engine, ex) = harness();
        let tok = Tokenizer::new();
        let mut answers = std::iter::once(vec![
            gen_result(&tok, "1+2=3;A:3\n"),
            gen_result(&tok, "1+2=3;A:3\n"),
        ]);
        let mut scores = std::iter::empty::<Vec<f32>>();
        let o = drive_with(
            &ex,
            &Strategy::mv_early_wave(8, 2),
            Budget::unlimited().with_max_tokens(3),
            &mut answers,
            &mut scores,
        );
        assert!(o.budget_exhausted);
        assert!(o.tokens <= 3, "token accounting capped: {}", o.tokens);
    }

    #[test]
    fn beam_machine_rounds_and_prm_memoization() {
        let (_engine, ex) = harness();
        let tok = Tokenizer::new();
        // Round 0: N·W = 2 expansion jobs for the root; both end with
        // '\n' so every beam is done after one round → round 1 issues
        // no jobs and the machine finishes.
        let mut answers = std::iter::once(vec![
            gen_result(&tok, "1+2=3;A:3\n"),
            gen_result(&tok, "1+2=3;A:3\n"),
        ]);
        let mut scores = std::iter::once(vec![0.7f32, 0.6]);
        let o = drive_with(
            &ex,
            &Strategy::beam(2, 1, 12),
            Budget::unlimited(),
            &mut answers,
            &mut scores,
        );
        assert_eq!(o.answer.as_deref(), Some("3"));
        assert_eq!(o.rounds, 1);
        // one generate + one scoring pass
        assert_eq!(o.engine_calls, 2);
        assert!(!o.budget_exhausted);
    }

    #[test]
    fn finished_machine_errors_on_extra_step() {
        let (_engine, ex) = harness();
        let ctx = ex.ctx("Q:1+2=?\n", Budget::unlimited());
        let method = resolve("majority_vote").unwrap();
        let mut state = method.start(&ctx, &StrategyParams::parallel(1)).unwrap();
        let tok = Tokenizer::new();
        let y = state.step(&ctx, StepInput::Start).unwrap();
        let y = match y {
            StepYield::Generate { .. } => state
                .step(
                    &ctx,
                    StepInput::Generated(vec![gen_result(&tok, "1+2=3;A:3\n")]),
                )
                .unwrap(),
            other => panic!("expected Generate, got {other:?}"),
        };
        assert!(matches!(y, StepYield::Done(_)));
        assert!(state.step(&ctx, StepInput::Start).is_err());
    }

    #[test]
    fn machines_run_engine_full_on_the_sim_backend() {
        // The backend-level mock that replaced the old disconnected
        // handle: machines run to completion through a real engine
        // thread (scheduler, batcher, preemption) with no artifacts.
        let (engine, ex) = harness();
        let mut stepper = Stepper::new(ex.clone());
        for (i, strategy) in [Strategy::mv(4), Strategy::beam(2, 2, 12)]
            .into_iter()
            .enumerate()
        {
            stepper
                .admit(Ticket {
                    query: "Q:7+8-5=?\n".into(),
                    strategy,
                    budget: Budget::unlimited(),
                    tag: i as u64,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        let done = stepper.drain_completed();
        assert_eq!(done.len(), 2);
        for c in &done {
            // temp 0 on the sim backend follows the ground-truth chain
            assert_eq!(c.outcome.answer.as_deref(), Some("0"), "{}", c.strategy_id);
            assert!(c.outcome.tokens > 0);
        }
        assert!(engine.metrics.decode_calls.get() > 0);
    }

    #[test]
    fn spent_budget_yields_empty_outcome_without_engine_work() {
        let (_engine, ex) = harness();
        let mut answers = std::iter::empty::<Vec<GenResult>>();
        let mut scores = std::iter::empty::<Vec<f32>>();
        let o = drive_with(
            &ex,
            &Strategy::mv(4),
            Budget::unlimited().with_max_tokens(0),
            &mut answers,
            &mut scores,
        );
        assert!(o.budget_exhausted);
        assert_eq!(o.tokens, 0);
        assert_eq!(o.engine_calls, 0);
    }
}
