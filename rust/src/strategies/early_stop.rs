//! Early-stopping self-consistency (`mv_early`).
//!
//! Majority voting that issues candidates in *waves* and stops as soon as
//! the vote is decided: when the leading answer's margin over the
//! runner-up exceeds the number of candidates not yet issued, no
//! remaining outcome can flip the result, so the tail of the batch is
//! never generated. Easy queries converge in one wave; only contested
//! queries spend the full N — the adaptive-allocation idea of Snell et
//! al. (arXiv 2408.03314) expressed as a decoding method.
//!
//! Cost structure: between one and ⌈N/wave⌉ batched generate calls, so
//! latency sits between majority voting (1 call) and beam search (one
//! call per round), while expected token cost drops on easy queries.

use crate::engine::{GenJob, GenKind};
use crate::error::Result;
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    accumulate_candidates, DecodingMethod, Outcome, RunCtx, StrategyParams,
};
use std::collections::HashMap;

pub struct EarlyStopMajority;

impl EarlyStopMajority {
    /// Wave size: a quarter of N (min 2) — up to four vote checkpoints.
    fn wave(n: usize) -> usize {
        (n / 4).max(2).min(n)
    }
}

impl DecodingMethod for EarlyStopMajority {
    fn name(&self) -> &'static str {
        "mv_early"
    }

    fn describe(&self) -> &'static str {
        "majority voting in waves, stops once the vote margin is decided"
    }

    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        let t0 = ctx.now_ms();
        let n = params.n.max(1);
        let prompt = format!("{}S:", ctx.query);
        let prompt_ids = ctx.tokenizer.encode(&prompt)?;

        let mut candidates: Vec<Candidate> = Vec::with_capacity(n);
        let mut tokens_total = 0usize;
        let mut engine_calls = 0usize;
        let mut budget_exhausted = false;
        let mut preempted = false;
        let mut stopped_early = false;
        let mut issued = 0usize;

        while issued < n {
            if ctx.budget.exhausted(tokens_total, ctx.now_ms() - t0) {
                budget_exhausted = true;
                break;
            }
            let batch = Self::wave(n).min(n - issued);
            let jobs: Vec<GenJob> = (0..batch)
                .map(|_| ctx.gen_job(prompt_ids.clone(), GenKind::Full, tokens_total))
                .collect();
            let results = ctx.generate_budgeted(jobs, t0)?;
            engine_calls += 1;
            issued += batch;
            let acc = accumulate_candidates(ctx, &results, &mut tokens_total, &mut candidates)?;
            if acc.preempted {
                preempted = true;
            }
            if acc.budget_hit() {
                budget_exhausted = true;
                break;
            }
            // Decided? Even if every unissued candidate voted for the
            // runner-up, the leader would still win.
            let mut counts: HashMap<String, usize> = HashMap::new();
            for c in &candidates {
                if let Some(a) = eval::extract_answer(&c.text) {
                    *counts.entry(a).or_default() += 1;
                }
            }
            let mut tallies: Vec<usize> = counts.values().copied().collect();
            tallies.sort_unstable_by(|a, b| b.cmp(a));
            let lead = tallies.first().copied().unwrap_or(0);
            let second = tallies.get(1).copied().unwrap_or(0);
            let remaining = n - issued;
            if remaining > 0 && lead > second + remaining {
                stopped_early = true;
                break;
            }
        }

        let chosen_text = eval::majority_vote(&candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        Ok(Outcome {
            answer: eval::extract_answer(&chosen_text),
            chosen: chosen_text,
            tokens: tokens_total,
            latency_ms: ctx.now_ms() - t0,
            engine_calls,
            rounds: engine_calls,
            budget_exhausted,
            preempted,
            stopped_early,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wave_sizing() {
        assert_eq!(EarlyStopMajority::wave(1), 1);
        assert_eq!(EarlyStopMajority::wave(2), 2);
        assert_eq!(EarlyStopMajority::wave(4), 2);
        assert_eq!(EarlyStopMajority::wave(8), 2);
        assert_eq!(EarlyStopMajority::wave(16), 4);
        assert_eq!(EarlyStopMajority::wave(32), 8);
    }
}
