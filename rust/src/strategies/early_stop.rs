//! Early-stopping self-consistency (`mv_early`).
//!
//! Majority voting that issues candidates in *waves* and stops as soon as
//! the vote is decided: when the leading answer's margin over the
//! runner-up exceeds the number of candidates not yet issued, no
//! remaining outcome can flip the result, so the tail of the batch is
//! never generated. Easy queries converge in one wave; only contested
//! queries spend the full N — the adaptive-allocation idea of Snell et
//! al. (arXiv 2408.03314) expressed as a decoding method.
//!
//! Cost structure: between one and ⌈N/wave⌉ batched generate calls, so
//! latency sits between majority voting (1 call) and beam search (one
//! call per round), while expected token cost drops on easy queries.
//!
//! The wave size is a searchable hyperparameter: it rides in
//! [`StrategyParams::width`] (`mv_early@16w4` = N=16, waves of 4), so
//! the router explores it exactly like beam's W and it feeds the probe's
//! existing `W/4` feature. `width <= 1` (the plain `mv_early@16` id)
//! selects the auto default, `max(2, N/4)` — up to four vote
//! checkpoints.
//!
//! Execution is a per-wave step machine: each wave is one
//! [`StepYield::Generate`], and the budget is re-read from the step
//! context before every wave — so a mid-flight reallocation grant
//! (extra deadline or token budget from a request that finished early)
//! widens what the remaining waves can spend.

use crate::engine::GenKind;
use crate::error::{Error, Result};
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    accumulate_candidates, DecodingMethod, Outcome, RunCtx, StepInput, StepYield, StrategyParams,
    StrategyState,
};
use std::collections::HashMap;

pub struct EarlyStopMajority;

impl EarlyStopMajority {
    /// Auto wave size: a quarter of N (min 2) — up to four vote
    /// checkpoints.
    fn auto_wave(n: usize) -> usize {
        (n / 4).max(2).min(n)
    }

    /// Effective wave size for `params`: explicit `width` when ≥ 2,
    /// otherwise the auto default; always clamped to N.
    fn wave(params: &StrategyParams) -> usize {
        let n = params.n.max(1);
        if params.width > 1 {
            params.width.min(n)
        } else {
            Self::auto_wave(n)
        }
    }
}

/// Where the wave loop is between steps.
enum Phase {
    /// Ready to issue the next wave (loop head).
    NextWave,
    /// Waiting on the current wave's generate call.
    Generating,
    /// Finished.
    Done,
}

/// Per-wave step machine for `mv_early`.
struct MvEarlyState {
    n: usize,
    wave: usize,
    prompt_ids: Vec<u32>,
    t0: f64,
    phase: Phase,
    candidates: Vec<Candidate>,
    tokens_total: usize,
    engine_calls: usize,
    issued: usize,
    /// Jobs in the wave currently in flight (counted into `issued` when
    /// the results arrive, matching the blocking loop's accounting).
    pending_batch: usize,
    budget_exhausted: bool,
    preempted: bool,
    stopped_early: bool,
}

impl MvEarlyState {
    /// Loop head: issue the next wave, or finish if N is reached / the
    /// budget is spent.
    fn next_wave(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        if self.issued < self.n {
            if ctx.budget.exhausted(self.tokens_total, ctx.now_ms() - self.t0) {
                self.budget_exhausted = true;
                return self.finish(ctx);
            }
            let batch = self.wave.min(self.n - self.issued);
            let jobs = (0..batch)
                .map(|_| ctx.gen_job(self.prompt_ids.clone(), GenKind::Full, self.tokens_total))
                .collect();
            self.pending_batch = batch;
            self.phase = Phase::Generating;
            return Ok(StepYield::Generate {
                jobs,
                deadline_ms: ctx.budget.deadline_at(self.t0),
            });
        }
        self.finish(ctx)
    }

    fn finish(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        self.phase = Phase::Done;
        let chosen_text = eval::majority_vote(&self.candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        Ok(StepYield::Done(Outcome {
            answer: eval::extract_answer(&chosen_text),
            chosen: chosen_text,
            tokens: self.tokens_total,
            latency_ms: ctx.now_ms() - self.t0,
            engine_calls: self.engine_calls,
            rounds: self.engine_calls,
            budget_exhausted: self.budget_exhausted,
            preempted: self.preempted,
            stopped_early: self.stopped_early,
        }))
    }
}

impl StrategyState for MvEarlyState {
    fn step(&mut self, ctx: &RunCtx<'_>, input: StepInput) -> Result<StepYield> {
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match (phase, input) {
            (Phase::NextWave, StepInput::Start) => self.next_wave(ctx),
            (Phase::Generating, StepInput::Generated(results)) => {
                self.engine_calls += 1;
                self.issued += self.pending_batch;
                self.pending_batch = 0;
                let acc = accumulate_candidates(
                    ctx,
                    &results,
                    &mut self.tokens_total,
                    &mut self.candidates,
                )?;
                if acc.preempted {
                    self.preempted = true;
                }
                if acc.budget_hit() {
                    self.budget_exhausted = true;
                    return self.finish(ctx);
                }
                // Decided? Even if every unissued candidate voted for
                // the runner-up, the leader would still win.
                let mut counts: HashMap<String, usize> = HashMap::new();
                for c in &self.candidates {
                    if let Some(a) = eval::extract_answer(&c.text) {
                        *counts.entry(a).or_default() += 1;
                    }
                }
                let mut tallies: Vec<usize> = counts.values().copied().collect();
                tallies.sort_unstable_by(|a, b| b.cmp(a));
                let lead = tallies.first().copied().unwrap_or(0);
                let second = tallies.get(1).copied().unwrap_or(0);
                let remaining = self.n - self.issued;
                if remaining > 0 && lead > second + remaining {
                    self.stopped_early = true;
                    return self.finish(ctx);
                }
                self.next_wave(ctx)
            }
            _ => Err(Error::internal("mv_early stepped with mismatched input")),
        }
    }
}

impl DecodingMethod for EarlyStopMajority {
    fn name(&self) -> &'static str {
        "mv_early"
    }

    fn describe(&self) -> &'static str {
        "majority voting in waves (searchable wave size), stops once the vote margin is decided"
    }

    /// `16` (auto wave) or `16w4` (explicit wave size 4).
    fn format_params(&self, p: &StrategyParams) -> String {
        if p.width > 1 {
            format!("{}w{}", p.n, p.width)
        } else {
            p.n.to_string()
        }
    }

    fn parse_params(&self, s: &str) -> Option<StrategyParams> {
        if let Some((n, w)) = s.split_once('w') {
            Some(StrategyParams::waves(n.parse().ok()?, w.parse().ok()?))
        } else {
            Some(StrategyParams::parallel(s.parse().ok()?))
        }
    }

    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        let n = params.n.max(1);
        let prompt = format!("{}S:", ctx.query);
        Ok(Box::new(MvEarlyState {
            n,
            wave: Self::wave(params),
            prompt_ids: ctx.tokenizer.encode(&prompt)?,
            t0: ctx.now_ms(),
            phase: Phase::NextWave,
            candidates: Vec::with_capacity(n),
            tokens_total: 0,
            engine_calls: 0,
            issued: 0,
            pending_batch: 0,
            budget_exhausted: false,
            preempted: false,
            stopped_early: false,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_wave_sizing() {
        for (n, expect) in [(1, 1), (2, 2), (4, 2), (8, 2), (16, 4), (32, 8)] {
            assert_eq!(EarlyStopMajority::wave(&StrategyParams::parallel(n)), expect);
        }
    }

    #[test]
    fn explicit_wave_overrides_auto() {
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 8)), 8);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 2)), 2);
        // clamped to N; <=1 falls back to auto
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(4, 9)), 4);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 1)), 4);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 0)), 4);
    }

    #[test]
    fn wave_ids_roundtrip() {
        let m = EarlyStopMajority;
        let auto = StrategyParams::parallel(16);
        assert_eq!(m.format_params(&auto), "16");
        assert_eq!(m.parse_params("16"), Some(auto));
        let waved = StrategyParams::waves(16, 4);
        assert_eq!(m.format_params(&waved), "16w4");
        assert_eq!(m.parse_params("16w4"), Some(waved));
        // wave 1 normalizes to the auto id
        assert_eq!(m.format_params(&StrategyParams::waves(8, 1)), "8");
        assert_eq!(m.parse_params("8w"), None);
        assert_eq!(m.parse_params("w4"), None);
    }
}
