//! Early-stopping self-consistency (`mv_early`).
//!
//! Majority voting that issues candidates in *waves* and stops as soon as
//! the vote is decided: when the leading answer's margin over the
//! runner-up exceeds the number of candidates not yet issued, no
//! remaining outcome can flip the result, so the tail of the batch is
//! never generated. Easy queries converge in one wave; only contested
//! queries spend the full N — the adaptive-allocation idea of Snell et
//! al. (arXiv 2408.03314) expressed as a decoding method.
//!
//! Cost structure: between one and ⌈N/wave⌉ batched generate calls, so
//! latency sits between majority voting (1 call) and beam search (one
//! call per round), while expected token cost drops on easy queries.
//!
//! The wave size is a searchable hyperparameter: it rides in
//! [`StrategyParams::width`] (`mv_early@16w4` = N=16, waves of 4), so
//! the router explores it exactly like beam's W and it feeds the probe's
//! existing `W/4` feature. `width <= 1` (the plain `mv_early@16` id)
//! selects the auto default, `max(2, N/4)` — up to four vote
//! checkpoints.
//!
//! Execution is a per-wave step machine: each wave is one
//! [`StepYield::GenerateEach`] fan-out, so per-row results stream back
//! as they finish. When the early rows of a wave already decide the
//! vote, the machine sets the wave's shared stop flag
//! ([`crate::engine::GenJob::with_stop`]) and the continuous engine
//! retires the still-decoding rows at the next step boundary — decode
//! steps the round-based engine would have spent finishing a wave whose
//! outcome was already known (`decode_steps_saved_live` in the engine
//! metrics). The budget is re-read from the step context before every
//! wave — so a mid-flight reallocation grant (extra deadline or token
//! budget from a request that finished early) widens what the remaining
//! waves can spend.

use crate::engine::{GenKind, GenResult};
use crate::error::{Error, Result};
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    accumulate_candidates, DecodingMethod, Outcome, RunCtx, StepInput, StepYield, StrategyParams,
    StrategyState,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub struct EarlyStopMajority;

impl EarlyStopMajority {
    /// Auto wave size: a quarter of N (min 2) — up to four vote
    /// checkpoints.
    fn auto_wave(n: usize) -> usize {
        (n / 4).max(2).min(n)
    }

    /// Effective wave size for `params`: explicit `width` when ≥ 2,
    /// otherwise the auto default; always clamped to N.
    fn wave(params: &StrategyParams) -> usize {
        let n = params.n.max(1);
        if params.width > 1 {
            params.width.min(n)
        } else {
            Self::auto_wave(n)
        }
    }
}

/// Where the wave loop is between steps.
enum Phase {
    /// Ready to issue the next wave (loop head).
    NextWave,
    /// Waiting on the current wave's generate call.
    Generating,
    /// Finished.
    Done,
}

/// Per-wave step machine for `mv_early`.
struct MvEarlyState {
    n: usize,
    wave: usize,
    prompt_ids: Vec<u32>,
    t0: f64,
    phase: Phase,
    candidates: Vec<Candidate>,
    tokens_total: usize,
    engine_calls: usize,
    issued: usize,
    /// Jobs in the wave currently in flight (counted into `issued` when
    /// the results arrive, matching the blocking loop's accounting).
    pending_batch: usize,
    /// Shared stop flag attached to every job of the in-flight wave:
    /// setting it makes the continuous engine retire the wave's
    /// still-decoding rows at the next step boundary (recorded in
    /// `decode_steps_saved_live`).
    wave_stop: Option<Arc<AtomicBool>>,
    /// Answers heard from the in-flight wave so far (per-row results
    /// stream in via [`StrategyState::on_row_result`]).
    wave_counts: HashMap<String, usize>,
    /// Rows of the in-flight wave heard so far.
    wave_seen: usize,
    /// The vote crossed the decided margin mid-wave and the stop flag
    /// was set; the wave's assembled results finish the request as
    /// `stopped_early`, not as a budget hit.
    wave_decided: bool,
    budget_exhausted: bool,
    preempted: bool,
    stopped_early: bool,
}

/// `lead > second + unknown`: even if every unheard candidate voted for
/// the runner-up, the leader would still win.
fn decided(tallies: &mut Vec<usize>, unknown: usize) -> bool {
    tallies.sort_unstable_by(|a, b| b.cmp(a));
    let lead = tallies.first().copied().unwrap_or(0);
    let second = tallies.get(1).copied().unwrap_or(0);
    lead > second + unknown
}

impl MvEarlyState {
    /// Loop head: issue the next wave, or finish if N is reached / the
    /// budget is spent.
    fn next_wave(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        if self.issued < self.n {
            if ctx.budget.exhausted(self.tokens_total, ctx.now_ms() - self.t0) {
                self.budget_exhausted = true;
                return self.finish(ctx);
            }
            let batch = self.wave.min(self.n - self.issued);
            let stop = Arc::new(AtomicBool::new(false));
            let jobs = (0..batch)
                .map(|_| {
                    ctx.gen_job(self.prompt_ids.clone(), GenKind::Full, self.tokens_total)
                        .with_stop(stop.clone())
                })
                .collect();
            self.pending_batch = batch;
            self.wave_stop = Some(stop);
            self.wave_counts.clear();
            self.wave_seen = 0;
            self.wave_decided = false;
            self.phase = Phase::Generating;
            // GenerateEach (not Generate): per-row results stream back
            // through `on_row_result`, so a wave whose early rows
            // already decide the vote can stop its own tail mid-decode.
            return Ok(StepYield::GenerateEach {
                jobs,
                deadline_ms: ctx.budget.deadline_at(self.t0),
            });
        }
        self.finish(ctx)
    }

    fn finish(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        self.phase = Phase::Done;
        let chosen_text = eval::majority_vote(&self.candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        Ok(StepYield::Done(Outcome {
            answer: eval::extract_answer(&chosen_text),
            chosen: chosen_text,
            tokens: self.tokens_total,
            latency_ms: ctx.now_ms() - self.t0,
            engine_calls: self.engine_calls,
            rounds: self.engine_calls,
            budget_exhausted: self.budget_exhausted,
            preempted: self.preempted,
            stopped_early: self.stopped_early,
        }))
    }
}

impl StrategyState for MvEarlyState {
    fn step(&mut self, ctx: &RunCtx<'_>, input: StepInput) -> Result<StepYield> {
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match (phase, input) {
            (Phase::NextWave, StepInput::Start) => self.next_wave(ctx),
            (Phase::Generating, StepInput::Generated(results)) => {
                self.engine_calls += 1;
                self.issued += self.pending_batch;
                self.pending_batch = 0;
                self.wave_stop = None;
                let acc = accumulate_candidates(
                    ctx,
                    &results,
                    &mut self.tokens_total,
                    &mut self.candidates,
                )?;
                if self.wave_decided {
                    // We halted the rest of the wave ourselves once the
                    // vote crossed the margin: the engine tags those
                    // rows `preempted`, but that is a deliberate early
                    // stop, not a budget hit (a genuine token-cap
                    // truncation in the same batch still reports).
                    self.budget_exhausted = acc.truncated;
                    self.stopped_early = true;
                    return self.finish(ctx);
                }
                if acc.preempted {
                    self.preempted = true;
                }
                if acc.budget_hit() {
                    self.budget_exhausted = true;
                    return self.finish(ctx);
                }
                // Decided at the wave boundary? Even if every unissued
                // candidate voted for the runner-up, the leader would
                // still win.
                let mut counts: HashMap<String, usize> = HashMap::new();
                for c in &self.candidates {
                    if let Some(a) = eval::extract_answer(&c.text) {
                        *counts.entry(a).or_default() += 1;
                    }
                }
                let mut tallies: Vec<usize> = counts.values().copied().collect();
                let remaining = self.n - self.issued;
                if remaining > 0 && decided(&mut tallies, remaining) {
                    self.stopped_early = true;
                    return self.finish(ctx);
                }
                self.next_wave(ctx)
            }
            _ => Err(Error::internal("mv_early stepped with mismatched input")),
        }
    }

    /// Streamed per-row arrival for the in-flight wave: tally the row's
    /// answer and, the moment the vote can no longer flip — counting
    /// every unheard row (in-flight and unissued) for the runner-up —
    /// set the wave's stop flag so the continuous engine retires the
    /// rows still decoding instead of finishing them.
    fn on_row_result(&mut self, ctx: &RunCtx<'_>, _row: usize, result: &GenResult) {
        if !matches!(self.phase, Phase::Generating) || self.wave_decided {
            return;
        }
        self.wave_seen += 1;
        if !result.preempted {
            if let Ok(text) = ctx.tokenizer.decode(&result.tokens) {
                if let Some(a) = eval::extract_answer(&format!("S:{text}")) {
                    *self.wave_counts.entry(a).or_default() += 1;
                }
            }
        }
        let mut counts = self.wave_counts.clone();
        for c in &self.candidates {
            if let Some(a) = eval::extract_answer(&c.text) {
                *counts.entry(a).or_default() += 1;
            }
        }
        let mut tallies: Vec<usize> = counts.values().copied().collect();
        let unknown = (self.n - self.issued).saturating_sub(self.wave_seen);
        if unknown > 0 && decided(&mut tallies, unknown) {
            self.wave_decided = true;
            if let Some(stop) = &self.wave_stop {
                stop.store(true, Ordering::Relaxed);
            }
        }
    }
}

impl DecodingMethod for EarlyStopMajority {
    fn name(&self) -> &'static str {
        "mv_early"
    }

    fn describe(&self) -> &'static str {
        "majority voting in waves (searchable wave size), stops once the vote margin is decided"
    }

    /// `16` (auto wave) or `16w4` (explicit wave size 4).
    fn format_params(&self, p: &StrategyParams) -> String {
        if p.width > 1 {
            format!("{}w{}", p.n, p.width)
        } else {
            p.n.to_string()
        }
    }

    fn parse_params(&self, s: &str) -> Option<StrategyParams> {
        if let Some((n, w)) = s.split_once('w') {
            Some(StrategyParams::waves(n.parse().ok()?, w.parse().ok()?))
        } else {
            Some(StrategyParams::parallel(s.parse().ok()?))
        }
    }

    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        let n = params.n.max(1);
        let prompt = format!("{}S:", ctx.query);
        Ok(Box::new(MvEarlyState {
            n,
            wave: Self::wave(params),
            prompt_ids: ctx.tokenizer.encode(&prompt)?,
            t0: ctx.now_ms(),
            phase: Phase::NextWave,
            candidates: Vec::with_capacity(n),
            tokens_total: 0,
            engine_calls: 0,
            issued: 0,
            pending_batch: 0,
            wave_stop: None,
            wave_counts: HashMap::new(),
            wave_seen: 0,
            wave_decided: false,
            budget_exhausted: false,
            preempted: false,
            stopped_early: false,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_wave_sizing() {
        for (n, expect) in [(1, 1), (2, 2), (4, 2), (8, 2), (16, 4), (32, 8)] {
            assert_eq!(EarlyStopMajority::wave(&StrategyParams::parallel(n)), expect);
        }
    }

    #[test]
    fn explicit_wave_overrides_auto() {
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 8)), 8);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 2)), 2);
        // clamped to N; <=1 falls back to auto
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(4, 9)), 4);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 1)), 4);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 0)), 4);
    }

    use crate::config::EngineConfig;
    use crate::engine::{
        Backend, BatchPlan, DecodeSession, EmbedKind, Engine, EngineShapes, ProbeTrainReport,
        StepRows, StepTok,
    };
    use crate::strategies::executor::Executor;
    use crate::strategies::method::Budget;
    use crate::strategies::space::Strategy;
    use crate::strategies::stepper::{Stepper, Ticket};
    use crate::tokenizer::Tokenizer;
    use crate::util::clock;
    use crate::util::json::Value;

    /// Scripted steppable backend: slot `s` always answers "3" but with
    /// a CoT whose length grows steeply with the slot index, so a
    /// wave's rows finish many decode steps apart. Each decode step
    /// also sleeps briefly in *real* time, so reply handling on the
    /// stepper thread (hear the early rows, flip the wave's stop flag)
    /// comfortably outruns the rows still decoding — the stand-in for
    /// a device whose step latency dwarfs channel latency.
    struct StaggerBackend {
        shapes: EngineShapes,
        naturals: Vec<Vec<u32>>,
    }

    struct StaggerRow {
        natural: Vec<u32>,
        cursor: usize,
    }

    struct StaggerSession {
        rows: Vec<Option<StaggerRow>>,
    }

    impl StaggerBackend {
        fn new() -> StaggerBackend {
            let tok = Tokenizer::new();
            let naturals = (0..8)
                .map(|slot| {
                    let text = format!("{}A:3\n", "1+2=3;".repeat(1 + slot * 4));
                    tok.encode(&text).unwrap()
                })
                .collect();
            StaggerBackend {
                shapes: EngineShapes::sim_default(&EngineConfig::default()),
                naturals,
            }
        }

        fn natural(&self, slot: usize) -> Vec<u32> {
            self.naturals[slot % self.naturals.len()].clone()
        }
    }

    impl Backend for StaggerBackend {
        fn name(&self) -> &'static str {
            "stagger"
        }

        fn shapes(&self) -> &EngineShapes {
            &self.shapes
        }

        fn describe(&self) -> Value {
            Value::obj().with("backend", "stagger")
        }

        fn generate(&mut self, _plan: &BatchPlan, prompts: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
            Ok((0..prompts.len()).map(|slot| self.natural(slot)).collect())
        }

        fn prm_score(&mut self, _bucket: usize, _prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
            Err(Error::Engine("stagger backend has no PRM".into()))
        }

        fn embed(
            &mut self,
            _kind: EmbedKind,
            _bucket: usize,
            _queries: &[Vec<u32>],
        ) -> Result<Vec<Vec<f32>>> {
            Err(Error::Engine("stagger backend has no embedder".into()))
        }

        fn probe_fwd(&mut self, _feats: &[Vec<f32>]) -> Result<Vec<f32>> {
            Err(Error::Engine("stagger backend has no probe".into()))
        }

        fn probe_train(
            &mut self,
            _train_feats: &[Vec<f32>],
            _train_labels: &[f32],
            _val_feats: &[Vec<f32>],
            _val_labels: &[f32],
            _epochs: usize,
            _patience: usize,
        ) -> Result<ProbeTrainReport> {
            Err(Error::Engine("stagger backend has no probe".into()))
        }

        fn probe_load(&mut self, _params: Vec<f32>) -> Result<()> {
            Err(Error::Engine("stagger backend has no probe".into()))
        }

        fn stepping(&self) -> bool {
            true
        }

        fn prefill(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<DecodeSession> {
            let mut rows: Vec<Option<StaggerRow>> = (0..plan.bucket).map(|_| None).collect();
            for slot in 0..prompts.len() {
                rows[slot] = Some(StaggerRow {
                    natural: self.natural(slot),
                    cursor: 0,
                });
            }
            Ok(DecodeSession::new(plan, Box::new(StaggerSession { rows })))
        }

        fn decode_step(&mut self, session: &mut DecodeSession) -> Result<StepRows> {
            // the real-time throttle: one step is long against reply
            // handling on the stepper thread
            std::thread::sleep(std::time::Duration::from_micros(300));
            let bucket = session.bucket;
            let s = session.state_mut::<StaggerSession>()?;
            let mut out: StepRows = (0..bucket).map(|_| None).collect();
            for (slot, row) in s.rows.iter_mut().enumerate() {
                if let Some(r) = row {
                    if r.cursor < r.natural.len() {
                        let token = r.natural[r.cursor];
                        r.cursor += 1;
                        out[slot] = Some(StepTok {
                            token,
                            last: r.cursor == r.natural.len(),
                        });
                    }
                }
            }
            Ok(out)
        }

        fn admit_row(
            &mut self,
            session: &mut DecodeSession,
            slot: usize,
            _prompt: &[u32],
        ) -> Result<bool> {
            let natural = self.natural(slot);
            let s = session.state_mut::<StaggerSession>()?;
            s.rows[slot] = Some(StaggerRow { natural, cursor: 0 });
            Ok(true)
        }

        fn retire_row(&mut self, session: &mut DecodeSession, slot: usize) -> usize {
            let Ok(s) = session.state_mut::<StaggerSession>() else {
                return 0;
            };
            match s.rows.get_mut(slot).and_then(|r| r.take()) {
                Some(r) => r.natural.len().saturating_sub(r.cursor),
                None => 0,
            }
        }
    }

    /// ISSUE 9 satellite: a decided vote mid-wave sets the wave's stop
    /// flag, and the continuous engine retires the still-decoding rows
    /// — live decode steps genuinely saved, not just relabeled.
    ///
    /// With N=8, wave=4 and every row answering "3": the wave-1
    /// boundary is undecided (lead 4 = remaining 4), so wave 2 is
    /// issued. Its shortest row lands first → lead 5 > 3 unheard →
    /// decided mid-wave while the three longer rows are still many
    /// (throttled) steps from their ends.
    #[test]
    fn decided_wave_stops_live_rows_and_saves_decode_steps() {
        let clock = clock::sim_clock();
        let engine = Engine::start_member_with_factory(
            clock.clone(),
            0,
            Box::new(|| Ok(Box::new(StaggerBackend::new()) as Box<dyn Backend>)),
            "stagger backend",
            None,
            true,
        )
        .unwrap();
        let ex = Executor::new(engine.handle(), clock, 0.0);
        let mut stepper = Stepper::new(ex);
        stepper
            .admit(Ticket {
                query: "Q:1+2=?\n".into(),
                strategy: Strategy::mv_early_wave(8, 4),
                budget: Budget::unlimited(),
                tag: 0,
            })
            .unwrap();
        stepper.run_to_completion().unwrap();
        let done = stepper.drain_completed();
        assert_eq!(done.len(), 1);
        let o = &done[0].outcome;
        assert_eq!(o.answer.as_deref(), Some("3"));
        assert!(o.stopped_early, "decided mid-wave must report stopped_early");
        assert!(
            !o.budget_exhausted,
            "a deliberate stop is not a budget hit"
        );
        assert!(
            engine.metrics.decode_steps_saved_live.get() > 0,
            "stop flag must retire rows before their natural ends"
        );
        assert!(engine.metrics.retired_rows.get() > 0);
    }

    #[test]
    fn wave_ids_roundtrip() {
        let m = EarlyStopMajority;
        let auto = StrategyParams::parallel(16);
        assert_eq!(m.format_params(&auto), "16");
        assert_eq!(m.parse_params("16"), Some(auto));
        let waved = StrategyParams::waves(16, 4);
        assert_eq!(m.format_params(&waved), "16w4");
        assert_eq!(m.parse_params("16w4"), Some(waved));
        // wave 1 normalizes to the auto id
        assert_eq!(m.format_params(&StrategyParams::waves(8, 1)), "8");
        assert_eq!(m.parse_params("8w"), None);
        assert_eq!(m.parse_params("w4"), None);
    }
}
