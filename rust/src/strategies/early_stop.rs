//! Early-stopping self-consistency (`mv_early`).
//!
//! Majority voting that issues candidates in *waves* and stops as soon as
//! the vote is decided: when the leading answer's margin over the
//! runner-up exceeds the number of candidates not yet issued, no
//! remaining outcome can flip the result, so the tail of the batch is
//! never generated. Easy queries converge in one wave; only contested
//! queries spend the full N — the adaptive-allocation idea of Snell et
//! al. (arXiv 2408.03314) expressed as a decoding method.
//!
//! Cost structure: between one and ⌈N/wave⌉ batched generate calls, so
//! latency sits between majority voting (1 call) and beam search (one
//! call per round), while expected token cost drops on easy queries.
//!
//! The wave size is a searchable hyperparameter: it rides in
//! [`StrategyParams::width`] (`mv_early@16w4` = N=16, waves of 4), so
//! the router explores it exactly like beam's W and it feeds the probe's
//! existing `W/4` feature. `width <= 1` (the plain `mv_early@16` id)
//! selects the auto default, `max(2, N/4)` — up to four vote
//! checkpoints.

use crate::engine::{GenJob, GenKind};
use crate::error::Result;
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    accumulate_candidates, DecodingMethod, Outcome, RunCtx, StrategyParams,
};
use std::collections::HashMap;

pub struct EarlyStopMajority;

impl EarlyStopMajority {
    /// Auto wave size: a quarter of N (min 2) — up to four vote
    /// checkpoints.
    fn auto_wave(n: usize) -> usize {
        (n / 4).max(2).min(n)
    }

    /// Effective wave size for `params`: explicit `width` when ≥ 2,
    /// otherwise the auto default; always clamped to N.
    fn wave(params: &StrategyParams) -> usize {
        let n = params.n.max(1);
        if params.width > 1 {
            params.width.min(n)
        } else {
            Self::auto_wave(n)
        }
    }
}

impl DecodingMethod for EarlyStopMajority {
    fn name(&self) -> &'static str {
        "mv_early"
    }

    fn describe(&self) -> &'static str {
        "majority voting in waves (searchable wave size), stops once the vote margin is decided"
    }

    /// `16` (auto wave) or `16w4` (explicit wave size 4).
    fn format_params(&self, p: &StrategyParams) -> String {
        if p.width > 1 {
            format!("{}w{}", p.n, p.width)
        } else {
            p.n.to_string()
        }
    }

    fn parse_params(&self, s: &str) -> Option<StrategyParams> {
        if let Some((n, w)) = s.split_once('w') {
            Some(StrategyParams::waves(n.parse().ok()?, w.parse().ok()?))
        } else {
            Some(StrategyParams::parallel(s.parse().ok()?))
        }
    }

    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        let t0 = ctx.now_ms();
        let n = params.n.max(1);
        let wave = Self::wave(params);
        let prompt = format!("{}S:", ctx.query);
        let prompt_ids = ctx.tokenizer.encode(&prompt)?;

        let mut candidates: Vec<Candidate> = Vec::with_capacity(n);
        let mut tokens_total = 0usize;
        let mut engine_calls = 0usize;
        let mut budget_exhausted = false;
        let mut preempted = false;
        let mut stopped_early = false;
        let mut issued = 0usize;

        while issued < n {
            if ctx.budget.exhausted(tokens_total, ctx.now_ms() - t0) {
                budget_exhausted = true;
                break;
            }
            let batch = wave.min(n - issued);
            let jobs: Vec<GenJob> = (0..batch)
                .map(|_| ctx.gen_job(prompt_ids.clone(), GenKind::Full, tokens_total))
                .collect();
            let results = ctx.generate_budgeted(jobs, t0)?;
            engine_calls += 1;
            issued += batch;
            let acc = accumulate_candidates(ctx, &results, &mut tokens_total, &mut candidates)?;
            if acc.preempted {
                preempted = true;
            }
            if acc.budget_hit() {
                budget_exhausted = true;
                break;
            }
            // Decided? Even if every unissued candidate voted for the
            // runner-up, the leader would still win.
            let mut counts: HashMap<String, usize> = HashMap::new();
            for c in &candidates {
                if let Some(a) = eval::extract_answer(&c.text) {
                    *counts.entry(a).or_default() += 1;
                }
            }
            let mut tallies: Vec<usize> = counts.values().copied().collect();
            tallies.sort_unstable_by(|a, b| b.cmp(a));
            let lead = tallies.first().copied().unwrap_or(0);
            let second = tallies.get(1).copied().unwrap_or(0);
            let remaining = n - issued;
            if remaining > 0 && lead > second + remaining {
                stopped_early = true;
                break;
            }
        }

        let chosen_text = eval::majority_vote(&candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        Ok(Outcome {
            answer: eval::extract_answer(&chosen_text),
            chosen: chosen_text,
            tokens: tokens_total,
            latency_ms: ctx.now_ms() - t0,
            engine_calls,
            rounds: engine_calls,
            budget_exhausted,
            preempted,
            stopped_early,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_wave_sizing() {
        for (n, expect) in [(1, 1), (2, 2), (4, 2), (8, 2), (16, 4), (32, 8)] {
            assert_eq!(EarlyStopMajority::wave(&StrategyParams::parallel(n)), expect);
        }
    }

    #[test]
    fn explicit_wave_overrides_auto() {
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 8)), 8);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 2)), 2);
        // clamped to N; <=1 falls back to auto
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(4, 9)), 4);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 1)), 4);
        assert_eq!(EarlyStopMajority::wave(&StrategyParams::waves(16, 0)), 4);
    }

    #[test]
    fn wave_ids_roundtrip() {
        let m = EarlyStopMajority;
        let auto = StrategyParams::parallel(16);
        assert_eq!(m.format_params(&auto), "16");
        assert_eq!(m.parse_params("16"), Some(auto));
        let waved = StrategyParams::waves(16, 4);
        assert_eq!(m.format_params(&waved), "16w4");
        assert_eq!(m.parse_params("16w4"), Some(waved));
        // wave 1 normalizes to the auto id
        assert_eq!(m.format_params(&StrategyParams::waves(8, 1)), "8");
        assert_eq!(m.parse_params("8w"), None);
        assert_eq!(m.parse_params("w4"), None);
    }
}
