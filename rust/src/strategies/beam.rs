//! Step-synchronized beam search with PRM scoring (paper §2.1).
//!
//! `θ_Beam = (N, W, C)`: N active beams, W continuations per beam per
//! round, chunks of up to C tokens per round (a chunk normally ends at
//! the `;` CoT step boundary — the `lm_chunk` artifacts stop there). After
//! each expansion round the PRM scores every live prefix and the top-N
//! survive. After at most D rounds the N complete solutions vote on the
//! final answer.
//!
//! Cost structure (the paper's motivation): every round is a *sequential*
//! engine call — generation cannot overlap across rounds — so latency
//! grows with solution depth even though each call is batched. Token cost
//! counts every generated token, including pruned beams.

use crate::engine::{GenJob, GenKind};
use crate::error::Result;
use crate::eval::{self, Candidate};
use crate::strategies::executor::{Executor, Outcome};
use crate::strategies::space::Strategy;

/// One live beam.
#[derive(Debug, Clone)]
struct Beam {
    /// Solution text so far (starts with `S:`).
    text: String,
    /// Latest PRM score of (query + text).
    score: f64,
    /// Completed (hit EOS or a cap).
    done: bool,
    /// Tokens this beam has generated (for its own account; pruned beams'
    /// tokens are accounted in the run total separately).
    tokens: usize,
}

pub struct BeamSearch<'a> {
    exec: &'a Executor,
    strategy: &'a Strategy,
}

impl<'a> BeamSearch<'a> {
    pub fn new(exec: &'a Executor, strategy: &'a Strategy) -> BeamSearch<'a> {
        BeamSearch { exec, strategy }
    }

    pub fn run(&self, query: &str) -> Result<Outcome> {
        let clock = &self.exec.clock;
        let tok = &self.exec.tokenizer;
        let t0 = clock.now_ms();
        let n = self.strategy.n.max(1);
        let w = self.strategy.width.max(1);
        let chunk_cap = self.strategy.chunk.max(1);
        // memoizing PRM client: finished beams keep their prefix across
        // rounds, so re-scoring them hits the cache instead of the engine
        let mut prm = crate::prm::PrmClient::new(&self.exec.engine, tok);

        let mut beams = vec![Beam {
            text: "S:".to_string(),
            score: 0.5,
            done: false,
            tokens: 0,
        }];
        let mut tokens_total = 0usize;
        let mut engine_calls = 0usize;

        for round in 0..self.exec.beam_max_rounds {
            let live: Vec<usize> = (0..beams.len()).filter(|&i| !beams[i].done).collect();
            if live.is_empty() {
                break;
            }
            // Expand every live beam W ways (round 0 expands the root to
            // N·W so the first PRM selection already sees N·W options).
            let per_beam = if round == 0 { n * w } else { w };
            let mut jobs = Vec::new();
            let mut parents = Vec::new();
            for &bi in &live {
                let prompt = format!("{query}{}", beams[bi].text);
                let ids = tok.encode(&prompt)?;
                if ids.len() + 2 >= self.exec.max_prefix {
                    beams[bi].done = true; // length cap — force completion
                    continue;
                }
                for _ in 0..per_beam {
                    jobs.push(GenJob {
                        tokens: ids.clone(),
                        kind: GenKind::Chunk,
                        temperature: self.exec.temperature,
                    });
                    parents.push(bi);
                }
            }
            if jobs.is_empty() {
                break;
            }
            let results = self.exec.engine.generate(jobs)?;
            engine_calls += 1;

            // Build expansion candidates.
            let mut expanded: Vec<Beam> = Vec::with_capacity(results.len());
            for (r, &pi) in results.iter().zip(&parents) {
                let mut kept = r.tokens.clone();
                if kept.len() > chunk_cap {
                    kept.truncate(chunk_cap); // chunk-size hyperparameter C
                }
                tokens_total += kept.len();
                let piece = tok.decode(&kept)?;
                let done = piece.contains('\n') || kept.is_empty();
                expanded.push(Beam {
                    text: format!("{}{}", beams[pi].text, piece),
                    score: 0.0,
                    done,
                    tokens: beams[pi].tokens + kept.len(),
                });
            }
            // Carry over already-done beams to compete in selection.
            let finished: Vec<Beam> = beams.iter().filter(|b| b.done).cloned().collect();
            let mut pool = finished;
            pool.extend(expanded);

            // PRM-score the pool. Done beams keep identical prefixes, so
            // the memoizing client only sends fresh expansions to the
            // engine (measured: ~20% fewer PRM rows per beam run).
            let texts: Vec<String> = pool.iter().map(|b| b.text.clone()).collect();
            let scores = prm.score(query, &texts)?;
            engine_calls += 1;
            for (b, s) in pool.iter_mut().zip(scores) {
                b.score = s as f64;
            }

            // Top-N by PRM score.
            pool.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
            pool.truncate(n);
            beams = pool;
        }

        // Force-finish any still-live beams (depth bound D hit).
        for b in beams.iter_mut() {
            b.done = true;
        }

        // Final answer: majority vote over the N beams (paper §2.1),
        // PRM scores as tie-break weights.
        let candidates: Vec<Candidate> = beams
            .iter()
            .map(|b| Candidate {
                text: b.text.clone(),
                score: b.score,
                tokens: b.tokens,
            })
            .collect();
        let chosen = eval::majority_vote(&candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        let latency_ms = clock.now_ms() - t0;
        Ok(Outcome {
            answer: eval::extract_answer(&chosen),
            chosen,
            tokens: tokens_total,
            latency_ms,
            engine_calls,
        })
    }
}
