//! Step-synchronized beam search with PRM scoring (paper §2.1), in two
//! flavors sharing one core:
//!
//! * [`Beam`] (`beam`) — the paper's method. `θ = (N, W, C)`: N active
//!   beams, W continuations per beam per round, chunks of up to C tokens
//!   per round (a chunk normally ends at the `;` CoT step boundary).
//!   After each round the PRM scores every live prefix and the top-N
//!   survive; after at most D rounds the beams vote on the final answer.
//!   Budgets are observed *reactively*: the round loop stops once the
//!   deadline has passed or the token cap is hit.
//! * [`LatencyAwareBeam`] (`beam_latency`) — deadline-aware variant in
//!   the spirit of latency-aware test-time scaling (Wang et al., arXiv
//!   2505.19634): before each round it predicts the round's cost from
//!   the previous round's measured duration (with 1.2× headroom) and
//!   stops *before* overshooting the deadline, reporting
//!   `stopped_early`. Without a deadline it behaves exactly like `beam`.
//!
//! Cost structure (the paper's motivation): every round is a *sequential*
//! engine call — generation cannot overlap across rounds — so latency
//! grows with solution depth even though each call is batched. Token cost
//! counts every generated token, including pruned beams.
//!
//! Execution is a per-expansion-round step machine: each round is one
//! [`StepYield::Generate`] followed (budget permitting) by one
//! [`StepYield::PrmScore`] for the fresh expansions. Because the machine
//! suspends between rounds, the serving layer can run N concurrent beam
//! requests on one thread and the engine scheduler coalesces their
//! round-k expansions into shared bucket-shaped calls — the
//! step-synchronized structure no longer costs a thread per request.
//! PRM memoization (finished beams keep their prefix across rounds) is
//! machine-local: cached prefixes are skipped from the yield, so only
//! fresh expansions reach the engine.

use crate::engine::GenKind;
use crate::error::{Error, Result};
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    DecodingMethod, Outcome, RunCtx, StepInput, StepYield, StrategyParams, StrategyState,
};
use std::collections::HashMap;

/// One live beam.
#[derive(Debug, Clone)]
struct BeamNode {
    /// Solution text so far (starts with `S:`).
    text: String,
    /// Latest PRM score of (query + text).
    score: f64,
    /// Completed (hit EOS or a cap).
    done: bool,
    /// Tokens this beam has generated (for its own account; pruned beams'
    /// tokens are accounted in the run total separately).
    tokens: usize,
}

/// Safety factor on the predicted next-round cost for the deadline-aware
/// variant: rounds grow as prefixes lengthen, so predict high.
const ROUND_COST_HEADROOM: f64 = 1.2;

/// Where the round loop is between steps.
enum Phase {
    /// Ready to open the next expansion round (loop head).
    RoundHead,
    /// Waiting on the round's batched expansion call.
    Expanding,
    /// Waiting on PRM scores for the fresh (non-memoized) pool prefixes.
    Scoring,
    /// Finished.
    Done,
}

/// Per-round step machine shared by both beam flavors.
struct BeamState {
    deadline_aware: bool,
    n: usize,
    w: usize,
    chunk_cap: usize,
    t0: f64,
    phase: Phase,
    round: usize,
    round_start: f64,
    beams: Vec<BeamNode>,
    /// Parent beam index of each in-flight expansion job.
    parents: Vec<usize>,
    /// Selection pool being assembled for the current round (finished
    /// beams + fresh expansions), held across the scoring yield.
    pool: Vec<BeamNode>,
    /// Pool indices whose prefixes were yielded for scoring (cache
    /// misses), in yield order.
    score_idx: Vec<usize>,
    /// Memoized PRM scores keyed by the full `query + text` prefix —
    /// finished beams keep identical prefixes across rounds, so only
    /// fresh expansions reach the engine (measured on the blocking
    /// path: ~20% fewer PRM rows per beam run).
    cache: HashMap<String, f32>,
    tokens_total: usize,
    engine_calls: usize,
    rounds_done: usize,
    budget_exhausted: bool,
    preempted: bool,
    stopped_early: bool,
    last_round_ms: f64,
    /// Absolute deadline the in-flight expansion call was issued with.
    /// Budget-hit accounting for that call must use *this* value, not
    /// the current budget: a reallocation grant may extend the budget
    /// while the call is in flight, but the engine preempts at the
    /// deadline the call was submitted with.
    issued_deadline: Option<f64>,
}

impl BeamState {
    /// Loop head: open round `self.round` or finish (depth bound D,
    /// budget spent, predictive deadline truncation, nothing live).
    fn round_head(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        if self.round >= ctx.beam_max_rounds {
            return self.finish(ctx);
        }
        let elapsed = ctx.now_ms() - self.t0;
        if ctx.budget.exhausted(self.tokens_total, elapsed) {
            self.budget_exhausted = true;
            return self.finish(ctx);
        }
        // Predictive truncation (deadline-aware variant): if the next
        // round — estimated from the previous round's duration — would
        // overrun the deadline, stop now instead of blowing through it.
        // The deadline is re-read from the budget each round, so a
        // mid-flight reallocation grant extends how many rounds fit.
        if self.deadline_aware
            && self.round > 0
            && ROUND_COST_HEADROOM * self.last_round_ms > ctx.budget.ms_left(elapsed)
        {
            self.stopped_early = true;
            return self.finish(ctx);
        }
        self.round_start = ctx.now_ms();

        let live: Vec<usize> = (0..self.beams.len()).filter(|&i| !self.beams[i].done).collect();
        if live.is_empty() {
            return self.finish(ctx);
        }
        // Expand every live beam W ways (round 0 expands the root to
        // N·W so the first PRM selection already sees N·W options).
        let per_beam = if self.round == 0 { self.n * self.w } else { self.w };
        let mut jobs = Vec::new();
        self.parents.clear();
        for &bi in &live {
            let prompt = format!("{}{}", ctx.query, self.beams[bi].text);
            let ids = ctx.tokenizer.encode(&prompt)?;
            if ids.len() + 2 >= ctx.max_prefix {
                self.beams[bi].done = true; // length cap — force completion
                continue;
            }
            for _ in 0..per_beam {
                // budget rides into the engine: token cap left + cancel
                // flag per job, absolute deadline on the call — a round
                // that would overrun is halted mid-decode, not after.
                // The chunk hyperparameter C also bounds the engine cap:
                // decoding past C is discarded by accounting anyway.
                let job = ctx.gen_job(ids.clone(), GenKind::Chunk, self.tokens_total);
                let cap = job.max_new_tokens.map_or(self.chunk_cap, |c| c.min(self.chunk_cap));
                jobs.push(job.with_max_new_tokens(cap));
                self.parents.push(bi);
            }
        }
        if jobs.is_empty() {
            return self.finish(ctx);
        }
        self.phase = Phase::Expanding;
        self.issued_deadline = ctx.budget.deadline_at(self.t0);
        Ok(StepYield::Generate {
            jobs,
            deadline_ms: self.issued_deadline,
        })
    }

    /// The round's expansion results arrived: account tokens against the
    /// budget, assemble the selection pool, and either yield the fresh
    /// prefixes for PRM scoring or (budget spent) select unscored.
    fn after_generate(
        &mut self,
        ctx: &RunCtx<'_>,
        results: Vec<crate::engine::GenResult>,
    ) -> Result<StepYield> {
        self.engine_calls += 1;
        self.rounds_done += 1;

        // Was the round halted by the *budget* (deadline passed mid-call
        // or cancellation)? An engine row preempted only by the C-chunk
        // cap is a hyperparameter bound, not a budget event — the token
        // cap makes itself felt through `clamp_tokens` / `exhausted`
        // accounting below instead. The check runs against the deadline
        // the call was *issued* with: the engine enforced that value,
        // and a reallocation grant landing mid-call must not make its
        // preemption look spontaneous (without grants this equals
        // `ctx.budget.deadline_passed(now - t0)` exactly).
        let round_budget_hit = ctx.budget.cancelled()
            || self.issued_deadline.is_some_and(|d| ctx.now_ms() >= d);

        // Build expansion candidates (token accounting capped by budget).
        let mut expanded: Vec<BeamNode> = Vec::with_capacity(results.len());
        for (r, &pi) in results.iter().zip(&self.parents) {
            let mut kept = r.tokens.clone();
            if kept.len() > self.chunk_cap {
                kept.truncate(self.chunk_cap); // chunk-size hyperparameter C
            }
            let (kept, truncated) = ctx.budget.clamp_tokens(self.tokens_total, &kept);
            if truncated {
                self.budget_exhausted = true;
            }
            if r.preempted && (truncated || round_budget_hit) {
                // the engine evicted this row mid-round for budget
                // reasons — the budget is spent
                self.preempted = true;
                self.budget_exhausted = true;
            }
            self.tokens_total += kept.len();
            let piece = ctx.tokenizer.decode(&kept)?;
            let done = piece.contains('\n') || kept.is_empty();
            expanded.push(BeamNode {
                text: format!("{}{}", self.beams[pi].text, piece),
                score: 0.0,
                done,
                tokens: self.beams[pi].tokens + kept.len(),
            });
        }
        // Carry over already-done beams to compete in selection.
        let finished: Vec<BeamNode> = self.beams.iter().filter(|b| b.done).cloned().collect();
        self.pool = finished;
        self.pool.extend(expanded);

        // Budget spent during this round (token cap during accounting,
        // or the generate call overran the deadline)? Then no further
        // engine work — skip the PRM yield and select on whatever scores
        // the pool already has (fresh expansions stay at 0.0; the final
        // majority vote only uses scores as tie-break weights).
        if self.budget_exhausted
            || ctx.budget.exhausted(self.tokens_total, ctx.now_ms() - self.t0)
        {
            self.budget_exhausted = true;
            return self.select_and_continue(ctx);
        }

        // PRM-score the pool, memoization first: only prefixes not seen
        // in an earlier round reach the engine. `engine_calls` counts
        // the scoring pass either way, even when fully served from
        // cache (parity with the pre-refactor accounting).
        self.engine_calls += 1;
        self.score_idx.clear();
        let mut prefixes: Vec<Vec<u32>> = Vec::new();
        for (i, b) in self.pool.iter_mut().enumerate() {
            let full = format!("{}{}", ctx.query, b.text);
            if let Some(&s) = self.cache.get(&full) {
                b.score = s as f64;
            } else {
                prefixes.push(ctx.tokenizer.encode(&full)?);
                self.score_idx.push(i);
            }
        }
        if prefixes.is_empty() {
            // every pool prefix was memoized — no engine round trip
            return self.select_and_continue(ctx);
        }
        self.phase = Phase::Scoring;
        Ok(StepYield::PrmScore(prefixes))
    }

    /// Fresh scores arrived: memoize and fill them in, then select.
    fn after_score(&mut self, ctx: &RunCtx<'_>, scores: Vec<f32>) -> Result<StepYield> {
        if scores.len() != self.score_idx.len() {
            return Err(Error::internal("beam PRM score count mismatch"));
        }
        let idx = std::mem::take(&mut self.score_idx);
        for (&i, s) in idx.iter().zip(scores) {
            self.pool[i].score = s as f64;
            let full = format!("{}{}", ctx.query, self.pool[i].text);
            self.cache.insert(full, s);
        }
        self.select_and_continue(ctx)
    }

    /// Top-N selection over the assembled pool, then the next round (or
    /// finish when the budget was hit during this round).
    fn select_and_continue(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        let mut pool = std::mem::take(&mut self.pool);
        pool.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
        pool.truncate(self.n);
        self.beams = pool;

        self.last_round_ms = ctx.now_ms() - self.round_start;
        if self.budget_exhausted {
            return self.finish(ctx);
        }
        self.round += 1;
        self.round_head(ctx)
    }

    /// Force-finish any still-live beams (depth bound D or budget hit)
    /// and vote.
    fn finish(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        self.phase = Phase::Done;
        for b in self.beams.iter_mut() {
            b.done = true;
        }
        // Final answer: majority vote over the N beams (paper §2.1),
        // PRM scores as tie-break weights.
        let candidates: Vec<Candidate> = self
            .beams
            .iter()
            .map(|b| Candidate {
                text: b.text.clone(),
                score: b.score,
                tokens: b.tokens,
            })
            .collect();
        let chosen = eval::majority_vote(&candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        Ok(StepYield::Done(Outcome {
            answer: eval::extract_answer(&chosen),
            chosen,
            tokens: self.tokens_total,
            latency_ms: ctx.now_ms() - self.t0,
            engine_calls: self.engine_calls,
            rounds: self.rounds_done,
            budget_exhausted: self.budget_exhausted,
            preempted: self.preempted,
            stopped_early: self.stopped_early,
        }))
    }
}

impl StrategyState for BeamState {
    fn step(&mut self, ctx: &RunCtx<'_>, input: StepInput) -> Result<StepYield> {
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match (phase, input) {
            (Phase::RoundHead, StepInput::Start) => self.round_head(ctx),
            (Phase::Expanding, StepInput::Generated(results)) => self.after_generate(ctx, results),
            (Phase::Scoring, StepInput::Scored(scores)) => self.after_score(ctx, scores),
            _ => Err(Error::internal("beam stepped with mismatched input")),
        }
    }
}

/// Shared `start` for both flavors. `deadline_aware` switches between
/// reactive budget observance and predictive round truncation.
fn start_beam(
    ctx: &RunCtx<'_>,
    params: &StrategyParams,
    deadline_aware: bool,
) -> Result<Box<dyn StrategyState>> {
    Ok(Box::new(BeamState {
        deadline_aware,
        n: params.n.max(1),
        w: params.width.max(1),
        chunk_cap: params.chunk.max(1),
        t0: ctx.now_ms(),
        phase: Phase::RoundHead,
        round: 0,
        round_start: 0.0,
        beams: vec![BeamNode {
            text: "S:".to_string(),
            score: 0.5,
            done: false,
            tokens: 0,
        }],
        parents: Vec::new(),
        pool: Vec::new(),
        score_idx: Vec::new(),
        cache: HashMap::new(),
        tokens_total: 0,
        engine_calls: 0,
        rounds_done: 0,
        budget_exhausted: false,
        preempted: false,
        stopped_early: false,
        last_round_ms: 0.0,
        issued_deadline: None,
    }))
}

/// The paper's step-synchronized beam search (`beam`).
pub struct Beam;

impl DecodingMethod for Beam {
    fn name(&self) -> &'static str {
        "beam"
    }
    fn describe(&self) -> &'static str {
        "PRM-scored beam search: N beams x W expansions per CoT step"
    }
    fn uses_rounds(&self) -> bool {
        true
    }
    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        start_beam(ctx, params, false)
    }
}

/// Deadline-aware beam search (`beam_latency`): truncates rounds
/// predictively as the per-request deadline approaches.
pub struct LatencyAwareBeam;

impl DecodingMethod for LatencyAwareBeam {
    fn name(&self) -> &'static str {
        "beam_latency"
    }
    fn describe(&self) -> &'static str {
        "beam search that stops expanding before the deadline would be overrun"
    }
    fn uses_rounds(&self) -> bool {
        true
    }
    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        start_beam(ctx, params, true)
    }
}
