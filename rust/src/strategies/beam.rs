//! Step-synchronized beam search with PRM scoring (paper §2.1), in two
//! flavors sharing one core:
//!
//! * [`Beam`] (`beam`) — the paper's method. `θ = (N, W, C)`: N active
//!   beams, W continuations per beam per round, chunks of up to C tokens
//!   per round (a chunk normally ends at the `;` CoT step boundary).
//!   After each round the PRM scores every live prefix and the top-N
//!   survive; after at most D rounds the beams vote on the final answer.
//!   Budgets are observed *reactively*: the round loop stops once the
//!   deadline has passed or the token cap is hit.
//! * [`LatencyAwareBeam`] (`beam_latency`) — deadline-aware variant in
//!   the spirit of latency-aware test-time scaling (Wang et al., arXiv
//!   2505.19634): before each round it predicts the round's cost from
//!   the previous round's measured duration (with 1.2× headroom) and
//!   stops *before* overshooting the deadline, reporting
//!   `stopped_early`. Without a deadline it behaves exactly like `beam`.
//!
//! Cost structure (the paper's motivation): every round is a *sequential*
//! engine call — generation cannot overlap across rounds — so latency
//! grows with solution depth even though each call is batched. Token cost
//! counts every generated token, including pruned beams.

use crate::engine::GenKind;
use crate::error::Result;
use crate::eval::{self, Candidate};
use crate::strategies::method::{DecodingMethod, Outcome, RunCtx, StrategyParams};

/// One live beam.
#[derive(Debug, Clone)]
struct BeamNode {
    /// Solution text so far (starts with `S:`).
    text: String,
    /// Latest PRM score of (query + text).
    score: f64,
    /// Completed (hit EOS or a cap).
    done: bool,
    /// Tokens this beam has generated (for its own account; pruned beams'
    /// tokens are accounted in the run total separately).
    tokens: usize,
}

/// Safety factor on the predicted next-round cost for the deadline-aware
/// variant: rounds grow as prefixes lengthen, so predict high.
const ROUND_COST_HEADROOM: f64 = 1.2;

/// Shared beam core. `deadline_aware` switches between reactive budget
/// observance and predictive round truncation.
fn run_beam(ctx: &RunCtx<'_>, params: &StrategyParams, deadline_aware: bool) -> Result<Outcome> {
    let tok = ctx.tokenizer;
    let t0 = ctx.now_ms();
    let n = params.n.max(1);
    let w = params.width.max(1);
    let chunk_cap = params.chunk.max(1);
    // memoizing PRM client: finished beams keep their prefix across
    // rounds, so re-scoring them hits the cache instead of the engine
    let mut prm = crate::prm::PrmClient::new(ctx.engine, tok);

    let mut beams = vec![BeamNode {
        text: "S:".to_string(),
        score: 0.5,
        done: false,
        tokens: 0,
    }];
    let mut tokens_total = 0usize;
    let mut engine_calls = 0usize;
    let mut rounds_done = 0usize;
    let mut budget_exhausted = false;
    let mut preempted = false;
    let mut stopped_early = false;
    let mut last_round_ms = 0.0f64;

    for round in 0..ctx.beam_max_rounds {
        let elapsed = ctx.now_ms() - t0;
        if ctx.budget.exhausted(tokens_total, elapsed) {
            budget_exhausted = true;
            break;
        }
        // Predictive truncation (deadline-aware variant): if the next
        // round — estimated from the previous round's duration — would
        // overrun the deadline, stop now instead of blowing through it.
        if deadline_aware
            && round > 0
            && ROUND_COST_HEADROOM * last_round_ms > ctx.budget.ms_left(elapsed)
        {
            stopped_early = true;
            break;
        }
        let round_start = ctx.now_ms();

        let live: Vec<usize> = (0..beams.len()).filter(|&i| !beams[i].done).collect();
        if live.is_empty() {
            break;
        }
        // Expand every live beam W ways (round 0 expands the root to
        // N·W so the first PRM selection already sees N·W options).
        let per_beam = if round == 0 { n * w } else { w };
        let mut jobs = Vec::new();
        let mut parents = Vec::new();
        for &bi in &live {
            let prompt = format!("{}{}", ctx.query, beams[bi].text);
            let ids = tok.encode(&prompt)?;
            if ids.len() + 2 >= ctx.max_prefix {
                beams[bi].done = true; // length cap — force completion
                continue;
            }
            for _ in 0..per_beam {
                // budget rides into the engine: token cap left + cancel
                // flag per job, absolute deadline on the call — a round
                // that would overrun is halted mid-decode, not after.
                // The chunk hyperparameter C also bounds the engine cap:
                // decoding past C is discarded by accounting anyway.
                let job = ctx.gen_job(ids.clone(), GenKind::Chunk, tokens_total);
                let cap = job.max_new_tokens.map_or(chunk_cap, |c| c.min(chunk_cap));
                jobs.push(job.with_max_new_tokens(cap));
                parents.push(bi);
            }
        }
        if jobs.is_empty() {
            break;
        }
        let results = ctx.generate_budgeted(jobs, t0)?;
        engine_calls += 1;
        rounds_done += 1;

        // Was the round halted by the *budget* (deadline passed mid-call
        // or cancellation)? An engine row preempted only by the C-chunk
        // cap is a hyperparameter bound, not a budget event — the token
        // cap makes itself felt through `clamp_tokens` / `exhausted`
        // accounting below instead.
        let round_budget_hit =
            ctx.budget.cancelled() || ctx.budget.deadline_passed(ctx.now_ms() - t0);

        // Build expansion candidates (token accounting capped by budget).
        let mut expanded: Vec<BeamNode> = Vec::with_capacity(results.len());
        for (r, &pi) in results.iter().zip(&parents) {
            let mut kept = r.tokens.clone();
            if kept.len() > chunk_cap {
                kept.truncate(chunk_cap); // chunk-size hyperparameter C
            }
            let (kept, truncated) = ctx.budget.clamp_tokens(tokens_total, &kept);
            if truncated {
                budget_exhausted = true;
            }
            if r.preempted && (truncated || round_budget_hit) {
                // the engine evicted this row mid-round for budget
                // reasons — the budget is spent
                preempted = true;
                budget_exhausted = true;
            }
            tokens_total += kept.len();
            let piece = tok.decode(&kept)?;
            let done = piece.contains('\n') || kept.is_empty();
            expanded.push(BeamNode {
                text: format!("{}{}", beams[pi].text, piece),
                score: 0.0,
                done,
                tokens: beams[pi].tokens + kept.len(),
            });
        }
        // Carry over already-done beams to compete in selection.
        let finished: Vec<BeamNode> = beams.iter().filter(|b| b.done).cloned().collect();
        let mut pool = finished;
        pool.extend(expanded);

        // Budget spent during this round (token cap during accounting,
        // or the generate call overran the deadline)? Then no further
        // engine work — skip the PRM call and select on whatever scores
        // the pool already has (fresh expansions stay at 0.0; the final
        // majority vote only uses scores as tie-break weights).
        if budget_exhausted || ctx.budget.exhausted(tokens_total, ctx.now_ms() - t0) {
            budget_exhausted = true;
        } else {
            // PRM-score the pool. Done beams keep identical prefixes, so
            // the memoizing client only sends fresh expansions to the
            // engine (measured: ~20% fewer PRM rows per beam run).
            let texts: Vec<String> = pool.iter().map(|b| b.text.clone()).collect();
            let scores = prm.score(ctx.query, &texts)?;
            engine_calls += 1;
            for (b, s) in pool.iter_mut().zip(scores) {
                b.score = s as f64;
            }
        }

        // Top-N by PRM score.
        pool.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
        pool.truncate(n);
        beams = pool;

        last_round_ms = ctx.now_ms() - round_start;
        if budget_exhausted {
            break;
        }
    }

    // Force-finish any still-live beams (depth bound D or budget hit).
    for b in beams.iter_mut() {
        b.done = true;
    }

    // Final answer: majority vote over the N beams (paper §2.1),
    // PRM scores as tie-break weights.
    let candidates: Vec<Candidate> = beams
        .iter()
        .map(|b| Candidate {
            text: b.text.clone(),
            score: b.score,
            tokens: b.tokens,
        })
        .collect();
    let chosen = eval::majority_vote(&candidates)
        .map(|c| c.text.clone())
        .unwrap_or_default();
    let latency_ms = ctx.now_ms() - t0;
    Ok(Outcome {
        answer: eval::extract_answer(&chosen),
        chosen,
        tokens: tokens_total,
        latency_ms,
        engine_calls,
        rounds: rounds_done,
        budget_exhausted,
        preempted,
        stopped_early,
    })
}

/// The paper's step-synchronized beam search (`beam`).
pub struct Beam;

impl DecodingMethod for Beam {
    fn name(&self) -> &'static str {
        "beam"
    }
    fn describe(&self) -> &'static str {
        "PRM-scored beam search: N beams x W expansions per CoT step"
    }
    fn uses_rounds(&self) -> bool {
        true
    }
    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        run_beam(ctx, params, false)
    }
}

/// Deadline-aware beam search (`beam_latency`): truncates rounds
/// predictively as the per-request deadline approaches.
pub struct LatencyAwareBeam;

impl DecodingMethod for LatencyAwareBeam {
    fn name(&self) -> &'static str {
        "beam_latency"
    }
    fn describe(&self) -> &'static str {
        "beam search that stops expanding before the deadline would be overrun"
    }
    fn uses_rounds(&self) -> bool {
        true
    }
    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        run_beam(ctx, params, true)
    }
}
