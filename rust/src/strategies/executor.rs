//! Strategy execution: a thin dispatcher over the decoding-method
//! registry.
//!
//! One [`Executor`] per coordinator; it owns a tokenizer and talks to the
//! engine handle. Token + latency accounting — the `T_s(x)` and `L_s(x)`
//! of the paper's utility (Eq. 1) — happens inside each
//! [`crate::strategies::DecodingMethod`]: latency is the full wall/sim
//! time from submission to final answer, *including PRM scoring*, exactly
//! as in appendix A.2. The executor's only jobs are resolving the method
//! by name and assembling the [`RunCtx`] (engine, clock, tokenizer,
//! per-request [`Budget`]).

use crate::engine::EngineHandle;
use crate::error::{Error, Result};
use crate::strategies::method::{Budget, RunCtx};
use crate::strategies::registry;
use crate::strategies::space::Strategy;
use crate::tokenizer::Tokenizer;
use crate::util::clock::SharedClock;

pub use crate::strategies::method::Outcome;

/// Executes strategies; cheap to clone per worker thread.
#[derive(Clone)]
pub struct Executor {
    pub engine: EngineHandle,
    pub clock: SharedClock,
    pub tokenizer: Tokenizer,
    /// Sampling temperature for all candidate generation.
    pub temperature: f32,
    /// Depth bound D for beam-family methods (max expansion rounds).
    pub beam_max_rounds: usize,
    /// Longest prefix (tokens) a beam may reach before being forced done —
    /// the engine's largest chunk length bucket.
    pub max_prefix: usize,
}

impl Executor {
    pub fn new(engine: EngineHandle, clock: SharedClock, temperature: f32) -> Executor {
        Executor {
            engine,
            clock,
            tokenizer: Tokenizer::new(),
            temperature,
            beam_max_rounds: 10,
            max_prefix: 128,
        }
    }

    /// Run strategy `s` on `query` (full query text incl. trailing `\n`)
    /// with no per-request budget — the offline/figure collection path.
    pub fn run(&self, strategy: &Strategy, query: &str) -> Result<Outcome> {
        self.run_budgeted(strategy, query, Budget::unlimited())
    }

    /// Run under a per-request [`Budget`] — the serving path. The method
    /// must observe the budget mid-strategy and report against it via
    /// [`Outcome::budget_exhausted`] / [`Outcome::stopped_early`].
    pub fn run_budgeted(
        &self,
        strategy: &Strategy,
        query: &str,
        budget: Budget,
    ) -> Result<Outcome> {
        let method = registry::get(strategy.method).ok_or_else(|| {
            Error::Config(format!(
                "unknown decoding method '{}' (registered: {:?})",
                strategy.method,
                registry::all().iter().map(|m| m.name()).collect::<Vec<_>>()
            ))
        })?;
        let ctx = RunCtx {
            engine: &self.engine,
            clock: &self.clock,
            tokenizer: &self.tokenizer,
            query,
            temperature: self.temperature,
            beam_max_rounds: self.beam_max_rounds,
            max_prefix: self.max_prefix,
            budget,
        };
        method.run(&ctx, &strategy.params())
    }
}
