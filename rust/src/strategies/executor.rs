//! Strategy execution against the engine.
//!
//! One [`Executor`] per coordinator; it owns a tokenizer, talks to the
//! engine handle and accounts tokens + latency per strategy run — the
//! `T_s(x)` and `L_s(x)` of the paper's utility (Eq. 1). Latency is the
//! full wall/sim time from submission to final answer, *including PRM
//! scoring*, exactly as in appendix A.2.

use crate::engine::{EngineHandle, GenJob, GenKind};
use crate::error::Result;
use crate::eval::{self, Candidate};
use crate::strategies::beam::BeamSearch;
use crate::strategies::space::{Method, Strategy};
use crate::tokenizer::Tokenizer;
use crate::util::clock::SharedClock;

/// Result of running one strategy on one query.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Chosen solution text (includes the leading `S:`).
    pub chosen: String,
    /// Extracted final answer, if parseable.
    pub answer: Option<String>,
    /// Total tokens generated (all candidates / all beams incl. pruned).
    pub tokens: usize,
    /// End-to-end strategy latency in ms (generation + scoring).
    pub latency_ms: f64,
    /// Number of engine calls (diagnostic; beam ≫ parallel).
    pub engine_calls: usize,
}

impl Outcome {
    pub fn is_correct(&self, ground_truth: &str) -> bool {
        self.answer.as_deref() == Some(ground_truth)
    }
}

/// Executes strategies; cheap to clone per worker thread.
#[derive(Clone)]
pub struct Executor {
    pub engine: EngineHandle,
    pub clock: SharedClock,
    pub tokenizer: Tokenizer,
    /// Sampling temperature for all candidate generation.
    pub temperature: f32,
    /// Depth bound D for beam search (max expansion rounds).
    pub beam_max_rounds: usize,
    /// Longest prefix (tokens) a beam may reach before being forced done —
    /// the engine's largest chunk length bucket.
    pub max_prefix: usize,
}

impl Executor {
    pub fn new(engine: EngineHandle, clock: SharedClock, temperature: f32) -> Executor {
        Executor {
            engine,
            clock,
            tokenizer: Tokenizer::new(),
            temperature,
            beam_max_rounds: 10,
            max_prefix: 128,
        }
    }

    /// Run strategy `s` on `query` (full query text incl. trailing `\n`).
    pub fn run(&self, strategy: &Strategy, query: &str) -> Result<Outcome> {
        match strategy.method {
            Method::Beam => BeamSearch::new(self, strategy).run(query),
            _ => self.run_parallel(strategy, query),
        }
    }

    /// Parallel methods: one batched generate + (for BoN) one PRM call.
    fn run_parallel(&self, strategy: &Strategy, query: &str) -> Result<Outcome> {
        let t0 = self.clock.now_ms();
        let prompt = format!("{query}S:");
        let prompt_ids = self.tokenizer.encode(&prompt)?;
        let jobs: Vec<GenJob> = (0..strategy.n)
            .map(|_| GenJob {
                tokens: prompt_ids.clone(),
                kind: GenKind::Full,
                temperature: self.temperature,
            })
            .collect();
        let results = self.engine.generate(jobs)?;
        let mut engine_calls = 1;

        let mut tokens_total = 0usize;
        let mut candidates: Vec<Candidate> = Vec::with_capacity(results.len());
        for r in &results {
            tokens_total += r.tokens.len();
            let text = format!("S:{}", self.tokenizer.decode(&r.tokens)?);
            candidates.push(Candidate {
                text,
                score: 0.0,
                tokens: r.tokens.len(),
            });
        }

        // PRM scoring for best-of-N variants (appendix A.2: scoring time
        // is part of latency).
        if matches!(
            strategy.method,
            Method::BestOfNNaive | Method::BestOfNWeighted
        ) {
            let prefixes: Vec<Vec<u32>> = candidates
                .iter()
                .map(|c| self.tokenizer.encode(&format!("{query}{}", c.text)))
                .collect::<Result<_>>()?;
            let scores = self.engine.prm_score(prefixes)?;
            engine_calls += 1;
            for (c, s) in candidates.iter_mut().zip(scores) {
                c.score = s as f64;
            }
        }

        let chosen = match strategy.method {
            Method::MajorityVote => eval::majority_vote(&candidates),
            Method::BestOfNNaive => eval::best_of_n(&candidates),
            Method::BestOfNWeighted => eval::weighted_vote(&candidates),
            Method::Beam => unreachable!(),
        };
        let chosen_text = chosen.map(|c| c.text.clone()).unwrap_or_default();
        let latency_ms = self.clock.now_ms() - t0;
        Ok(Outcome {
            answer: eval::extract_answer(&chosen_text),
            chosen: chosen_text,
            tokens: tokens_total,
            latency_ms,
            engine_calls,
        })
    }
}
