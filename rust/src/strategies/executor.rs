//! Strategy execution: a thin dispatcher over the decoding-method
//! registry.
//!
//! One [`Executor`] per coordinator; it owns a tokenizer and talks to the
//! engine handle. Token + latency accounting — the `T_s(x)` and `L_s(x)`
//! of the paper's utility (Eq. 1) — happens inside each
//! [`crate::strategies::DecodingMethod`]: latency is the full wall/sim
//! time from submission to final answer, *including PRM scoring*, exactly
//! as in appendix A.2. The executor's only jobs are resolving the method
//! by name and assembling the [`RunCtx`] (engine, clock, tokenizer,
//! per-request [`Budget`]).

use crate::engine::EngineHandle;
use crate::error::{Error, Result};
use crate::strategies::method::{Budget, DecodingMethod, RunCtx};
use crate::strategies::registry;
use crate::strategies::space::Strategy;
use crate::tokenizer::Tokenizer;
use crate::util::clock::SharedClock;

pub use crate::strategies::method::Outcome;

/// Resolve a method name against the registry, with a deterministic
/// error: the registered-name list is sorted before formatting, so the
/// message does not leak registration order (which varies with which
/// tests ran [`registry::register`] first).
pub(crate) fn resolve(name: &str) -> Result<&'static dyn DecodingMethod> {
    registry::get(name).ok_or_else(|| {
        let mut names: Vec<&str> = registry::all().iter().map(|m| m.name()).collect();
        names.sort_unstable();
        Error::Config(format!(
            "unknown decoding method '{name}' (registered: {names:?})"
        ))
    })
}

/// Executes strategies; cheap to clone per worker thread.
#[derive(Clone)]
pub struct Executor {
    pub engine: EngineHandle,
    pub clock: SharedClock,
    pub tokenizer: Tokenizer,
    /// Sampling temperature for all candidate generation.
    pub temperature: f32,
    /// Depth bound D for beam-family methods (max expansion rounds).
    pub beam_max_rounds: usize,
    /// Longest prefix (tokens) a beam may reach before being forced done —
    /// the engine's largest chunk length bucket.
    pub max_prefix: usize,
}

impl Executor {
    pub fn new(engine: EngineHandle, clock: SharedClock, temperature: f32) -> Executor {
        Executor {
            engine,
            clock,
            tokenizer: Tokenizer::new(),
            temperature,
            beam_max_rounds: 10,
            max_prefix: 128,
        }
    }

    /// Run strategy `s` on `query` (full query text incl. trailing `\n`)
    /// with no per-request budget — the offline/figure collection path.
    pub fn run(&self, strategy: &Strategy, query: &str) -> Result<Outcome> {
        self.run_budgeted(strategy, query, Budget::unlimited())
    }

    /// Run under a per-request [`Budget`] — the serving path. The method
    /// must observe the budget mid-strategy and report against it via
    /// [`Outcome::budget_exhausted`] / [`Outcome::stopped_early`].
    pub fn run_budgeted(
        &self,
        strategy: &Strategy,
        query: &str,
        budget: Budget,
    ) -> Result<Outcome> {
        let method = resolve(strategy.method)?;
        let ctx = self.ctx(query, budget);
        method.run(&ctx, &strategy.params())
    }

    /// Assemble the per-request execution context — the same one the
    /// blocking path and the continuation executor
    /// ([`crate::strategies::stepper::Stepper`]) hand to step machines.
    pub(crate) fn ctx<'a>(&'a self, query: &'a str, budget: Budget) -> RunCtx<'a> {
        RunCtx {
            engine: &self.engine,
            clock: &self.clock,
            tokenizer: &self.tokenizer,
            query,
            temperature: self.temperature,
            beam_max_rounds: self.beam_max_rounds,
            max_prefix: self.max_prefix,
            budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_method_error_lists_names_sorted() {
        let err = resolve("definitely_not_registered").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown decoding method 'definitely_not_registered'"));
        // the built-in names must appear in sorted order, independent of
        // registration order
        let mut sorted: Vec<&str> = registry::all().iter().map(|m| m.name()).collect();
        sorted.sort_unstable();
        assert!(
            msg.contains(&format!("{sorted:?}")),
            "error message should list sorted names: {msg}"
        );
    }
}
