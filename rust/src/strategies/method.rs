//! The open decoding-method API.
//!
//! A decoding method is anything that turns a query into an [`Outcome`]
//! by spending engine calls: it implements [`DecodingMethod`] and is
//! looked up by stable name in [`crate::strategies::registry`]. The
//! method receives a [`RunCtx`] — engine handle, tokenizer, clock and the
//! per-request [`Budget`] — plus its hyperparameters as
//! [`StrategyParams`]. Everything downstream (probe features, cost-model
//! keys, figures, the CLI) resolves methods by name, so adding a method
//! is one `impl` + one `registry::register` call.
//!
//! Budgets are the paper's agentic serving story made concrete: the
//! router *predicts* token/latency cost, but the budget lets the serving
//! path *enforce* it mid-strategy — methods must stop issuing engine
//! work once the budget is spent, and must report what happened through
//! [`Outcome::budget_exhausted`] / [`Outcome::stopped_early`].
//!
//! Methods execute as **resumable step machines** ([`StrategyState`]):
//! [`DecodingMethod::start`] returns a machine whose [`StrategyState::step`]
//! yields engine work ([`StepYield`]) instead of blocking on it, so the
//! serving layer can suspend a request between rounds, coalesce many
//! requests' rounds into shared engine calls
//! ([`crate::strategies::stepper`]), and reallocate budget mid-flight.
//! [`DecodingMethod::run`] is the blanket drive-to-completion adapter
//! over the same machine (see `docs/strategies.md` for the contract).

use crate::engine::{EngineHandle, GenJob, GenKind, GenResult};
use crate::error::{Error, Result};
use crate::eval::Candidate;
use crate::tokenizer::Tokenizer;
use crate::util::clock::SharedClock;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Per-request execution budget, enforced *inside* strategies.
///
/// All limits are optional; `Budget::unlimited()` (the default) imposes
/// none. `deadline_ms` is relative to strategy start. The contract for
/// methods:
///
/// * never issue a new engine call once the budget is spent;
/// * never account more than `max_tokens` generated tokens;
/// * pass the budget down to the engine ([`RunCtx::gen_job`] /
///   [`RunCtx::generate_budgeted`]) so an in-flight batched call is
///   preempted mid-decode when the deadline passes, instead of merely
///   refusing the *next* call (see `docs/budgets.md`).
#[derive(Debug, Clone, Default)]
pub struct Budget {
    /// Hard cap on generated tokens accounted to this request.
    pub max_tokens: Option<usize>,
    /// Latency deadline in milliseconds from strategy start.
    pub deadline_ms: Option<f64>,
    /// Cooperative cancellation flag (set by the caller at any time).
    pub cancel: Option<Arc<AtomicBool>>,
}

impl Budget {
    /// No limits — the offline/figure collection default.
    pub fn unlimited() -> Budget {
        Budget::default()
    }

    pub fn with_max_tokens(mut self, max_tokens: usize) -> Budget {
        self.max_tokens = Some(max_tokens);
        self
    }

    pub fn with_deadline_ms(mut self, deadline_ms: f64) -> Budget {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    pub fn with_cancel(mut self, flag: Arc<AtomicBool>) -> Budget {
        self.cancel = Some(flag);
        self
    }

    pub fn is_unlimited(&self) -> bool {
        self.max_tokens.is_none() && self.deadline_ms.is_none() && self.cancel.is_none()
    }

    /// The caller flipped the cancellation flag.
    pub fn cancelled(&self) -> bool {
        if let Some(f) = &self.cancel {
            f.load(Ordering::Relaxed)
        } else {
            false
        }
    }

    /// Tokens still spendable given `used` so far (`usize::MAX` when
    /// unlimited).
    pub fn tokens_left(&self, used: usize) -> usize {
        match self.max_tokens {
            Some(cap) => cap.saturating_sub(used),
            None => usize::MAX,
        }
    }

    pub fn tokens_exhausted(&self, used: usize) -> bool {
        match self.max_tokens {
            Some(cap) => used >= cap,
            None => false,
        }
    }

    /// True once `elapsed_ms` (since strategy start) reaches the deadline.
    pub fn deadline_passed(&self, elapsed_ms: f64) -> bool {
        match self.deadline_ms {
            Some(d) => elapsed_ms >= d,
            None => false,
        }
    }

    /// Milliseconds left before the deadline (`f64::INFINITY` when none).
    pub fn ms_left(&self, elapsed_ms: f64) -> f64 {
        match self.deadline_ms {
            Some(d) => (d - elapsed_ms).max(0.0),
            None => f64::INFINITY,
        }
    }

    /// Absolute clock deadline for a strategy that started at `start_ms`
    /// — what the engine's mid-call preemption works against.
    pub fn deadline_at(&self, start_ms: f64) -> Option<f64> {
        self.deadline_ms.map(|d| start_ms + d)
    }

    /// No further engine work may be issued.
    pub fn exhausted(&self, used_tokens: usize, elapsed_ms: f64) -> bool {
        self.cancelled() || self.tokens_exhausted(used_tokens) || self.deadline_passed(elapsed_ms)
    }

    /// Clamp one candidate's generated tokens to what the token cap
    /// leaves, given `used` accounted so far. Returns the kept prefix
    /// and whether the cap bit (shared accounting for every method —
    /// keep this the single source of the truncation contract).
    pub fn clamp_tokens(&self, used: usize, tokens: &[u32]) -> (Vec<u32>, bool) {
        let left = self.tokens_left(used);
        if tokens.len() > left {
            (tokens[..left].to_vec(), true)
        } else {
            (tokens.to_vec(), false)
        }
    }
}

/// Hyperparameters `θ_m` of one strategy. Parallel methods use `n` only;
/// round-based (beam-family) methods use all three; wave-based methods
/// (`mv_early`) reuse `width` as their wave size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct StrategyParams {
    /// Candidates (parallel methods) or active beams (beam family).
    pub n: usize,
    /// Branching factor per beam per round (beam family), wave size for
    /// `mv_early` (≥ 2 explicit; ≤ 1 means the method's auto default),
    /// 1 otherwise.
    pub width: usize,
    /// Max tokens per beam round (0 for parallel methods).
    pub chunk: usize,
}

impl StrategyParams {
    pub fn parallel(n: usize) -> StrategyParams {
        StrategyParams { n, width: 1, chunk: 0 }
    }

    pub fn beam(n: usize, width: usize, chunk: usize) -> StrategyParams {
        StrategyParams { n, width, chunk }
    }

    /// Wave-based parallel method (`mv_early`): `wave` rides in `width`
    /// — it is a searchable hyperparameter exactly like beam's W, flows
    /// into the probe's existing `W/4` feature, and `wave <= 1` selects
    /// the method's auto sizing (`max(2, N/4)`).
    pub fn waves(n: usize, wave: usize) -> StrategyParams {
        StrategyParams {
            n,
            width: wave.max(1),
            chunk: 0,
        }
    }
}

/// Everything a decoding method needs to execute one request.
pub struct RunCtx<'a> {
    pub engine: &'a EngineHandle,
    pub clock: &'a SharedClock,
    pub tokenizer: &'a Tokenizer,
    /// Full query text (incl. the trailing `\n`).
    pub query: &'a str,
    /// Sampling temperature for candidate generation.
    pub temperature: f32,
    /// Depth bound D for round-based methods (max expansion rounds).
    pub beam_max_rounds: usize,
    /// Longest prefix (tokens) a beam may reach before being forced done.
    pub max_prefix: usize,
    /// Per-request budget this method must observe and report against.
    pub budget: Budget,
}

impl RunCtx<'_> {
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Build one generation job carrying this request's budget: the
    /// token cap left after `used` accounted tokens and the shared
    /// cancel flag, both enforced *inside* the engine's decode loop.
    pub fn gen_job(&self, tokens: Vec<u32>, kind: GenKind, used: usize) -> GenJob {
        let mut job = GenJob::new(tokens, kind, self.temperature);
        let left = self.budget.tokens_left(used);
        if left != usize::MAX {
            job = job.with_max_new_tokens(left);
        }
        if let Some(flag) = &self.budget.cancel {
            job = job.with_cancel(flag.clone());
        }
        job
    }

    /// Submit jobs under the budget's deadline (absolute, anchored at
    /// the strategy start `t0`): the engine halts decoding mid-call when
    /// it passes and returns partial results tagged `preempted`.
    pub fn generate_budgeted(&self, jobs: Vec<GenJob>, t0: f64) -> Result<Vec<GenResult>> {
        self.engine
            .generate_with_deadline(jobs, self.budget.deadline_at(t0))
    }

    /// Score CoT prefixes through the engine's coalesced PRM path:
    /// concurrent scoring requests from other workers merge with this
    /// one into shared bucket-shaped device calls (see
    /// [`crate::engine::scheduler`]). Step machines should express
    /// scoring as [`StepYield::PrmScore`] instead, so the serving
    /// layer batches it with other requests; this blocking entry point
    /// serves the drive-to-completion adapter and blocking custom
    /// methods. Memoize within a request where prefixes repeat across
    /// rounds (see the beam machine's cache).
    pub fn prm_score(&self, prefixes: Vec<Vec<u32>>) -> Result<Vec<f32>> {
        self.engine.prm_score(prefixes)
    }
}

/// What a batch of generated candidates did to the request's budget.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Accumulated {
    /// The shared token cap bit during accounting (caller reports it as
    /// `budget_exhausted`).
    pub truncated: bool,
    /// The engine halted at least one row mid-call (deadline, cancel or
    /// per-job cap) — `Outcome::preempted`, and a budget hit too.
    pub preempted: bool,
}

impl Accumulated {
    pub fn budget_hit(&self) -> bool {
        self.truncated || self.preempted
    }
}

/// Shared accumulation for single-prompt parallel candidates: clamp each
/// generated result to the token budget, decode, and collect it as a
/// [`Candidate`]. Once the cap is fully spent the remaining results are
/// dropped. Engine-level preemption (partial rows tagged
/// [`GenResult::preempted`]) is surfaced on the returned [`Accumulated`].
/// Keep this the single copy of the truncation contract —
/// `majority_vote`, best-of-N and `mv_early` all go through it.
pub(crate) fn accumulate_candidates(
    ctx: &RunCtx<'_>,
    results: &[GenResult],
    tokens_total: &mut usize,
    candidates: &mut Vec<Candidate>,
) -> Result<Accumulated> {
    let mut acc = Accumulated::default();
    for r in results {
        if r.preempted {
            acc.preempted = true;
        }
        let (kept, truncated) = ctx.budget.clamp_tokens(*tokens_total, &r.tokens);
        if truncated {
            acc.truncated = true;
        }
        if kept.is_empty() && (truncated || r.preempted) {
            // cap fully spent or the engine evicted this row before it
            // produced anything — nothing to vote with
            if truncated {
                break;
            }
            continue;
        }
        *tokens_total += kept.len();
        let text = format!("S:{}", ctx.tokenizer.decode(&kept)?);
        candidates.push(Candidate {
            text,
            score: 0.0,
            tokens: kept.len(),
        });
    }
    Ok(acc)
}

/// Result of running one strategy on one query.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Chosen solution text (includes the leading `S:`).
    pub chosen: String,
    /// Extracted final answer, if parseable.
    pub answer: Option<String>,
    /// Total tokens accounted (all candidates / all beams incl. pruned),
    /// never exceeding `Budget::max_tokens`.
    pub tokens: usize,
    /// End-to-end strategy latency in ms (generation + scoring).
    pub latency_ms: f64,
    /// Number of engine calls (diagnostic; beam ≫ parallel).
    pub engine_calls: usize,
    /// Completed generation rounds: 1 for single-batch parallel methods,
    /// waves issued for `mv_early`, expansion rounds for the beam family.
    /// The budget-bucket cost model predicts this under truncation.
    pub rounds: usize,
    /// The per-request budget ran out mid-strategy (token cap hit,
    /// deadline passed, or cancelled) and the method stopped issuing
    /// engine work.
    pub budget_exhausted: bool,
    /// The engine halted a generation call mid-decode for this request
    /// (deadline, cancel, or token cap) and returned partial rows.
    pub preempted: bool,
    /// The method finished before its configured work on purpose:
    /// early-stop vote decided, or deadline-aware round truncation.
    pub stopped_early: bool,
}

impl Outcome {
    pub fn is_correct(&self, ground_truth: &str) -> bool {
        self.answer.as_deref() == Some(ground_truth)
    }

    /// Outcome for a request whose budget was already spent before the
    /// first engine call: no work, no answer, budget reported.
    pub fn empty(latency_ms: f64) -> Outcome {
        Outcome {
            chosen: String::new(),
            answer: None,
            tokens: 0,
            latency_ms,
            engine_calls: 0,
            rounds: 0,
            budget_exhausted: true,
            preempted: false,
            stopped_early: false,
        }
    }
}

/// The engine results a step machine receives at the start of a step —
/// whatever its previous [`StepYield`] asked for.
#[derive(Debug)]
pub enum StepInput {
    /// First step of a freshly started machine: no engine work has been
    /// requested yet.
    Start,
    /// Results for the jobs of a previous [`StepYield::Generate`], in
    /// job order.
    Generated(Vec<GenResult>),
    /// Scores for the prefixes of a previous [`StepYield::PrmScore`],
    /// in prefix order.
    Scored(Vec<f32>),
}

/// What a step machine needs next from the serving layer.
#[derive(Debug)]
pub enum StepYield {
    /// Submit these generation jobs (per-job budget caps/cancel already
    /// attached) under an *absolute* engine-clock deadline, and resume
    /// the machine with [`StepInput::Generated`].
    Generate {
        jobs: Vec<GenJob>,
        /// Absolute deadline for the call (the machine anchors its
        /// budget's relative deadline at its own start time), or `None`.
        deadline_ms: Option<f64>,
    },
    /// Like [`StepYield::Generate`], but each job is submitted as its
    /// own engine request so results stream back per row as they
    /// finish: the serving layer fires
    /// [`StrategyState::on_row_result`] for every arriving row, and
    /// resumes the machine with [`StepInput::Generated`] (results in
    /// job order) once all rows are in. On the continuous engine the
    /// rows still coalesce into shared bucket-shaped sessions — the
    /// per-request split only changes when *replies* fire. This is how
    /// `mv_early` watches a wave mid-flight and stops the rest of it
    /// (via each job's shared stop flag) the moment the vote is
    /// decided.
    GenerateEach {
        jobs: Vec<GenJob>,
        /// Absolute deadline for the calls, or `None`.
        deadline_ms: Option<f64>,
    },
    /// Score these CoT prefixes with the PRM and resume with
    /// [`StepInput::Scored`].
    PrmScore(Vec<Vec<u32>>),
    /// The strategy finished; the machine must not be stepped again.
    Done(Outcome),
}

/// A resumable, in-flight execution of one decoding method on one query
/// (the continuation half of [`DecodingMethod::start`]).
///
/// A step machine owns all strategy-local state (candidates, beams,
/// token accounting, PRM memoization) but issues **no** engine calls
/// itself: every engine interaction is expressed as a [`StepYield`] and
/// the caller delivers the results through the next [`StepInput`]. That
/// inversion is what lets [`crate::strategies::stepper::Stepper`]
/// multiplex many in-flight machines onto one engine — concurrent
/// machines' yields land on the engine channel together, so the
/// coalescing scheduler merges them into shared bucket-shaped calls.
///
/// Contract:
///
/// * `step` is called with exactly the input the previous yield asked
///   for ([`StepInput::Start`] on the first call); anything else is an
///   internal error.
/// * The `ctx` passed to each step carries the *current* budget — the
///   serving layer may have extended it between steps (mid-flight
///   reallocation, see [`crate::router::Reallocator`]); machines must
///   re-read it every step rather than caching limits.
/// * After [`StepYield::Done`] the machine must not be stepped again.
pub trait StrategyState: Send {
    /// Advance the strategy by one step.
    fn step(&mut self, ctx: &RunCtx<'_>, input: StepInput) -> Result<StepYield>;

    /// Streamed notification for [`StepYield::GenerateEach`]: called
    /// once per row as its result arrives, *before* the machine is
    /// resumed with the full result set. The machine may only update
    /// internal state or flip shared flags here (e.g. set the wave's
    /// stop flag when the vote is decided so the engine retires the
    /// rows still decoding); it must not assume the remaining rows have
    /// run, and it still receives every row — this one included —
    /// through [`StepInput::Generated`] afterwards.
    fn on_row_result(&mut self, _ctx: &RunCtx<'_>, _row: usize, _result: &GenResult) {}
}

/// Drive a step machine to completion against the blocking engine API —
/// the run-to-completion adapter behind [`DecodingMethod::run`]. The
/// offline paths (matrix collection, figures, warmup) go through this,
/// so a method converted to a step machine needs no blocking
/// implementation of its own.
pub fn drive(ctx: &RunCtx<'_>, state: &mut (dyn StrategyState + '_)) -> Result<Outcome> {
    let mut input = StepInput::Start;
    loop {
        match state.step(ctx, input)? {
            StepYield::Generate { jobs, deadline_ms } => {
                input = StepInput::Generated(ctx.engine.generate_with_deadline(jobs, deadline_ms)?);
            }
            StepYield::GenerateEach { jobs, deadline_ms } => {
                input = StepInput::Generated(drive_each(ctx, state, jobs, deadline_ms)?);
            }
            StepYield::PrmScore(prefixes) => {
                input = StepInput::Scored(ctx.prm_score(prefixes)?);
            }
            StepYield::Done(outcome) => return Ok(outcome),
        }
    }
}

/// Blocking half of [`StepYield::GenerateEach`]: submit every job as
/// its own engine request, fire [`StrategyState::on_row_result`] as
/// each row's reply lands, and return the results in job order. Rows
/// are polled non-blockingly first so late rows hear about early ones
/// (that ordering is the whole point of the variant); when nothing is
/// ready we block briefly on the oldest outstanding reply.
fn drive_each(
    ctx: &RunCtx<'_>,
    state: &mut (dyn StrategyState + '_),
    jobs: Vec<GenJob>,
    deadline_ms: Option<f64>,
) -> Result<Vec<GenResult>> {
    let pending = jobs
        .into_iter()
        .map(|job| ctx.engine.submit_generate(vec![job], deadline_ms))
        .collect::<Result<Vec<_>>>()?;
    let mut results: Vec<Option<GenResult>> = (0..pending.len()).map(|_| None).collect();
    let mut outstanding: Vec<usize> = (0..pending.len()).collect();
    while !outstanding.is_empty() {
        let mut progressed = false;
        outstanding.retain(|&row| match pending[row].try_wait() {
            Some(reply) => {
                progressed = true;
                results[row] = Some(settle_row(ctx, state, row, reply));
                false
            }
            None => true,
        });
        if !progressed {
            let row = outstanding[0];
            let wait = Some(std::time::Duration::from_millis(2));
            if let Some(reply) = pending[row].wait_timeout(wait) {
                results[row] = Some(settle_row(ctx, state, row, reply));
                outstanding.remove(0);
            }
        }
    }
    let mut out = Vec::with_capacity(results.len());
    for (row, slot) in results.into_iter().enumerate() {
        out.push(slot.expect("outstanding drained")?.into_iter().next().ok_or_else(|| {
            Error::internal(format!("engine returned no rows for single-job request {row}"))
        })?);
    }
    Ok(out)
}

/// Fire the per-row hook for one arrived [`drive_each`] reply. Errors
/// are deferred to final assembly so every submitted row is joined.
fn settle_row(
    ctx: &RunCtx<'_>,
    state: &mut (dyn StrategyState + '_),
    row: usize,
    reply: Result<Vec<GenResult>>,
) -> Result<Vec<GenResult>> {
    if let Ok(rows) = &reply {
        if let Some(result) = rows.first() {
            state.on_row_result(ctx, row, result);
        }
    }
    reply
}

/// Fallback step machine for methods that only implement the blocking
/// [`DecodingMethod::run`]: a single step that executes the whole
/// strategy (engine calls included) and yields `Done`. Such methods
/// still work under the stepper — they just can't be suspended between
/// rounds, so they don't coalesce across requests or receive mid-flight
/// budget grants.
struct BlockingAdapter<'m, M: DecodingMethod + ?Sized> {
    method: &'m M,
    params: StrategyParams,
    done: bool,
}

impl<M: DecodingMethod + ?Sized> StrategyState for BlockingAdapter<'_, M> {
    fn step(&mut self, ctx: &RunCtx<'_>, _input: StepInput) -> Result<StepYield> {
        if self.done {
            return Err(Error::internal("stepped a finished strategy"));
        }
        self.done = true;
        Ok(StepYield::Done(self.method.run(ctx, &self.params)?))
    }
}

/// An open-ended decoding method (paper §2.1 generalized).
///
/// Implementations are registered in [`crate::strategies::registry`];
/// see the module docs of [`crate::strategies`] for the "adding a new
/// decoding method" walkthrough.
///
/// Execution comes in two equivalent shapes, and an implementation must
/// provide **at least one** of them (each default delegates to the
/// other, so implementing neither would recurse forever):
///
/// * [`DecodingMethod::start`] — the resumable shape: return a
///   [`StrategyState`] step machine. Preferred; the serving layer can
///   suspend/resume it between rounds and coalesce its engine work with
///   other in-flight requests. `run` then comes for free.
/// * [`DecodingMethod::run`] — the blocking shape: execute to
///   completion against `ctx`. `start` then wraps it in a one-step
///   fallback machine.
pub trait DecodingMethod: Send + Sync {
    /// Stable registry id — also the prefix of
    /// [`crate::strategies::Strategy::id`], a cost-model key, and the
    /// probe one-hot label. Never change it once matrices exist.
    fn name(&self) -> &'static str;

    /// One-line description for docs and CLI listings.
    fn describe(&self) -> &'static str;

    /// Round-based methods (beam family) run sequential PRM-scored
    /// rounds: they use `NxWcC` ids, contribute the rounds probe feature
    /// and appear in round-structured figures (Fig 9).
    fn uses_rounds(&self) -> bool {
        false
    }

    /// Reasonable middle-of-the-space parameters (benches, smoke tests).
    fn default_params(&self) -> StrategyParams {
        if self.uses_rounds() {
            StrategyParams::beam(4, 2, 12)
        } else {
            StrategyParams::parallel(4)
        }
    }

    /// Render `θ_m` for [`crate::strategies::Strategy::id`]
    /// (`"8"` or `"4x2c12"`).
    fn format_params(&self, p: &StrategyParams) -> String {
        if self.uses_rounds() {
            format!("{}x{}c{}", p.n, p.width, p.chunk)
        } else {
            p.n.to_string()
        }
    }

    /// Parse `θ_m` back (inverse of [`DecodingMethod::format_params`]).
    fn parse_params(&self, s: &str) -> Option<StrategyParams> {
        if self.uses_rounds() {
            let (n, rest) = s.split_once('x')?;
            let (w, c) = rest.split_once('c')?;
            Some(StrategyParams::beam(
                n.parse().ok()?,
                w.parse().ok()?,
                c.parse().ok()?,
            ))
        } else {
            Some(StrategyParams::parallel(s.parse().ok()?))
        }
    }

    /// Begin a resumable execution on `ctx.query` under `ctx.budget`:
    /// returns the strategy's step machine, anchored (time zero for the
    /// relative deadline) at `ctx.now_ms()`. The default wraps
    /// [`DecodingMethod::run`] in a single blocking step.
    fn start<'s>(
        &'s self,
        _ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        Ok(Box::new(BlockingAdapter {
            method: self,
            params: *params,
            done: false,
        }))
    }

    /// Execute on `ctx.query` under `ctx.budget`, blocking until the
    /// outcome. The default drives [`DecodingMethod::start`]'s step
    /// machine to completion — byte-identical results at temperature 0.
    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        let mut state = self.start(ctx, params)?;
        drive(ctx, state.as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, prop_assert};

    #[test]
    fn unlimited_budget_never_binds() {
        let b = Budget::unlimited();
        assert!(b.is_unlimited());
        assert!(!b.exhausted(usize::MAX - 1, 1e12));
        assert_eq!(b.tokens_left(123), usize::MAX);
        assert_eq!(b.ms_left(1e9), f64::INFINITY);
    }

    #[test]
    fn token_cap_binds() {
        let b = Budget::unlimited().with_max_tokens(10);
        assert!(!b.tokens_exhausted(9));
        assert!(b.tokens_exhausted(10));
        assert_eq!(b.tokens_left(4), 6);
        assert_eq!(b.tokens_left(15), 0);
    }

    #[test]
    fn deadline_binds_at_zero() {
        let b = Budget::unlimited().with_deadline_ms(0.0);
        assert!(b.deadline_passed(0.0));
        assert!(b.exhausted(0, 0.0));
        let b = Budget::unlimited().with_deadline_ms(5.0);
        assert!(!b.deadline_passed(4.9));
        assert!(b.deadline_passed(5.0));
        assert_eq!(b.ms_left(2.0), 3.0);
        assert_eq!(b.ms_left(9.0), 0.0);
    }

    #[test]
    fn cancel_flag_flips() {
        let flag = Arc::new(AtomicBool::new(false));
        let b = Budget::unlimited().with_cancel(flag.clone());
        assert!(!b.cancelled());
        flag.store(true, Ordering::Relaxed);
        assert!(b.cancelled());
        assert!(b.exhausted(0, 0.0));
    }

    #[test]
    fn prop_budget_accounting_consistent() {
        forall(
            "exhausted ⇔ (cancel ∨ token cap ∨ deadline)",
            300,
            |rng| {
                let cap = rng.below(200) as usize;
                let used = rng.below(300) as usize;
                let deadline = rng.f64() * 100.0;
                let elapsed = rng.f64() * 150.0;
                (cap, used, deadline, elapsed)
            },
            |&(cap, used, deadline, elapsed)| {
                let b = Budget::unlimited()
                    .with_max_tokens(cap)
                    .with_deadline_ms(deadline);
                let expect = used >= cap || elapsed >= deadline;
                prop_assert(
                    b.exhausted(used, elapsed) == expect,
                    format!("cap={cap} used={used} deadline={deadline} elapsed={elapsed}"),
                )?;
                prop_assert(
                    b.tokens_left(used) == cap.saturating_sub(used),
                    "tokens_left mismatch".to_string(),
                )
            },
        );
    }

    #[test]
    fn empty_outcome_reports_flags() {
        let o = Outcome::empty(1.5);
        assert_eq!(o.tokens, 0);
        assert_eq!(o.engine_calls, 0);
        assert_eq!(o.rounds, 0);
        assert!(o.budget_exhausted);
        assert!(!o.preempted);
        assert!(!o.stopped_early);
        assert!(!o.is_correct("3"));
    }

    #[test]
    fn deadline_at_anchors_absolute() {
        let b = Budget::unlimited().with_deadline_ms(100.0);
        assert_eq!(b.deadline_at(250.0), Some(350.0));
        assert_eq!(Budget::unlimited().deadline_at(250.0), None);
    }

    #[test]
    fn clamp_tokens_shared_accounting() {
        let b = Budget::unlimited().with_max_tokens(5);
        let toks = vec![1u32, 2, 3, 4];
        assert_eq!(b.clamp_tokens(0, &toks), (toks.clone(), false));
        assert_eq!(b.clamp_tokens(2, &toks), (vec![1, 2, 3], true));
        assert_eq!(b.clamp_tokens(5, &toks), (vec![], true));
        let unlimited = Budget::unlimited();
        assert_eq!(unlimited.clamp_tokens(1_000_000, &toks), (toks.clone(), false));
    }
}
