//! Global decoding-method registry.
//!
//! Maps stable method names to [`DecodingMethod`] implementations. The
//! built-in methods are installed on first access in a fixed order — the
//! order *is* the probe one-hot feature index, so it must never be
//! reshuffled once probes have been trained (append-only). Additional
//! methods can be registered at runtime with [`register`]; they extend
//! the feature layout for builders constructed afterwards.

use crate::error::{Error, Result};
use crate::strategies::beam::{Beam, LatencyAwareBeam};
use crate::strategies::early_stop::EarlyStopMajority;
use crate::strategies::method::DecodingMethod;
use crate::strategies::parallel::{BestOfNNaive, BestOfNWeighted, MajorityVote};
use std::sync::{OnceLock, RwLock};

fn table() -> &'static RwLock<Vec<&'static dyn DecodingMethod>> {
    static TABLE: OnceLock<RwLock<Vec<&'static dyn DecodingMethod>>> = OnceLock::new();
    TABLE.get_or_init(|| {
        // Append-only: indices 0..3 match the pre-registry Method enum
        // (and any probe checkpoint trained against it, modulo width).
        RwLock::new(vec![
            &MajorityVote as &'static dyn DecodingMethod,
            &BestOfNNaive,
            &BestOfNWeighted,
            &Beam,
            &EarlyStopMajority,
            &LatencyAwareBeam,
        ])
    })
}

/// Look up a method by its stable id.
pub fn get(name: &str) -> Option<&'static dyn DecodingMethod> {
    table().read().unwrap().iter().copied().find(|m| m.name() == name)
}

/// All registered methods, in stable feature order.
pub fn all() -> Vec<&'static dyn DecodingMethod> {
    table().read().unwrap().clone()
}

/// Number of registered methods — the width of the probe one-hot block
/// for feature builders constructed now.
pub fn len() -> usize {
    table().read().unwrap().len()
}

/// Stable one-hot index of a method (its registration order).
pub fn feature_index(name: &str) -> Option<usize> {
    table().read().unwrap().iter().position(|m| m.name() == name)
}

/// Register a new decoding method. The implementation is leaked to get a
/// `'static` handle (registration is once-per-process by design).
/// Returns an error — without leaking — if the name is already taken.
pub fn register(method: Box<dyn DecodingMethod>) -> Result<&'static dyn DecodingMethod> {
    let mut t = table().write().unwrap();
    if t.iter().any(|m| m.name() == method.name()) {
        return Err(Error::Config(format!(
            "decoding method '{}' is already registered",
            method.name()
        )));
    }
    let method: &'static dyn DecodingMethod = Box::leak(method);
    t.push(method);
    Ok(method)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_present_in_stable_order() {
        let names: Vec<&str> = all().iter().map(|m| m.name()).collect();
        // Append-only contract: the first six are frozen.
        assert_eq!(
            &names[..6],
            &[
                "majority_vote",
                "bon_naive",
                "bon_weighted",
                "beam",
                "mv_early",
                "beam_latency"
            ]
        );
        for (i, n) in names.iter().enumerate().take(6) {
            assert_eq!(feature_index(n), Some(i));
        }
    }

    #[test]
    fn lookup_and_misses() {
        assert!(get("beam").is_some());
        assert!(get("majority_vote").is_some());
        assert!(get("nope").is_none());
        assert!(feature_index("nope").is_none());
        assert!(len() >= 6);
    }

    #[test]
    fn round_methods_flagged() {
        assert!(get("beam").unwrap().uses_rounds());
        assert!(get("beam_latency").unwrap().uses_rounds());
        assert!(!get("majority_vote").unwrap().uses_rounds());
        assert!(!get("mv_early").unwrap().uses_rounds());
    }
}
