//! Single-batch parallel methods: majority voting and best-of-N.
//!
//! All three ride one batched `lm_generate` call (latency ≈ a single
//! generation); the best-of-N variants add one batched PRM call. Budget
//! semantics: the budget rides down into the engine (per-job token caps,
//! shared cancel flag, absolute call deadline) so the batched call is
//! preempted mid-decode; token accounting is additionally truncated at
//! `Budget::max_tokens` (candidates beyond the cap are dropped), and the
//! PRM call is skipped when the deadline has already passed — a late
//! request degrades to an unscored pick instead of spending another
//! engine call.
//!
//! Execution is a three-phase step machine (generate → optionally score
//! → done), so the serving layer can interleave many requests' phases
//! and coalesce their engine calls; `run()` drives the same machine to
//! completion for the offline paths.

use crate::engine::GenKind;
use crate::error::{Error, Result};
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    accumulate_candidates, DecodingMethod, Outcome, RunCtx, StepInput, StepYield, StrategyParams,
    StrategyState,
};

const PARALLEL_ROUNDS: usize = 1;

/// How the winning candidate is chosen.
#[derive(Clone, Copy)]
enum Chooser {
    Majority,
    BestNaive,
    BestWeighted,
}

impl Chooser {
    fn needs_prm(self) -> bool {
        !matches!(self, Chooser::Majority)
    }

    fn choose(self, candidates: &[Candidate]) -> Option<&Candidate> {
        match self {
            Chooser::Majority => eval::majority_vote(candidates),
            Chooser::BestNaive => eval::best_of_n(candidates),
            Chooser::BestWeighted => eval::weighted_vote(candidates),
        }
    }
}

/// Where the machine is in its generate → score → done progression.
enum Phase {
    /// Nothing issued yet.
    Fresh,
    /// Waiting on the single batched generate call.
    Generating,
    /// Waiting on the PRM scores for the generated candidates.
    Scoring,
    /// Finished — stepping again is an error.
    Done,
}

/// Step machine shared by all single-batch parallel methods: one batched
/// generate + optional PRM scoring (appendix A.2: scoring time is part
/// of latency), with budget observance between and inside phases.
struct SingleBatchState {
    chooser: Chooser,
    n: usize,
    /// Strategy start on the engine clock — anchors the relative
    /// deadline and the reported latency.
    t0: f64,
    phase: Phase,
    tokens_total: usize,
    candidates: Vec<Candidate>,
    engine_calls: usize,
    budget_exhausted: bool,
    preempted: bool,
}

impl SingleBatchState {
    fn finish(&mut self, ctx: &RunCtx<'_>) -> Result<StepYield> {
        self.phase = Phase::Done;
        let chosen_text = self
            .chooser
            .choose(&self.candidates)
            .map(|c| c.text.clone())
            .unwrap_or_default();
        Ok(StepYield::Done(Outcome {
            answer: eval::extract_answer(&chosen_text),
            chosen: chosen_text,
            tokens: self.tokens_total,
            latency_ms: ctx.now_ms() - self.t0,
            engine_calls: self.engine_calls,
            rounds: PARALLEL_ROUNDS,
            budget_exhausted: self.budget_exhausted,
            preempted: self.preempted,
            stopped_early: false,
        }))
    }
}

impl StrategyState for SingleBatchState {
    fn step(&mut self, ctx: &RunCtx<'_>, input: StepInput) -> Result<StepYield> {
        // Take the phase out; every arm that continues writes the next
        // phase back, so a mismatched input leaves the machine poisoned
        // as Done.
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match (phase, input) {
            (Phase::Fresh, StepInput::Start) => {
                if ctx.budget.exhausted(0, ctx.now_ms() - self.t0) {
                    self.phase = Phase::Done;
                    return Ok(StepYield::Done(Outcome::empty(ctx.now_ms() - self.t0)));
                }
                let prompt = format!("{}S:", ctx.query);
                let prompt_ids = ctx.tokenizer.encode(&prompt)?;
                // budgeted jobs: per-job token cap + shared cancel flag,
                // plus the absolute deadline on the call — the engine
                // preempts mid-decode
                let jobs = (0..self.n)
                    .map(|_| ctx.gen_job(prompt_ids.clone(), GenKind::Full, 0))
                    .collect();
                self.phase = Phase::Generating;
                Ok(StepYield::Generate {
                    jobs,
                    deadline_ms: ctx.budget.deadline_at(self.t0),
                })
            }
            (Phase::Generating, StepInput::Generated(results)) => {
                self.engine_calls = 1;
                let acc = accumulate_candidates(
                    ctx,
                    &results,
                    &mut self.tokens_total,
                    &mut self.candidates,
                )?;
                self.budget_exhausted = acc.budget_hit();
                self.preempted = acc.preempted;
                if self.chooser.needs_prm() && !self.candidates.is_empty() {
                    if self.budget_exhausted
                        || ctx.budget.deadline_passed(ctx.now_ms() - self.t0)
                        || ctx.budget.cancelled()
                    {
                        // No further engine calls once the budget is
                        // spent (token cap, deadline or cancellation);
                        // the chooser falls back to the first parseable
                        // candidate.
                        self.budget_exhausted = true;
                    } else {
                        let prefixes: Vec<Vec<u32>> = self
                            .candidates
                            .iter()
                            .map(|c| ctx.tokenizer.encode(&format!("{}{}", ctx.query, c.text)))
                            .collect::<Result<_>>()?;
                        // the engine's scheduler coalesces this with
                        // concurrent requests' scoring into shared
                        // bucket-shaped calls
                        self.phase = Phase::Scoring;
                        return Ok(StepYield::PrmScore(prefixes));
                    }
                }
                self.finish(ctx)
            }
            (Phase::Scoring, StepInput::Scored(scores)) => {
                self.engine_calls += 1;
                for (c, s) in self.candidates.iter_mut().zip(scores) {
                    c.score = s as f64;
                }
                self.finish(ctx)
            }
            _ => Err(Error::internal(
                "single-batch strategy stepped with mismatched input",
            )),
        }
    }
}

/// Shared `start` for the three choosers.
fn start_single_batch(
    ctx: &RunCtx<'_>,
    params: &StrategyParams,
    chooser: Chooser,
) -> Result<Box<dyn StrategyState>> {
    Ok(Box::new(SingleBatchState {
        chooser,
        n: params.n.max(1),
        t0: ctx.now_ms(),
        phase: Phase::Fresh,
        tokens_total: 0,
        candidates: Vec::new(),
        engine_calls: 0,
        budget_exhausted: false,
        preempted: false,
    }))
}

/// N parallel candidates, most frequent answer (paper §2.1 "Majority").
pub struct MajorityVote;

impl DecodingMethod for MajorityVote {
    fn name(&self) -> &'static str {
        "majority_vote"
    }
    fn describe(&self) -> &'static str {
        "N parallel candidates, most frequent extracted answer"
    }
    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        start_single_batch(ctx, params, Chooser::Majority)
    }
}

/// N parallel candidates, highest PRM score wins (paper §2.1 "Naive").
pub struct BestOfNNaive;

impl DecodingMethod for BestOfNNaive {
    fn name(&self) -> &'static str {
        "bon_naive"
    }
    fn describe(&self) -> &'static str {
        "N parallel candidates, single highest PRM score wins"
    }
    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        start_single_batch(ctx, params, Chooser::BestNaive)
    }
}

/// N parallel candidates, PRM scores aggregated over identical answers
/// (paper §2.1 "Weighted").
pub struct BestOfNWeighted;

impl DecodingMethod for BestOfNWeighted {
    fn name(&self) -> &'static str {
        "bon_weighted"
    }
    fn describe(&self) -> &'static str {
        "N parallel candidates, PRM scores summed per identical answer"
    }
    fn start<'s>(
        &'s self,
        ctx: &RunCtx<'_>,
        params: &StrategyParams,
    ) -> Result<Box<dyn StrategyState + 's>> {
        start_single_batch(ctx, params, Chooser::BestWeighted)
    }
}
