//! Single-batch parallel methods: majority voting and best-of-N.
//!
//! All three ride one batched `lm_generate` call (latency ≈ a single
//! generation); the best-of-N variants add one batched PRM call. Budget
//! semantics: the budget rides down into the engine (per-job token caps,
//! shared cancel flag, absolute call deadline) so the batched call is
//! preempted mid-decode; token accounting is additionally truncated at
//! `Budget::max_tokens` (candidates beyond the cap are dropped), and the
//! PRM call is skipped when the deadline has already passed — a late
//! request degrades to an unscored pick instead of spending another
//! engine call.

use crate::engine::{GenJob, GenKind};
use crate::error::Result;
use crate::eval::{self, Candidate};
use crate::strategies::method::{
    accumulate_candidates, DecodingMethod, Outcome, RunCtx, StrategyParams,
};

const PARALLEL_ROUNDS: usize = 1;

/// How the winning candidate is chosen.
#[derive(Clone, Copy)]
enum Chooser {
    Majority,
    BestNaive,
    BestWeighted,
}

impl Chooser {
    fn needs_prm(self) -> bool {
        !matches!(self, Chooser::Majority)
    }

    fn choose(self, candidates: &[Candidate]) -> Option<&Candidate> {
        match self {
            Chooser::Majority => eval::majority_vote(candidates),
            Chooser::BestNaive => eval::best_of_n(candidates),
            Chooser::BestWeighted => eval::weighted_vote(candidates),
        }
    }
}

/// Shared runner: one batched generate + optional PRM scoring (appendix
/// A.2: scoring time is part of latency), with budget observance.
fn run_single_batch(
    ctx: &RunCtx<'_>,
    params: &StrategyParams,
    chooser: Chooser,
) -> Result<Outcome> {
    let t0 = ctx.now_ms();
    if ctx.budget.exhausted(0, 0.0) {
        return Ok(Outcome::empty(ctx.now_ms() - t0));
    }
    let n = params.n.max(1);
    let prompt = format!("{}S:", ctx.query);
    let prompt_ids = ctx.tokenizer.encode(&prompt)?;
    // budgeted jobs: per-job token cap + shared cancel flag, plus the
    // absolute deadline on the call — the engine preempts mid-decode
    let jobs: Vec<GenJob> = (0..n)
        .map(|_| ctx.gen_job(prompt_ids.clone(), GenKind::Full, 0))
        .collect();
    let results = ctx.generate_budgeted(jobs, t0)?;
    let mut engine_calls = 1usize;

    let mut tokens_total = 0usize;
    let mut candidates: Vec<Candidate> = Vec::with_capacity(results.len());
    let acc = accumulate_candidates(ctx, &results, &mut tokens_total, &mut candidates)?;
    let mut budget_exhausted = acc.budget_hit();

    if chooser.needs_prm() && !candidates.is_empty() {
        if budget_exhausted
            || ctx.budget.deadline_passed(ctx.now_ms() - t0)
            || ctx.budget.cancelled()
        {
            // No further engine calls once the budget is spent (token
            // cap, deadline or cancellation); the chooser falls back to
            // the first parseable candidate.
            budget_exhausted = true;
        } else {
            let prefixes: Vec<Vec<u32>> = candidates
                .iter()
                .map(|c| ctx.tokenizer.encode(&format!("{}{}", ctx.query, c.text)))
                .collect::<Result<_>>()?;
            // the engine's scheduler coalesces this with concurrent
            // workers' scoring into shared bucket-shaped calls
            let scores = ctx.prm_score(prefixes)?;
            engine_calls += 1;
            for (c, s) in candidates.iter_mut().zip(scores) {
                c.score = s as f64;
            }
        }
    }

    let chosen_text = chooser
        .choose(&candidates)
        .map(|c| c.text.clone())
        .unwrap_or_default();
    Ok(Outcome {
        answer: eval::extract_answer(&chosen_text),
        chosen: chosen_text,
        tokens: tokens_total,
        latency_ms: ctx.now_ms() - t0,
        engine_calls,
        rounds: PARALLEL_ROUNDS,
        budget_exhausted,
        preempted: acc.preempted,
        stopped_early: false,
    })
}

/// N parallel candidates, most frequent answer (paper §2.1 "Majority").
pub struct MajorityVote;

impl DecodingMethod for MajorityVote {
    fn name(&self) -> &'static str {
        "majority_vote"
    }
    fn describe(&self) -> &'static str {
        "N parallel candidates, most frequent extracted answer"
    }
    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        run_single_batch(ctx, params, Chooser::Majority)
    }
}

/// N parallel candidates, highest PRM score wins (paper §2.1 "Naive").
pub struct BestOfNNaive;

impl DecodingMethod for BestOfNNaive {
    fn name(&self) -> &'static str {
        "bon_naive"
    }
    fn describe(&self) -> &'static str {
        "N parallel candidates, single highest PRM score wins"
    }
    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        run_single_batch(ctx, params, Chooser::BestNaive)
    }
}

/// N parallel candidates, PRM scores aggregated over identical answers
/// (paper §2.1 "Weighted").
pub struct BestOfNWeighted;

impl DecodingMethod for BestOfNWeighted {
    fn name(&self) -> &'static str {
        "bon_weighted"
    }
    fn describe(&self) -> &'static str {
        "N parallel candidates, PRM scores summed per identical answer"
    }
    fn run(&self, ctx: &RunCtx<'_>, params: &StrategyParams) -> Result<Outcome> {
        run_single_batch(ctx, params, Chooser::BestWeighted)
    }
}
