//! Inference-time scaling strategies (paper §2.1, generalized).
//!
//! A *decoding strategy* is `s = (m, θ_m)` where `m` names a
//! [`DecodingMethod`] in the open [`registry`] and `θ_m` is its
//! [`StrategyParams`]. The built-in methods, in stable feature order:
//!
//! | id | description | shape |
//! |---|---|---|
//! | `majority_vote` | N parallel candidates, most frequent answer | 1 batched call |
//! | `bon_naive` | N parallel candidates, highest PRM score | 1 call + PRM |
//! | `bon_weighted` | PRM scores aggregated across identical answers | 1 call + PRM |
//! | `beam` | N beams × W expansions per CoT step, PRM-pruned | 1 call *per round* |
//! | `mv_early` | majority voting in waves (searchable wave size), stops when the vote is decided | 1..⌈N/wave⌉ calls |
//! | `beam_latency` | beam search with predictive deadline truncation | ≤ beam's calls |
//!
//! The parallel methods ride one batched `lm_generate` call (latency ≈ a
//! single generation); the beam family issues one batched `lm_chunk` call
//! *per round* plus a PRM call — the step-synchronized structure whose
//! latency cost the paper's router learns to avoid when `λ_L` is high.
//! `mv_early` and `beam_latency` close the loop the paper leaves open:
//! budgets are not just *predicted* by the router but *enforced* inside
//! the strategy via the per-request [`Budget`] in [`RunCtx`].
//!
//! # Execution shapes: step machines and the continuation executor
//!
//! Every method executes as a resumable **step machine**
//! ([`method::StrategyState`]): `DecodingMethod::start` returns a
//! machine whose `step()` *yields* engine work (`Generate`, `PrmScore`)
//! instead of blocking on it, and `run()` is the blanket
//! drive-to-completion adapter over the same machine — the offline
//! matrix/figure paths use `run()` and see identical results. The
//! serving path instead multiplexes many machines onto one thread with
//! the continuation executor ([`stepper::Stepper`]): concurrent
//! requests' rounds are submitted together so the engine scheduler
//! coalesces them, and a between-steps reallocation hook
//! ([`crate::router::Reallocator`]) re-grants finished requests'
//! leftover budget mid-flight. Contract details in `docs/strategies.md`.
//!
//! # Adding a new decoding method
//!
//! No edits to the router, probe features, cost model, figures or config
//! enumeration are needed — they all resolve methods through the
//! registry by stable name:
//!
//! 1. Implement [`DecodingMethod`] (see `parallel.rs` for the minimal
//!    shape, `early_stop.rs` for a multi-wave machine, `beam.rs` for a
//!    multi-phase one). Prefer implementing `start()` (the step-machine
//!    shape — suspendable, coalescible, reallocation-aware); a blocking
//!    `run()` also works and is wrapped in a one-step fallback machine.
//!    Honor `ctx.budget` *re-reading it every step*: stop issuing
//!    engine calls once it is exhausted and report via
//!    `Outcome::{budget_exhausted, stopped_early}`.
//! 2. Register it: built-ins append themselves to the table in
//!    `registry.rs` (append-only — the order is the probe one-hot
//!    index); external code calls
//!    `registry::register(Box::new(MyMethod))` once at startup.
//! 3. Put it in the space: add `"my_method@8"` to `space.extra` in the
//!    config (or a `Strategy::new("my_method", params)` anywhere). Ids
//!    round-trip through `Strategy::id`/`Strategy::parse` automatically;
//!    cost-model keys, matrices and figures pick the method up from its
//!    id.
//! 4. Re-run `collect`/`train-probe`: the probe one-hot block widens
//!    with the registry, so `python/compile/model.py::PROBE_FEATURES`
//!    must match `registry::len()` when regenerating artifacts.

pub mod beam;
pub mod early_stop;
pub mod executor;
pub mod method;
pub mod parallel;
pub mod registry;
pub mod space;
pub mod stepper;

pub use executor::Executor;
pub use method::{
    Budget, DecodingMethod, Outcome, RunCtx, StepInput, StepYield, StrategyParams, StrategyState,
};
pub use space::Strategy;
pub use stepper::{Completion, Progress, Stepper, Ticket};
