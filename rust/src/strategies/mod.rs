//! Inference-time scaling strategies (paper §2.1).
//!
//! A *decoding strategy* is `s = (method, θ_method)`:
//!
//! * **Majority voting** — N parallel candidates, most frequent answer.
//! * **Best-of-N (naive)** — N parallel candidates, highest PRM score.
//! * **Best-of-N (weighted)** — PRM scores aggregated across identical
//!   answers.
//! * **Beam search** — incremental: N beams × W expansions per CoT step,
//!   PRM-scored, top-N retained, answer by majority over final beams.
//!
//! The parallel methods ride one batched `lm_generate` call (latency ≈ a
//! single generation); beam search issues one batched `lm_chunk` call
//! *per round* plus a PRM call — the step-synchronized structure whose
//! latency cost the paper's router learns to avoid when `λ_L` is high.

pub mod beam;
pub mod executor;
pub mod space;

pub use executor::{Executor, Outcome};
pub use space::{Method, Strategy};
