//! Crate-wide error type.

/// Unified error for the ttc library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// IO failure (file paths included in the message).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON parse or schema error from [`crate::util::json`].
    #[error("json error: {0}")]
    Json(String),

    /// Error bubbled up from the `xla` crate / PJRT.
    #[error("xla error: {0}")]
    Xla(String),

    /// A required artifact (HLO, weights, vocab, data) is missing or
    /// malformed. Usually means `make artifacts` has not been run.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Configuration error (bad CLI flag, bad config file).
    #[error("config error: {0}")]
    Config(String),

    /// The engine thread is gone or rejected a request.
    #[error("engine error: {0}")]
    Engine(String),

    /// Invariant violation inside a coordinator component.
    #[error("internal error: {0}")]
    Internal(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for formatted artifact errors.
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    /// Helper for formatted internal errors.
    pub fn internal(msg: impl Into<String>) -> Self {
        Error::Internal(msg.into())
    }
}
