//! Crate-wide error type.

/// Unified error for the ttc library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// IO failure (file paths included in the message).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON parse or schema error from [`crate::util::json`].
    #[error("json error: {0}")]
    Json(String),

    /// Error bubbled up from the `xla` crate / PJRT.
    #[error("xla error: {0}")]
    Xla(String),

    /// A required artifact (HLO, weights, vocab, data) is missing or
    /// malformed. Usually means `make artifacts` has not been run.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Configuration error (bad CLI flag, bad config file).
    #[error("config error: {0}")]
    Config(String),

    /// The engine thread is gone or rejected a request.
    #[error("engine error: {0}")]
    Engine(String),

    /// A remote-engine wire fault: connection, framing, protocol version
    /// or handshake mismatch, or an error the server reported over the
    /// wire. Kept distinct from [`Error::Artifact`]/[`Error::Internal`]
    /// so remote faults never masquerade as local ones. `transient`
    /// marks faults worth retrying (connect refused, timeouts, dropped
    /// connections) as opposed to protocol disagreements.
    #[error("net error: {message}")]
    Net {
        /// Human-readable description of the fault.
        message: String,
        /// True when a retry (possibly on another shard) may succeed.
        transient: bool,
    },

    /// Invariant violation inside a coordinator component.
    #[error("internal error: {0}")]
    Internal(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for formatted artifact errors.
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    /// Helper for formatted internal errors.
    pub fn internal(msg: impl Into<String>) -> Self {
        Error::Internal(msg.into())
    }
    /// A permanent (non-retryable) network/protocol error.
    pub fn net(msg: impl Into<String>) -> Self {
        Error::Net {
            message: msg.into(),
            transient: false,
        }
    }
    /// A transient network error: retrying, possibly against another
    /// shard, may succeed.
    pub fn net_transient(msg: impl Into<String>) -> Self {
        Error::Net {
            message: msg.into(),
            transient: true,
        }
    }
    /// True for transient [`Error::Net`] faults — the signal the pool's
    /// failover path keys on.
    pub fn is_transient_net(&self) -> bool {
        matches!(self, Error::Net { transient: true, .. })
    }
    /// Short machine-readable kind tag, used by the wire error envelope.
    pub fn kind_str(&self) -> &'static str {
        match self {
            Error::Io(_) => "io",
            Error::Json(_) => "json",
            Error::Xla(_) => "xla",
            Error::Artifact(_) => "artifact",
            Error::Config(_) => "config",
            Error::Engine(_) => "engine",
            Error::Net { .. } => "net",
            Error::Internal(_) => "internal",
        }
    }
    /// Best-effort clone for fan-out to multiple reply channels
    /// (`Error` is not `Clone` because [`std::io::Error`] is not).
    /// Preserves the variant — in particular `Net { transient }`, which
    /// failover logic inspects — except `Io`, which degrades to
    /// `Engine` with the formatted message.
    pub fn replicate(&self) -> Error {
        match self {
            Error::Io(e) => Error::Engine(format!("io error: {e}")),
            Error::Json(m) => Error::Json(m.clone()),
            Error::Xla(m) => Error::Xla(m.clone()),
            Error::Artifact(m) => Error::Artifact(m.clone()),
            Error::Config(m) => Error::Config(m.clone()),
            Error::Engine(m) => Error::Engine(m.clone()),
            Error::Net { message, transient } => Error::Net {
                message: message.clone(),
                transient: *transient,
            },
            Error::Internal(m) => Error::Internal(m.clone()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_errors_carry_transience() {
        assert!(Error::net_transient("conn reset").is_transient_net());
        assert!(!Error::net("bad version").is_transient_net());
        assert!(!Error::internal("x").is_transient_net());
        assert_eq!(Error::net("v1 vs v2").to_string(), "net error: v1 vs v2");
    }

    #[test]
    fn replicate_preserves_variant_and_transience() {
        let e = Error::net_transient("peer gone");
        let r = e.replicate();
        assert!(r.is_transient_net());
        assert_eq!(r.to_string(), e.to_string());
        assert_eq!(r.kind_str(), "net");

        let io = Error::Io(std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "pipe closed",
        ));
        let r = io.replicate();
        assert_eq!(r.kind_str(), "engine");
        assert!(r.to_string().contains("pipe closed"));
    }
}
