//! Figure regeneration: every figure in the paper, recomputed offline
//! from the evaluation matrix + probe predictions.
//!
//! | id | paper figure | emitter |
//! |---|---|---|
//! | 1a | accuracy–token tradeoff, λ_L fixed, λ_T swept | [`sweeps::fig1`] |
//! | 1b | accuracy–latency tradeoff, λ_T fixed, λ_L swept | [`sweeps::fig1`] |
//! | 2  | method / N selection proportions vs λ | [`sweeps::fig2`] |
//! | 3  | probe calibration (binned reliability) | [`calibration::fig3`] |
//! | 4  | per-method cost profile | [`methods::fig4`] |
//! | 5/6| Figs 1a/1b with compact ("BERT") embeddings | [`sweeps::fig1`] |
//! | 7/8| predicted vs ground-truth costs | [`sweeps::fig78`] |
//! | 9  | beam-only adaptive hyperparameter selection | [`beam::fig9`] |
//!
//! All emitters consume an [`EvalTable`] — dense `[query × strategy]`
//! grids of empirical accuracy/tokens/latency (from the test matrix) and
//! probe predictions — so a full λ sweep costs microseconds per point.

pub mod beam;
pub mod calibration;
pub mod methods;
pub mod sweeps;

use crate::costmodel::{CostEstimate, CostModel};
use crate::data::Query;
use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::strategies::Strategy;
use crate::util::stats;
use std::collections::HashMap;
use std::io::Write as _;
use std::path::Path;

/// Dense per-(query, strategy) evaluation grids.
pub struct EvalTable {
    pub queries: Vec<Query>,
    pub strategies: Vec<Strategy>,
    /// Empirical soft accuracy `[q][s]`.
    pub acc: Vec<Vec<f64>>,
    /// Mean generated tokens `[q][s]` (oracle token cost).
    pub tokens: Vec<Vec<f64>>,
    /// Mean latency ms `[q][s]` (oracle latency cost).
    pub latency: Vec<Vec<f64>>,
    /// Probe predictions `â_s(x)` `[q][s]`.
    pub probs: Vec<Vec<f64>>,
    /// Per-strategy mean cost estimates (the deployable cost model).
    pub cost_estimates: Vec<CostEstimate>,
}

impl EvalTable {
    /// Assemble from a test matrix, probe predictions and the cost model.
    ///
    /// `probs` must be indexed `[q][s]` against the given query/strategy
    /// orders (see `server::commands::build_eval_table`).
    pub fn new(
        queries: Vec<Query>,
        strategies: Vec<Strategy>,
        matrix: &Matrix,
        probs: Vec<Vec<f64>>,
        costs: &CostModel,
    ) -> Result<EvalTable> {
        let cells = matrix.cells();
        let mut acc = Vec::with_capacity(queries.len());
        let mut tokens = Vec::with_capacity(queries.len());
        let mut latency = Vec::with_capacity(queries.len());
        for q in &queries {
            let mut arow = Vec::with_capacity(strategies.len());
            let mut trow = Vec::with_capacity(strategies.len());
            let mut lrow = Vec::with_capacity(strategies.len());
            for s in &strategies {
                let cell = cells
                    .get(&(q.id.clone(), s.id()))
                    .ok_or_else(|| {
                        Error::internal(format!(
                            "matrix has no cell for ({}, {}) — incomplete collection?",
                            q.id,
                            s.id()
                        ))
                    })?;
                arow.push(cell.acc);
                trow.push(cell.tokens);
                lrow.push(cell.latency_ms);
            }
            acc.push(arow);
            tokens.push(trow);
            latency.push(lrow);
        }
        let cost_estimates = strategies
            .iter()
            .map(|s| costs.get(&s.id()))
            .collect::<Result<_>>()?;
        Ok(EvalTable {
            queries,
            strategies,
            acc,
            tokens,
            latency,
            probs,
            cost_estimates,
        })
    }

    pub fn n_queries(&self) -> usize {
        self.queries.len()
    }

    /// Mean (accuracy, tokens, latency) of always running strategy `s`.
    pub fn static_point(&self, s: usize) -> (f64, f64, f64) {
        let accs: Vec<f64> = self.acc.iter().map(|r| r[s]).collect();
        let toks: Vec<f64> = self.tokens.iter().map(|r| r[s]).collect();
        let lats: Vec<f64> = self.latency.iter().map(|r| r[s]).collect();
        (stats::mean(&accs), stats::mean(&toks), stats::mean(&lats))
    }

    /// Restrict to a strategy subset (e.g. beam-only for Fig 9).
    pub fn restrict(&self, keep: &[usize]) -> EvalTable {
        EvalTable {
            queries: self.queries.clone(),
            strategies: keep.iter().map(|&i| self.strategies[i].clone()).collect(),
            acc: self
                .acc
                .iter()
                .map(|r| keep.iter().map(|&i| r[i]).collect())
                .collect(),
            tokens: self
                .tokens
                .iter()
                .map(|r| keep.iter().map(|&i| r[i]).collect())
                .collect(),
            latency: self
                .latency
                .iter()
                .map(|r| keep.iter().map(|&i| r[i]).collect())
                .collect(),
            probs: self
                .probs
                .iter()
                .map(|r| keep.iter().map(|&i| r[i]).collect())
                .collect(),
            cost_estimates: keep.iter().map(|&i| self.cost_estimates[i]).collect(),
        }
    }
}

/// Which cost table the router consults (Figs 7/8 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostSource {
    /// Deployable: per-strategy train-split means.
    Model,
    /// Oracle: true per-(query, strategy) test costs.
    Oracle,
}

/// Run the adaptive policy over the table at one λ point.
/// Returns (mean acc, mean tokens, mean latency, selected strategy idx per query).
pub fn adaptive_point(
    table: &EvalTable,
    lambdas: crate::router::Lambdas,
    source: CostSource,
) -> (f64, f64, f64, Vec<usize>) {
    let mut accs = Vec::with_capacity(table.n_queries());
    let mut toks = Vec::with_capacity(table.n_queries());
    let mut lats = Vec::with_capacity(table.n_queries());
    let mut picks = Vec::with_capacity(table.n_queries());
    for q in 0..table.n_queries() {
        let costs: Vec<CostEstimate> = match source {
            CostSource::Model => table.cost_estimates.clone(),
            CostSource::Oracle => (0..table.strategies.len())
                .map(|s| CostEstimate {
                    tokens: table.tokens[q][s],
                    latency_ms: table.latency[q][s],
                })
                .collect(),
        };
        let s = crate::router::select_offline(&table.probs[q], &costs, lambdas);
        picks.push(s);
        accs.push(table.acc[q][s]);
        toks.push(table.tokens[q][s]);
        lats.push(table.latency[q][s]);
    }
    (
        stats::mean(&accs),
        stats::mean(&toks),
        stats::mean(&lats),
        picks,
    )
}

/// Minimal CSV writer (one file per figure panel).
pub struct Csv {
    lines: Vec<String>,
}

impl Csv {
    pub fn new(header: &str) -> Csv {
        Csv {
            lines: vec![header.to_string()],
        }
    }

    pub fn row(&mut self, fields: &[String]) {
        self.lines.push(fields.join(","));
    }

    pub fn rowf(&mut self, fields: std::fmt::Arguments<'_>) {
        self.lines.push(fields.to_string());
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        for line in &self.lines {
            writeln!(f, "{line}")?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.len() <= 1
    }
}

/// Build a synthetic EvalTable for tests (deterministic, difficulty-aware).
#[cfg(test)]
pub fn test_table() -> EvalTable {
    use crate::config::SpaceConfig;
    let strategies = Strategy::enumerate(&SpaceConfig::default());
    let mut queries = Vec::new();
    let mut acc = Vec::new();
    let mut tokens = Vec::new();
    let mut latency = Vec::new();
    let mut probs = Vec::new();
    for qi in 0..24 {
        let k = 2 + (qi % 6);
        queries.push(Query {
            id: format!("t-{qi}"),
            query: format!("Q:1+{qi}=?\n"),
            answer: "1".into(),
            k,
        });
        let hard = (k as f64 - 2.0) / 5.0; // 0..1
        let mut ar = Vec::new();
        let mut tr = Vec::new();
        let mut lr = Vec::new();
        for s in &strategies {
            // easy queries: parallel methods fine; hard: beam family better
            let base = 0.9 - 0.6 * hard;
            let n_bonus = 0.05 * (s.n as f64).log2();
            let beam_bonus = if s.uses_rounds() { 0.25 * hard } else { 0.0 };
            let a = (base + n_bonus + beam_bonus).clamp(0.05, 0.98);
            let t = if s.uses_rounds() {
                60.0 * s.n as f64 * s.width as f64
            } else {
                60.0 * s.n as f64
            };
            let l = if s.uses_rounds() {
                400.0 * 6.0 // sequential rounds
            } else {
                150.0 + 10.0 * (s.n as f64).log2()
            };
            ar.push(a);
            tr.push(t);
            lr.push(l);
        }
        // probe = truth + small bias (imperfect but informative)
        probs.push(ar.iter().map(|a| (a * 0.9 + 0.05).clamp(0.0, 1.0)).collect());
        acc.push(ar);
        tokens.push(tr);
        latency.push(lr);
    }
    let cost_estimates = (0..strategies.len())
        .map(|s| CostEstimate {
            tokens: stats::mean(&tokens.iter().map(|r| r[s]).collect::<Vec<_>>()),
            latency_ms: stats::mean(&latency.iter().map(|r| r[s]).collect::<Vec<_>>()),
        })
        .collect();
    EvalTable {
        queries,
        strategies,
        acc,
        tokens,
        latency,
        probs,
        cost_estimates,
    }
}

/// Lookup helper: strategy index groups by method name (for Figs 2/4).
/// Keyed by the registry id, so newly registered methods group with no
/// changes here.
pub fn indices_by_method(strategies: &[Strategy]) -> HashMap<&'static str, Vec<usize>> {
    let mut map: HashMap<&'static str, Vec<usize>> = HashMap::new();
    for (i, s) in strategies.iter().enumerate() {
        map.entry(s.method).or_default().push(i);
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Lambdas;

    #[test]
    fn adaptive_beats_or_matches_static_at_zero_penalty() {
        let table = test_table();
        let (acc, _, _, _) = adaptive_point(&table, Lambdas::new(0.0, 0.0), CostSource::Oracle);
        for s in 0..table.strategies.len() {
            let (sacc, _, _) = table.static_point(s);
            // probe is informative in the synthetic table; adaptive should
            // not lose to any static strategy by a meaningful margin
            assert!(
                acc >= sacc - 0.02,
                "adaptive {acc} < static {} ({})",
                sacc,
                table.strategies[s].id()
            );
        }
    }

    #[test]
    fn penalty_reduces_cost() {
        let table = test_table();
        let (_, t0, l0, _) = adaptive_point(&table, Lambdas::new(0.0, 0.0), CostSource::Model);
        let (_, t1, _, _) = adaptive_point(&table, Lambdas::new(1e-2, 0.0), CostSource::Model);
        let (_, _, l2, _) = adaptive_point(&table, Lambdas::new(0.0, 1e-2), CostSource::Model);
        assert!(t1 < t0, "token penalty must reduce tokens: {t1} vs {t0}");
        assert!(l2 < l0, "latency penalty must reduce latency: {l2} vs {l0}");
    }

    #[test]
    fn restrict_keeps_grid_consistent() {
        let table = test_table();
        let sub = table.restrict(&[0, 2, 5]);
        assert_eq!(sub.strategies.len(), 3);
        assert_eq!(sub.acc[0].len(), 3);
        assert_eq!(sub.acc[3][1], table.acc[3][2]);
        assert_eq!(sub.cost_estimates[2].tokens, table.cost_estimates[5].tokens);
    }
}
