//! Fig 9: query-adaptive test-time compute *within* beam search.
//!
//! The single-method setting of appendix A.5: the router selects beam
//! hyperparameters (beam size N, width W, chunk size C) per query,
//! compared against every static beam configuration on the
//! accuracy–token plane.

use crate::config::SweepConfig;
use crate::error::Result;
use crate::figures::{adaptive_point, CostSource, Csv, EvalTable};
use crate::router::Lambdas;
use std::path::Path;

/// Emits `fig9.csv`:
/// `series,label,lambda_t,accuracy,tokens,latency_ms` — static beam
/// configs (label = `(N,W,C)`) plus the adaptive λ_T frontier restricted
/// to the beam-only space.
pub fn fig9(table: &EvalTable, sweep: &SweepConfig, out: &Path) -> Result<Csv> {
    let beam_idx: Vec<usize> = table
        .strategies
        .iter()
        .enumerate()
        .filter(|(_, s)| s.uses_rounds())
        .map(|(i, _)| i)
        .collect();
    if beam_idx.is_empty() {
        return Err(crate::error::Error::Config(
            "fig9 needs beam-family strategies in the space".into(),
        ));
    }
    let beam_table = table.restrict(&beam_idx);

    let mut csv = Csv::new("series,label,lambda_t,accuracy,tokens,latency_ms");
    for (s, strat) in beam_table.strategies.iter().enumerate() {
        let (acc, toks, lats) = beam_table.static_point(s);
        csv.rowf(format_args!(
            "static,({} {} {}),0,{acc},{toks},{lats}",
            strat.n, strat.width, strat.chunk
        ));
    }
    for &lt in &sweep.lambda_t {
        let (acc, toks, lats, _) =
            adaptive_point(&beam_table, Lambdas::new(lt, 0.0), CostSource::Model);
        csv.rowf(format_args!("adaptive,lt={lt:e},{lt},{acc},{toks},{lats}"));
    }
    csv.write(out)?;
    Ok(csv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SweepConfig;
    use crate::figures::test_table;

    #[test]
    fn fig9_restricts_to_beam_space() {
        let table = test_table();
        let path = std::env::temp_dir().join(format!("ttc_fig9_{}.csv", std::process::id()));
        let csv = fig9(&table, &SweepConfig::default(), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let static_rows = text.lines().filter(|l| l.starts_with("static,")).count();
        let n_beam = table
            .strategies
            .iter()
            .filter(|s| s.uses_rounds())
            .count();
        assert_eq!(static_rows, n_beam);
        assert!(!csv.is_empty());
        std::fs::remove_file(&path).unwrap();
    }
}
