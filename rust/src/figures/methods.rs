//! Fig 4: per-method cost profile (tokens, latency, accuracy by method —
//! "beam search is the most accurate AND drastically more expensive").

use crate::error::Result;
use crate::figures::{indices_by_method, Csv, EvalTable};
use crate::strategies::registry;
use crate::util::stats;
use std::path::Path;

/// Emits `fig4.csv`:
/// `group,accuracy,tokens,latency_ms` — one row per strategy plus one
/// aggregated row per method family.
pub fn fig4(table: &EvalTable, out: &Path) -> Result<Csv> {
    let mut csv = Csv::new("group,accuracy,tokens,latency_ms");
    for (s, strat) in table.strategies.iter().enumerate() {
        let (acc, toks, lats) = table.static_point(s);
        csv.rowf(format_args!("{},{acc},{toks},{lats}", strat.id()));
    }
    let by_method = indices_by_method(&table.strategies);
    let mut methods: Vec<&'static str> = by_method.keys().copied().collect();
    methods.sort_by_key(|m| registry::feature_index(m).unwrap_or(usize::MAX));
    for m in methods {
        let idxs = &by_method[m];
        let points: Vec<(f64, f64, f64)> =
            idxs.iter().map(|&s| table.static_point(s)).collect();
        let acc = stats::mean(&points.iter().map(|p| p.0).collect::<Vec<_>>());
        let toks = stats::mean(&points.iter().map(|p| p.1).collect::<Vec<_>>());
        let lats = stats::mean(&points.iter().map(|p| p.2).collect::<Vec<_>>());
        csv.rowf(format_args!("method:{m},{acc},{toks},{lats}"));
    }
    csv.write(out)?;
    Ok(csv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::test_table;

    #[test]
    fn beam_is_most_expensive_in_synthetic_table() {
        let table = test_table();
        let path = std::env::temp_dir().join(format!("ttc_fig4_{}.csv", std::process::id()));
        fig4(&table, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let get = |name: &str| -> (f64, f64, f64) {
            let line = text
                .lines()
                .find(|l| l.starts_with(&format!("method:{name},")))
                .unwrap();
            let cols: Vec<&str> = line.split(',').collect();
            (
                cols[1].parse().unwrap(),
                cols[2].parse().unwrap(),
                cols[3].parse().unwrap(),
            )
        };
        let beam = get("beam");
        let mv = get("majority_vote");
        assert!(beam.2 > mv.2, "beam latency must dominate");
        std::fs::remove_file(&path).unwrap();
    }
}
