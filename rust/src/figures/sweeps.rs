//! λ-sweep figures: 1a/1b (accuracy–cost frontiers), 2 (selection
//! proportions), 5/6 (compact-embedding variants), 7/8 (predicted vs
//! oracle costs).

use crate::config::SweepConfig;
use crate::error::Result;
use crate::figures::{adaptive_point, indices_by_method, CostSource, Csv, EvalTable};
use crate::router::Lambdas;
use crate::strategies::registry;
use std::path::Path;

/// Figs 1a/1b (and 5/6 when given the compact-embedding table).
///
/// Emits `fig<id>.csv` with both the adaptive frontier and every static
/// strategy point:
/// `series,lambda_t,lambda_l,accuracy,tokens,latency_ms`
pub fn fig1(
    table: &EvalTable,
    sweep: &SweepConfig,
    panel: char, // 'a' (token sweep) or 'b' (latency sweep)
    out: &Path,
) -> Result<Csv> {
    let mut csv = Csv::new("series,lambda_t,lambda_l,accuracy,tokens,latency_ms");
    match panel {
        'a' => {
            for &ll in &sweep.fixed_lambda_l {
                for &lt in &sweep.lambda_t {
                    let (acc, toks, lats, _) =
                        adaptive_point(table, Lambdas::new(lt, ll), CostSource::Model);
                    csv.rowf(format_args!(
                        "adaptive_ll{ll:e},{lt},{ll},{acc},{toks},{lats}"
                    ));
                }
            }
        }
        'b' => {
            for &lt in &sweep.fixed_lambda_t {
                for &ll in &sweep.lambda_l {
                    let (acc, toks, lats, _) =
                        adaptive_point(table, Lambdas::new(lt, ll), CostSource::Model);
                    csv.rowf(format_args!(
                        "adaptive_lt{lt:e},{lt},{ll},{acc},{toks},{lats}"
                    ));
                }
            }
        }
        other => {
            return Err(crate::error::Error::Config(format!(
                "fig1 panel must be 'a' or 'b', got '{other}'"
            )))
        }
    }
    for (s, strat) in table.strategies.iter().enumerate() {
        let (acc, toks, lats) = table.static_point(s);
        csv.rowf(format_args!("static_{},0,0,{acc},{toks},{lats}", strat.id()));
    }
    csv.write(out)?;
    Ok(csv)
}

/// Fig 2: proportion of queries routed to each method (top row) and each
/// N (bottom row) as λ_L and λ_T grow.
///
/// Emits `fig2.csv`:
/// `sweep,lambda,group,proportion` where sweep ∈ {lambda_l, lambda_t}
/// and group is a method name or `N=<n>`.
pub fn fig2(table: &EvalTable, sweep: &SweepConfig, out: &Path) -> Result<Csv> {
    let mut csv = Csv::new("sweep,lambda,group,proportion");
    let by_method = indices_by_method(&table.strategies);
    let mut methods: Vec<&'static str> = by_method.keys().copied().collect();
    methods.sort_by_key(|m| registry::feature_index(m).unwrap_or(usize::MAX));
    let mut ns: Vec<usize> = table.strategies.iter().map(|s| s.n).collect();
    ns.sort();
    ns.dedup();

    let mut emit = |sweep_name: &str, lambda: f64, picks: &[usize]| {
        let n_q = picks.len() as f64;
        for m in &methods {
            let count = picks
                .iter()
                .filter(|&&s| table.strategies[s].method == *m)
                .count();
            csv.rowf(format_args!(
                "{sweep_name},{lambda},{m},{}",
                count as f64 / n_q
            ));
        }
        for &n in &ns {
            let count = picks.iter().filter(|&&s| table.strategies[s].n == n).count();
            csv.rowf(format_args!(
                "{sweep_name},{lambda},N={n},{}",
                count as f64 / n_q
            ));
        }
    };

    for &ll in &sweep.lambda_l {
        let (_, _, _, picks) = adaptive_point(table, Lambdas::new(0.0, ll), CostSource::Model);
        emit("lambda_l", ll, &picks);
    }
    for &lt in &sweep.lambda_t {
        let (_, _, _, picks) = adaptive_point(table, Lambdas::new(lt, 0.0), CostSource::Model);
        emit("lambda_t", lt, &picks);
    }
    csv.write(out)?;
    Ok(csv)
}

/// Figs 7/8: adaptive frontier with the deployable cost model vs the
/// per-query oracle costs.
///
/// Emits `fig<7|8>.csv`:
/// `series,lambda,accuracy,tokens,latency_ms`
pub fn fig78(
    table: &EvalTable,
    sweep: &SweepConfig,
    which: u8, // 7 = token costs, 8 = latency costs
    out: &Path,
) -> Result<Csv> {
    let mut csv = Csv::new("series,lambda,accuracy,tokens,latency_ms");
    let grid = if which == 7 {
        &sweep.lambda_t
    } else {
        &sweep.lambda_l
    };
    for &lam in grid {
        let lambdas = if which == 7 {
            Lambdas::new(lam, 0.0)
        } else {
            Lambdas::new(0.0, lam)
        };
        for (name, source) in [("predicted", CostSource::Model), ("oracle", CostSource::Oracle)] {
            let (acc, toks, lats, _) = adaptive_point(table, lambdas, source);
            csv.rowf(format_args!("{name},{lam},{acc},{toks},{lats}"));
        }
    }
    csv.write(out)?;
    Ok(csv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SweepConfig;
    use crate::figures::test_table;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ttc_fig_{}_{name}.csv", std::process::id()))
    }

    #[test]
    fn fig1a_has_adaptive_and_static_series() {
        let table = test_table();
        let sweep = SweepConfig::default();
        let path = tmp("1a");
        let csv = fig1(&table, &sweep, 'a', &path).unwrap();
        let expected =
            sweep.fixed_lambda_l.len() * sweep.lambda_t.len() + table.strategies.len() + 1;
        assert_eq!(csv.len(), expected);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fig1_rejects_bad_panel() {
        let table = test_table();
        assert!(fig1(&table, &SweepConfig::default(), 'x', &tmp("bad")).is_err());
    }

    #[test]
    fn fig2_proportions_sum_to_one_per_group_type() {
        let table = test_table();
        let sweep = SweepConfig::default();
        let path = tmp("2");
        let csv = fig2(&table, &sweep, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // for the first lambda_l value, method proportions sum to 1
        let first_lambda = sweep.lambda_l[0];
        let method_sum: f64 = text
            .lines()
            .skip(1)
            .filter(|l| l.starts_with(&format!("lambda_l,{first_lambda},")))
            .filter(|l| !l.contains(",N="))
            .map(|l| l.rsplit(',').next().unwrap().parse::<f64>().unwrap())
            .sum();
        assert!((method_sum - 1.0).abs() < 1e-9, "sum {method_sum}");
        assert!(!csv.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fig2_shifts_to_cheap_methods_at_high_lambda() {
        let table = test_table();
        let sweep = SweepConfig::default();
        // at the largest λ_T, beam share must not exceed its share at 0
        let (_, _, _, picks0) =
            adaptive_point(&table, Lambdas::new(0.0, 0.0), CostSource::Model);
        let big = *sweep.lambda_t.last().unwrap();
        let (_, _, _, picks1) =
            adaptive_point(&table, Lambdas::new(big, 0.0), CostSource::Model);
        let beam_share = |picks: &[usize]| {
            picks
                .iter()
                .filter(|&&s| table.strategies[s].uses_rounds())
                .count()
        };
        assert!(beam_share(&picks1) <= beam_share(&picks0));
    }

    #[test]
    fn fig78_series_close_when_probe_is_shared() {
        let table = test_table();
        let sweep = SweepConfig::default();
        let path = tmp("7");
        fig78(&table, &sweep, 7, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() > sweep.lambda_t.len());
        std::fs::remove_file(&path).unwrap();
    }
}
