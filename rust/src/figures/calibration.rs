//! Fig 3: probe reliability diagram (predicted vs empirical accuracy).

use crate::error::Result;
use crate::figures::Csv;
use crate::util::stats;
use std::path::Path;

/// Binned calibration data from (predicted prob, empirical soft label)
/// pairs on the calibration split.
///
/// Emits `fig3.csv`: `bin_lo,bin_hi,mean_predicted,mean_empirical,count`
/// plus a trailing `# ece,<value>` comment row consumed by SUMMARY.md.
pub fn fig3(pairs: &[(f64, f64)], bins: usize, out: &Path) -> Result<(Csv, f64)> {
    let mut csv = Csv::new("bin_lo,bin_hi,mean_predicted,mean_empirical,count");
    let mut grouped: Vec<(Vec<f64>, Vec<f64>)> = vec![(Vec::new(), Vec::new()); bins];
    for &(p, y) in pairs {
        let b = ((p * bins as f64) as usize).min(bins - 1);
        grouped[b].0.push(p);
        grouped[b].1.push(y);
    }
    for (b, (ps, ys)) in grouped.iter().enumerate() {
        if ps.is_empty() {
            continue;
        }
        csv.rowf(format_args!(
            "{},{},{},{},{}",
            b as f64 / bins as f64,
            (b + 1) as f64 / bins as f64,
            stats::mean(ps),
            stats::mean(ys),
            ps.len()
        ));
    }
    let ece = stats::ece(pairs, bins);
    csv.rowf(format_args!("# ece,{ece}"));
    csv.write(out)?;
    Ok((csv, ece))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn well_calibrated_bins_lie_on_diagonal() {
        let pairs: Vec<(f64, f64)> = (0..1000)
            .map(|i| {
                let p = i as f64 / 1000.0;
                (p, p) // perfect calibration
            })
            .collect();
        let path = std::env::temp_dir().join(format!("ttc_fig3_{}.csv", std::process::id()));
        let (_, ece) = fig3(&pairs, 10, &path).unwrap();
        assert!(ece < 0.03, "ece {ece}");
        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines().skip(1).filter(|l| !l.starts_with('#')) {
            let cols: Vec<f64> = line.split(',').map(|c| c.parse().unwrap()).collect();
            assert!((cols[2] - cols[3]).abs() < 0.06, "{line}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn miscalibrated_has_high_ece() {
        let pairs: Vec<(f64, f64)> = (0..1000)
            .map(|i| (i as f64 / 1000.0, 0.2))
            .collect();
        let path = std::env::temp_dir().join(format!("ttc_fig3b_{}.csv", std::process::id()));
        let (_, ece) = fig3(&pairs, 10, &path).unwrap();
        assert!(ece > 0.15, "ece {ece}");
        std::fs::remove_file(&path).unwrap();
    }
}
