//! Process-reward-model scoring client.
//!
//! Thin convenience layer over the engine's batched `prm_score` entry
//! point: builds `query + partial-solution` prefixes, enforces the PRM
//! length bucket, and memoizes scores within a request (beam search
//! re-scores surviving beams every round; identical prefixes hit the
//! cache instead of the engine). Engine-side, concurrent workers'
//! scoring requests coalesce into shared bucket-shaped calls
//! ([`crate::engine::scheduler`]), so cache misses here still amortize
//! across the fleet.

use crate::engine::EngineHandle;
use crate::error::Result;
use crate::tokenizer::Tokenizer;
use std::collections::HashMap;

/// Request-scoped PRM scorer with memoization.
pub struct PrmClient<'a> {
    engine: &'a EngineHandle,
    tokenizer: &'a Tokenizer,
    cache: HashMap<String, f32>,
    /// Engine calls actually issued (diagnostic).
    pub calls: usize,
    /// Cache hits (diagnostic).
    pub hits: usize,
}

impl<'a> PrmClient<'a> {
    pub fn new(engine: &'a EngineHandle, tokenizer: &'a Tokenizer) -> PrmClient<'a> {
        PrmClient {
            engine,
            tokenizer,
            cache: HashMap::new(),
            calls: 0,
            hits: 0,
        }
    }

    /// Score `query + text` prefixes; one score per text, cache-aware.
    pub fn score(&mut self, query: &str, texts: &[String]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; texts.len()];
        let mut todo_idx = Vec::new();
        let mut todo_tokens = Vec::new();
        for (i, t) in texts.iter().enumerate() {
            let full = format!("{query}{t}");
            if let Some(&s) = self.cache.get(&full) {
                out[i] = s;
                self.hits += 1;
            } else {
                todo_tokens.push(self.tokenizer.encode(&full)?);
                todo_idx.push(i);
            }
        }
        if !todo_idx.is_empty() {
            let scores = self.engine.prm_score(todo_tokens)?;
            self.calls += 1;
            for (&i, s) in todo_idx.iter().zip(scores) {
                out[i] = s;
                self.cache
                    .insert(format!("{query}{}", texts[i]), s);
            }
        }
        Ok(out)
    }
}
