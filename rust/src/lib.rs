//! # ttc-router — Latency and Token-Aware Test-Time Compute
//!
//! Reproduction of *"Latency and Token-Aware Test-Time Compute"* (Huang,
//! Damani, El-Kurdi, Astudillo, Sun; 2025) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the serving coordinator: a utility-maximizing
//!   router that selects, per query, an inference-scaling strategy
//!   (majority voting, best-of-N, beam search) and its hyperparameters,
//!   trading accuracy against *both* token cost and wall-clock latency;
//!   plus the continuous-batching engine, KV-cache manager, PRM scoring
//!   client, probe trainer and the full experiment harness.
//! * **L2 (python/compile, build time)** — the transformer generator, the
//!   process-reward model, query embedders and the probe MLP, written in
//!   JAX and AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels for the
//!   compute hot-spots (tiled causal attention, fused MLP, layernorm).
//!
//! Python never runs on the request path: `make artifacts` trains the
//! models and lowers every entry point; the rust binary then loads the
//! HLO artifacts through PJRT (`runtime`) and serves requests.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | hand-rolled substrates: JSON, RNG, clocks, logging |
//! | [`tokenizer`] | char-level tokenizer shared with the python side |
//! | [`taskgen`] | synthetic modular-arithmetic CoT task generator |
//! | [`data`] | JSONL dataset IO and splits |
//! | [`runtime`] | PJRT executable loading, weights, literal helpers |
//! | [`engine`] | backend-driven engine threads (device/sim), sharded pool, continuous batcher, scheduler |
//! | [`strategies`] | majority voting, best-of-N, beam search |
//! | [`probe`] | accuracy probe: features, training, Platt calibration |
//! | [`costmodel`] | per-strategy token/latency cost estimators |
//! | [`router`] | the paper's utility `U_s(x)` and strategy selection |
//! | [`matrix`] | evaluation-matrix collection and caching |
//! | [`figures`] | regeneration of every figure in the paper |
//! | [`server`] | serving driver and load generator |
//! | [`net`] | remote engine tier: wire protocol, engine servers, remote backend |
//! | [`eval`] | answer extraction, exact match, vote aggregation |
//! | [`metrics`] | counters and latency histograms |
//! | [`testkit`] | miniature property-testing framework |

pub mod cli;
pub mod config;
pub mod costmodel;
pub mod data;
pub mod engine;
pub mod error;
pub mod eval;
pub mod figures;
pub mod matrix;
pub mod metrics;
pub mod net;
pub mod probe;
pub mod router;
pub mod runtime;
pub mod server;
pub mod strategies;
pub mod taskgen;
pub mod testkit;
pub mod tokenizer;
pub mod util;

pub use error::{Error, Result};
