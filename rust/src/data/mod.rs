//! Dataset records and JSONL IO.

use crate::error::{Error, Result};
use crate::util::json::{parse, Value};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// One evaluation query (a math problem without its solution).
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    pub id: String,
    /// Full prompt text, e.g. `Q:7+8-2=?\n` (newline included).
    pub query: String,
    /// Ground-truth final answer as its surface string, e.g. `30`.
    pub answer: String,
    /// Difficulty (number of CoT steps).
    pub k: usize,
}

impl Query {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("id", self.id.as_str())
            .with("query", self.query.as_str())
            .with("answer", self.answer.as_str())
            .with("k", self.k)
    }

    pub fn from_json(v: &Value) -> Result<Query> {
        Ok(Query {
            id: v.req_str("id")?.to_string(),
            query: v.req_str("query")?.to_string(),
            answer: v.req_str("answer")?.to_string(),
            k: v.req_usize("k")?,
        })
    }
}

/// Read a whole JSONL file into values. Blank lines are skipped.
pub fn read_jsonl(path: &Path) -> Result<Vec<Value>> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::artifact(format!("cannot open {}: {e}", path.display())))?;
    let reader = BufReader::new(file);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(&line)
            .map_err(|e| Error::Json(format!("{}:{}: {e}", path.display(), lineno + 1)))?;
        out.push(v);
    }
    Ok(out)
}

/// Write values as JSONL (one compact document per line).
pub fn write_jsonl(path: &Path, values: &[Value]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for v in values {
        f.write_all(v.dumps().as_bytes())?;
        f.write_all(b"\n")?;
    }
    f.flush()?;
    Ok(())
}

/// Append values to an existing JSONL file (creates it if missing).
pub fn append_jsonl(path: &Path, values: &[Value]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::OpenOptions::new().create(true).append(true).open(path)?,
    );
    for v in values {
        f.write_all(v.dumps().as_bytes())?;
        f.write_all(b"\n")?;
    }
    f.flush()?;
    Ok(())
}

/// Load a query split file (`queries_*.jsonl`).
pub fn load_queries(path: &Path) -> Result<Vec<Query>> {
    read_jsonl(path)?.iter().map(Query::from_json).collect()
}

/// The three standard splits, loaded from a data directory.
#[derive(Debug, Clone)]
pub struct Splits {
    pub train: Vec<Query>,
    pub calib: Vec<Query>,
    pub test: Vec<Query>,
}

impl Splits {
    pub fn load(data_dir: &Path) -> Result<Splits> {
        Ok(Splits {
            train: load_queries(&data_dir.join("queries_train.jsonl"))?,
            calib: load_queries(&data_dir.join("queries_calib.jsonl"))?,
            test: load_queries(&data_dir.join("queries_test.jsonl"))?,
        })
    }

    /// Difficulty-balanced in-memory splits, for paths that can run
    /// without artifacts (the sim execution backend): same problem
    /// distribution as `ttc taskgen`, independent RNG streams per split,
    /// no filesystem involved.
    pub fn synthesize(seed: u64) -> Splits {
        let make = |stream: u64, n: usize, tag: &str| -> Vec<Query> {
            let mut rng = crate::util::rng::Rng::new(seed, stream);
            (0..n)
                .map(|i| {
                    let k = crate::taskgen::arith::MIN_OPS
                        + (i % (crate::taskgen::arith::MAX_OPS - crate::taskgen::arith::MIN_OPS + 1));
                    let p = crate::taskgen::Problem::sample(&mut rng, k);
                    Query {
                        id: format!("sim_{tag}_{i}"),
                        query: p.query_text(),
                        answer: p.answer().to_string(),
                        k,
                    }
                })
                .collect()
        };
        Splits {
            train: make(0x517_1, 120, "train"),
            calib: make(0x517_2, 60, "calib"),
            test: make(0x517_3, 160, "test"),
        }
    }

    pub fn by_name(&self, name: &str) -> Result<&[Query]> {
        match name {
            "train" => Ok(&self.train),
            "calib" => Ok(&self.calib),
            "test" => Ok(&self.test),
            other => Err(Error::Config(format!("unknown split '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_json_roundtrip() {
        let q = Query {
            id: "t-1".into(),
            query: "Q:1+2=?\n".into(),
            answer: "3".into(),
            k: 2,
        };
        let v = q.to_json();
        assert_eq!(Query::from_json(&v).unwrap(), q);
    }

    #[test]
    fn jsonl_roundtrip() {
        let path = std::env::temp_dir().join(format!("ttc_jsonl_{}.jsonl", std::process::id()));
        let values = vec![
            Value::obj().with("a", 1.0),
            Value::obj().with("b", "x"),
        ];
        write_jsonl(&path, &values).unwrap();
        append_jsonl(&path, &[Value::obj().with("c", true)]).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0], values[0]);
        assert_eq!(back[2].opt_bool("c", false), true);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn synthesized_splits_are_deterministic_and_balanced() {
        let a = Splits::synthesize(7);
        let b = Splits::synthesize(7);
        assert_eq!(a.test, b.test);
        assert_eq!(a.test.len(), 160);
        assert!(!a.train.is_empty() && !a.calib.is_empty());
        // answers are ground truth for their queries and ids are unique
        let mut ids = std::collections::HashSet::new();
        for q in a.test.iter().chain(&a.train).chain(&a.calib) {
            assert!(ids.insert(q.id.clone()), "duplicate id {}", q.id);
            assert!(q.query.starts_with("Q:") && q.query.ends_with("=?\n"));
            assert!(q.answer.chars().all(|c| c.is_ascii_digit()));
        }
        assert_ne!(Splits::synthesize(8).test, a.test);
    }

    #[test]
    fn read_jsonl_reports_line_numbers() {
        let path = std::env::temp_dir().join(format!("ttc_bad_{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"a\":1}\nnot json\n").unwrap();
        let err = read_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains(":2:"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }
}
