//! Counters and latency histograms for the serving path.

use crate::util::json::Value;
use crate::util::stats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotone counter, safe to share across threads.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc(&self) {
        self.add(1)
    }
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
    /// Raise the counter to `n` if `n` is larger than the current value.
    /// Used for high-water marks (e.g. peak in-flight calls on a
    /// multiplexed connection) rather than monotone accumulation.
    pub fn record_max(&self, n: u64) {
        self.value.fetch_max(n, Ordering::Relaxed);
    }
}

/// Latency histogram: keeps raw samples (bounded) for exact percentiles.
/// At the scale of this testbed (≤ 10⁵ requests) raw retention is cheaper
/// and more precise than bucketing.
#[derive(Debug)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
    cap: usize,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            samples: Mutex::new(Vec::new()),
            cap: 1 << 20,
        }
    }

    pub fn record(&self, v: f64) {
        let mut s = self.samples.lock().unwrap();
        if s.len() < self.cap {
            s.push(v);
        }
    }

    pub fn count(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn summary(&self) -> HistSummary {
        let s = self.samples.lock().unwrap();
        HistSummary {
            count: s.len(),
            mean: stats::mean(&s),
            p50: stats::percentile(&s, 50.0),
            p95: stats::percentile(&s, 95.0),
            p99: stats::percentile(&s, 99.0),
            max: s.iter().cloned().fold(0.0, f64::max),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Summary statistics of a histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistSummary {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("count", self.count)
            .with("mean", self.mean)
            .with("p50", self.p50)
            .with("p95", self.p95)
            .with("p99", self.p99)
            .with("max", self.max)
    }
}

/// Engine-level metrics bundle shared across coordinator threads.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    /// Total decode steps executed (batched calls).
    pub decode_calls: Counter,
    /// Total sequence-steps (sum of batch sizes over decode calls).
    pub decode_rows: Counter,
    /// Padded-but-unused rows (batching waste).
    pub padded_rows: Counter,
    /// Prefill calls.
    pub prefill_calls: Counter,
    /// PRM scoring calls.
    pub prm_calls: Counter,
    /// Real (non-padding) rows scored by the PRM.
    pub prm_rows: Counter,
    /// Padded-but-unused rows in PRM scoring calls.
    pub prm_padded_rows: Counter,
    /// Embedding calls.
    pub embed_calls: Counter,
    /// Real (non-padding) rows embedded.
    pub embed_rows: Counter,
    /// Padded-but-unused rows in embedding calls.
    pub embed_padded_rows: Counter,
    /// Scheduling rounds served by the engine loop.
    pub sched_rounds: Counter,
    /// Messages that were drained behind a round's first message (any
    /// op) — the raw coalescing opportunity the scheduler captured.
    pub coalesced_msgs: Counter,
    /// Generate requests merged into a shared batching round, beyond
    /// each round's first.
    pub coalesced_generates: Counter,
    /// PRM scoring requests merged into shared device calls, beyond
    /// each round's first.
    pub coalesced_prm: Counter,
    /// Embed requests merged into shared device calls, beyond each
    /// round's first.
    pub coalesced_embeds: Counter,
    /// Rows halted mid-call by deadline, cancel flag, or token cap.
    pub preempted_rows: Counter,
    /// Tokens generated (actual, not padded).
    pub tokens_generated: Counter,
    /// Slot-steps actually occupied by an emitting row on the continuous
    /// decode path (numerator of the occupancy fraction; the denominator
    /// is `slot_steps_total`).
    pub slot_steps_occupied: Counter,
    /// Slot-steps offered by continuous decode sessions (bucket ×
    /// charged steps).
    pub slot_steps_total: Counter,
    /// Decode steps the backend genuinely did *not* execute because a
    /// row was retired live (deadline / cancel / stop flag) before its
    /// natural end — real compute saved, distinct from the cache tier's
    /// zero-charge replays.
    pub decode_steps_saved_live: Counter,
    /// Generate jobs admitted into a free slot of an already-decoding
    /// session instead of waiting for the next scheduling round.
    pub mid_decode_admits: Counter,
    /// Rows retired live between decode steps (finished, deadline
    /// expired, cancelled or stop-flagged) — their slots freed while the
    /// session kept decoding.
    pub retired_rows: Counter,
    /// Wall-time per batched decode call (ms).
    pub decode_latency: Histogram,
    /// End-to-end per-request latency (ms).
    pub request_latency: Histogram,
}

impl EngineMetrics {
    pub fn new() -> EngineMetrics {
        EngineMetrics::default()
    }

    /// Real (non-padding) rows this engine executed across every op —
    /// the "rows served" quantity pool balance stats are computed over.
    /// Keep this the single definition so the pool report and the bench
    /// stat can never disagree.
    pub fn rows_served(&self) -> u64 {
        self.decode_rows.get() + self.prm_rows.get() + self.embed_rows.get()
    }

    /// Fraction of batch rows that were padding.
    pub fn padding_waste(&self) -> f64 {
        Self::waste(self.decode_rows.get(), self.padded_rows.get())
    }

    /// Fraction of PRM scoring rows that were padding.
    pub fn prm_padding_waste(&self) -> f64 {
        Self::waste(self.prm_rows.get(), self.prm_padded_rows.get())
    }

    /// Fraction of embedding rows that were padding.
    pub fn embed_padding_waste(&self) -> f64 {
        Self::waste(self.embed_rows.get(), self.embed_padded_rows.get())
    }

    fn waste(rows: u64, padded: u64) -> f64 {
        if rows + padded == 0 {
            0.0
        } else {
            padded as f64 / (rows + padded) as f64
        }
    }

    /// Fraction of continuous-decode slot-steps occupied by an emitting
    /// row (0 when the continuous path never ran).
    pub fn slot_occupancy(&self) -> f64 {
        let total = self.slot_steps_total.get();
        if total == 0 {
            0.0
        } else {
            self.slot_steps_occupied.get() as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("decode_calls", self.decode_calls.get())
            .with("decode_rows", self.decode_rows.get())
            .with("padded_rows", self.padded_rows.get())
            .with("padding_waste", self.padding_waste())
            .with("prefill_calls", self.prefill_calls.get())
            .with("prm_calls", self.prm_calls.get())
            .with("prm_rows", self.prm_rows.get())
            .with("prm_padded_rows", self.prm_padded_rows.get())
            .with("prm_padding_waste", self.prm_padding_waste())
            .with("embed_calls", self.embed_calls.get())
            .with("embed_rows", self.embed_rows.get())
            .with("embed_padded_rows", self.embed_padded_rows.get())
            .with("embed_padding_waste", self.embed_padding_waste())
            .with("sched_rounds", self.sched_rounds.get())
            .with("coalesced_msgs", self.coalesced_msgs.get())
            .with("coalesced_generates", self.coalesced_generates.get())
            .with("coalesced_prm", self.coalesced_prm.get())
            .with("coalesced_embeds", self.coalesced_embeds.get())
            .with("preempted_rows", self.preempted_rows.get())
            .with("tokens_generated", self.tokens_generated.get())
            .with("slot_steps_occupied", self.slot_steps_occupied.get())
            .with("slot_steps_total", self.slot_steps_total.get())
            .with("slot_occupancy", self.slot_occupancy())
            .with("decode_steps_saved_live", self.decode_steps_saved_live.get())
            .with("mid_decode_admits", self.mid_decode_admits.get())
            .with("retired_rows", self.retired_rows.get())
            .with("decode_latency_ms", self.decode_latency.summary().to_json())
            .with(
                "request_latency_ms",
                self.request_latency.summary().to_json(),
            )
    }
}

/// Counters for the cross-request cache tier
/// ([`crate::engine::cache::EngineCache`]): lookup outcomes, LRU
/// evictions, probe-swap invalidations, and the decode work that cache
/// replays avoided. One bundle per cache (shared by every engine of a
/// pool), surfaced in engine `info()`, the pool report and the serve
/// report.
#[derive(Debug, Default)]
pub struct CacheMetrics {
    /// Lookups served from the cache — exact generation hits, score
    /// hits, and intra-round duplicates that rode a leader's call.
    pub hits: Counter,
    /// Lookups that went to the backend (and seeded an insert).
    pub misses: Counter,
    /// Entries dropped by per-shard LRU eviction.
    pub evictions: Counter,
    /// Probe-swap invalidations (`probe_load` / `probe_train`).
    pub invalidations: Counter,
    /// Decode steps the engine did *not* execute because a generation
    /// row replayed from the cache (the per-row emitted lengths; the
    /// clock is never charged for these).
    pub decode_steps_saved: Counter,
}

impl CacheMetrics {
    pub fn new() -> CacheMetrics {
        CacheMetrics::default()
    }

    /// hits / (hits + misses); 0 before any lookup.
    pub fn hit_fraction(&self) -> f64 {
        let (h, m) = (self.hits.get(), self.misses.get());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("hits", self.hits.get())
            .with("misses", self.misses.get())
            .with("hit_fraction", self.hit_fraction())
            .with("evictions", self.evictions.get())
            .with("invalidations", self.invalidations.get())
            .with("decode_steps_saved", self.decode_steps_saved.get())
    }
}

/// Per-engine routing counters inside a [`PoolMetrics`].
#[derive(Debug, Default)]
pub struct PoolEngineMetrics {
    /// Submissions placed on this engine.
    pub submits: Counter,
    /// Rows (jobs/prefixes/queries/feature rows) placed on this engine.
    pub rows_submitted: Counter,
    /// Rows whose replies were harvested (or dropped) by the requester.
    pub rows_completed: Counter,
    /// Submissions this engine refused (its thread was gone); each one
    /// was re-placed on a live engine or failed the request.
    pub rejected_submits: Counter,
}

impl PoolEngineMetrics {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("submits", self.submits.get())
            .with("rows_submitted", self.rows_submitted.get())
            .with("rows_completed", self.rows_completed.get())
            .with("rejected_submits", self.rejected_submits.get())
    }
}

/// Placement metrics for the sharded engine pool
/// ([`crate::engine::pool::EnginePool`]): how many submissions were
/// placed, how often the deadline-aware tiebreak decided, and per-engine
/// submission/row counters. Per-engine *execution* metrics stay on each
/// engine's own [`EngineMetrics`].
#[derive(Debug)]
pub struct PoolMetrics {
    /// Accounted submissions routed through the placement policy.
    pub placements: Counter,
    /// Placements where the EDF tiebreak picked a different engine than
    /// plain least-loaded would have.
    pub deadline_tiebreaks: Counter,
    /// Submissions (or in-flight replies) rescued from a dead engine by
    /// re-placing them on a live one.
    pub rerouted_submits: Counter,
    /// Engines declared dead by the health tracker (each counted once).
    pub engines_marked_dead: Counter,
    per_engine: Vec<PoolEngineMetrics>,
}

impl PoolMetrics {
    pub fn new(engines: usize) -> PoolMetrics {
        PoolMetrics {
            placements: Counter::new(),
            deadline_tiebreaks: Counter::new(),
            rerouted_submits: Counter::new(),
            engines_marked_dead: Counter::new(),
            per_engine: (0..engines).map(|_| PoolEngineMetrics::default()).collect(),
        }
    }

    pub fn engines(&self) -> usize {
        self.per_engine.len()
    }

    pub fn engine(&self, i: usize) -> &PoolEngineMetrics {
        &self.per_engine[i]
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("placements", self.placements.get())
            .with("deadline_tiebreaks", self.deadline_tiebreaks.get())
            .with("rerouted_submits", self.rerouted_submits.get())
            .with("engines_marked_dead", self.engines_marked_dead.get())
            .with(
                "per_engine",
                Value::Arr(self.per_engine.iter().map(|m| m.to_json()).collect()),
            )
    }
}

/// Counters for the continuation executor
/// ([`crate::strategies::stepper::Stepper`]): how many step machines it
/// multiplexed, how much engine work it submitted, and what the
/// mid-flight budget reallocation hook granted.
#[derive(Debug, Default)]
pub struct StepperMetrics {
    /// Step machines admitted (requests entering the stepper).
    pub machines_admitted: Counter,
    /// Step machines that yielded `Done`.
    pub machines_completed: Counter,
    /// Individual `StrategyState::step` calls.
    pub steps: Counter,
    /// Engine submissions (generate + PRM) issued on behalf of machines.
    pub engine_submits: Counter,
    /// Finished requests whose leftover budget produced at least one
    /// grant to a still-running machine.
    pub realloc_events: Counter,
    /// Individual grants applied to running machines.
    pub realloc_grants: Counter,
    /// Deadline budget granted, microseconds (stored integral so the
    /// counter stays atomic; read via [`StepperMetrics::realloc_ms_granted`]).
    pub realloc_us_granted: Counter,
    /// Token budget granted to running machines.
    pub realloc_tokens_granted: Counter,
}

impl StepperMetrics {
    pub fn new() -> StepperMetrics {
        StepperMetrics::default()
    }

    /// Total deadline extension granted, in milliseconds.
    pub fn realloc_ms_granted(&self) -> f64 {
        self.realloc_us_granted.get() as f64 / 1e3
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("machines_admitted", self.machines_admitted.get())
            .with("machines_completed", self.machines_completed.get())
            .with("steps", self.steps.get())
            .with("engine_submits", self.engine_submits.get())
            .with("realloc_events", self.realloc_events.get())
            .with("realloc_grants", self.realloc_grants.get())
            .with("realloc_ms_granted", self.realloc_ms_granted())
            .with("realloc_tokens_granted", self.realloc_tokens_granted.get())
    }
}

/// Counters for the agentic chain tier
/// ([`crate::server::chain`]): session progress, cross-step budget
/// banking, and chain goodput — the fraction of finished chains that
/// were fully correct AND under their chain SLO. Surfaced as the
/// `chain` section of the serve report, next to `stepper`/`pool`.
#[derive(Debug, Default)]
pub struct ChainMetrics {
    /// Chains whose first step was admitted.
    pub chains_admitted: Counter,
    /// Chains that ran every configured step.
    pub chains_completed: Counter,
    /// Chains cut short by their chain-level budget (partial steps).
    pub chains_exhausted: Counter,
    /// Individual chain steps completed.
    pub steps_completed: Counter,
    /// Fully-correct-and-under-SLO chains (the goodput numerator).
    pub goodput_ok: Counter,
    /// Budget slices that exceeded their frozen nominal share — one
    /// early cheap step buying a later step a wider slice.
    pub realloc_grants: Counter,
    /// Deadline headroom granted beyond nominal shares, microseconds
    /// (integral so the counter stays atomic; read via
    /// [`ChainMetrics::realloc_ms_granted`]).
    pub realloc_us_granted: Counter,
    /// Tokens granted beyond nominal shares.
    pub realloc_tokens_granted: Counter,
    /// Per-chain end-to-end latency (arrival → last step), ms.
    pub e2e: Histogram,
}

impl ChainMetrics {
    pub fn new() -> ChainMetrics {
        ChainMetrics::default()
    }

    /// Chains that reached a terminal state (all steps or exhausted).
    pub fn chains_finished(&self) -> u64 {
        self.chains_completed.get() + self.chains_exhausted.get()
    }

    /// goodput = fully correct AND under SLO, over finished chains
    /// (0 before any chain finishes).
    pub fn goodput(&self) -> f64 {
        let n = self.chains_finished();
        if n == 0 {
            0.0
        } else {
            self.goodput_ok.get() as f64 / n as f64
        }
    }

    /// Total deadline headroom granted across steps, in milliseconds.
    pub fn realloc_ms_granted(&self) -> f64 {
        self.realloc_us_granted.get() as f64 / 1e3
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .with("chains_admitted", self.chains_admitted.get())
            .with("chains_completed", self.chains_completed.get())
            .with("chains_exhausted", self.chains_exhausted.get())
            .with("steps_completed", self.steps_completed.get())
            .with("goodput_ok", self.goodput_ok.get())
            .with("goodput", self.goodput())
            .with("realloc_grants", self.realloc_grants.get())
            .with("realloc_ms_granted", self.realloc_ms_granted())
            .with("realloc_tokens_granted", self.realloc_tokens_granted.get())
            .with("e2e_ms", self.e2e.summary().to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn histogram_summary() {
        let h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1.0);
        assert!(s.p99 >= 98.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn chain_metrics_goodput() {
        let m = ChainMetrics::new();
        assert_eq!(m.goodput(), 0.0); // nothing finished yet
        m.chains_admitted.add(4);
        m.chains_completed.add(3);
        m.chains_exhausted.inc();
        m.goodput_ok.add(2);
        m.steps_completed.add(9);
        m.realloc_grants.add(5);
        m.realloc_us_granted.add(1500);
        m.realloc_tokens_granted.add(40);
        m.e2e.record(120.0);
        assert_eq!(m.chains_finished(), 4);
        assert!((m.goodput() - 0.5).abs() < 1e-12);
        let v = m.to_json();
        assert!((v.req_f64("goodput").unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(v.req_f64("realloc_grants").unwrap(), 5.0);
        assert!((v.req_f64("realloc_ms_granted").unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(
            v.req("e2e_ms").unwrap().req_f64("count").unwrap(),
            1.0
        );
    }

    #[test]
    fn stepper_metrics_ms_conversion() {
        let m = StepperMetrics::new();
        m.realloc_us_granted.add(2500);
        assert!((m.realloc_ms_granted() - 2.5).abs() < 1e-12);
        let v = m.to_json();
        assert!((v.req_f64("realloc_ms_granted").unwrap() - 2.5).abs() < 1e-12);
        assert_eq!(v.req_f64("realloc_grants").unwrap(), 0.0);
    }

    #[test]
    fn pool_metrics_per_engine_counters() {
        let m = PoolMetrics::new(2);
        assert_eq!(m.engines(), 2);
        m.placements.inc();
        m.engine(1).submits.inc();
        m.engine(1).rows_submitted.add(8);
        m.engine(1).rows_completed.add(8);
        m.engine(0).rejected_submits.inc();
        m.rerouted_submits.inc();
        m.engines_marked_dead.inc();
        let v = m.to_json();
        assert_eq!(v.req_f64("placements").unwrap(), 1.0);
        assert_eq!(v.req_f64("rerouted_submits").unwrap(), 1.0);
        assert_eq!(v.req_f64("engines_marked_dead").unwrap(), 1.0);
        let per = v.req_arr("per_engine").unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[1].req_f64("rows_submitted").unwrap(), 8.0);
        assert_eq!(per[0].req_f64("submits").unwrap(), 0.0);
        assert_eq!(per[0].req_f64("rejected_submits").unwrap(), 1.0);
    }

    #[test]
    fn cache_metrics_hit_fraction() {
        let m = CacheMetrics::new();
        assert_eq!(m.hit_fraction(), 0.0); // no lookups yet
        m.hits.add(3);
        m.misses.add(1);
        m.decode_steps_saved.add(12);
        assert!((m.hit_fraction() - 0.75).abs() < 1e-12);
        let v = m.to_json();
        assert_eq!(v.req_f64("hits").unwrap(), 3.0);
        assert!((v.req_f64("hit_fraction").unwrap() - 0.75).abs() < 1e-12);
        assert_eq!(v.req_f64("decode_steps_saved").unwrap(), 12.0);
    }

    #[test]
    fn slot_occupancy_fraction() {
        let m = EngineMetrics::new();
        assert_eq!(m.slot_occupancy(), 0.0); // continuous path never ran
        m.slot_steps_occupied.add(3);
        m.slot_steps_total.add(4);
        assert!((m.slot_occupancy() - 0.75).abs() < 1e-12);
        m.decode_steps_saved_live.add(7);
        m.mid_decode_admits.add(2);
        m.retired_rows.add(5);
        let v = m.to_json();
        assert!((v.req_f64("slot_occupancy").unwrap() - 0.75).abs() < 1e-12);
        assert_eq!(v.req_f64("decode_steps_saved_live").unwrap(), 7.0);
        assert_eq!(v.req_f64("mid_decode_admits").unwrap(), 2.0);
        assert_eq!(v.req_f64("retired_rows").unwrap(), 5.0);
    }

    #[test]
    fn padding_waste() {
        let m = EngineMetrics::new();
        m.decode_rows.add(75);
        m.padded_rows.add(25);
        assert!((m.padding_waste() - 0.25).abs() < 1e-12);
        assert_eq!(m.prm_padding_waste(), 0.0); // no rows yet
        m.prm_rows.add(6);
        m.prm_padded_rows.add(2);
        assert!((m.prm_padding_waste() - 0.25).abs() < 1e-12);
        m.embed_rows.add(9);
        m.embed_padded_rows.add(3);
        assert!((m.embed_padding_waste() - 0.25).abs() < 1e-12);
    }
}
