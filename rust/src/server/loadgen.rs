//! Load generation for the serving driver: open-loop Poisson arrivals
//! (the standard serving-benchmark model), bursty Gamma / on-off
//! processes (trace-like burstiness without a trace file), or
//! closed-loop back-to-back. Each request carries a per-request
//! [`Budget`] that the decoding method enforces mid-strategy — either
//! one budget cloned for all requests ([`schedule_budgeted`]) or
//! sampled per request from a weighted **budget mix**
//! ([`schedule_mixed`]), so serving runs and benches exercise
//! heterogeneous budgets (tight-deadline traffic interleaved with
//! unlimited) the way real fleets see them. Every schedule is a pure
//! function of the rng seed — property-tested, because serve runs,
//! benches and the chain tier's trace emission all lean on exact
//! reproducibility.

use crate::data::Query;
use crate::error::{Error, Result};
use crate::strategies::Budget;
use crate::util::rng::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Open loop: exponential inter-arrival gaps at `rate` req/s.
    Poisson { rate: f64 },
    /// Bursty open loop: Gamma-distributed inter-arrival gaps with mean
    /// `1/rate`. `shape < 1` over-disperses (clumpier than Poisson —
    /// the classic trace shape), `shape = 1` *is* Poisson, `shape > 1`
    /// smooths toward deterministic.
    Gamma { rate: f64, shape: f64 },
    /// On-off bursts: `burst` arrivals with exponential gaps at `rate`,
    /// then an idle period of `idle_s` seconds, repeating.
    OnOff { rate: f64, burst: usize, idle_s: f64 },
    /// Closed loop: next request issues as soon as a worker frees up.
    Closed,
}

/// One inter-arrival gap (seconds) for request number `seq` under the
/// given process. Pure in the rng stream — the single gap definition
/// shared by request schedules and the chain tier's session arrivals.
pub fn arrival_gap_s(arrivals: Arrivals, rng: &mut Rng, seq: usize) -> f64 {
    match arrivals {
        Arrivals::Poisson { rate } => rng.exponential(rate),
        // mean(Gamma(shape, θ=1)) = shape, so scale to mean 1/rate
        Arrivals::Gamma { rate, shape } => sample_gamma(rng, shape) / (rate * shape),
        Arrivals::OnOff {
            rate,
            burst,
            idle_s,
        } => {
            let gap = rng.exponential(rate);
            if seq > 0 && seq % burst.max(1) == 0 {
                gap + idle_s
            } else {
                gap
            }
        }
        Arrivals::Closed => 0.0,
    }
}

/// Marsaglia–Tsang Gamma(shape, 1) sampling; shapes below 1 use the
/// standard boost `G(a) = G(a+1) · U^{1/a}`.
fn sample_gamma(rng: &mut Rng, shape: f64) -> f64 {
    assert!(
        shape.is_finite() && shape > 0.0,
        "gamma shape must be positive"
    );
    if shape < 1.0 {
        let u = rng.f64().max(1e-12);
        return sample_gamma(rng, shape + 1.0) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v = v * v * v;
        let u = rng.f64().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

/// A scheduled request.
#[derive(Debug, Clone)]
pub struct Request {
    pub query: Query,
    /// Offset from run start, ms (0 for closed-loop).
    pub arrival_ms: f64,
    pub seq: usize,
    /// Per-request execution budget, enforced inside the strategy.
    pub budget: Budget,
}

/// Build a request schedule by sampling `n` queries (with replacement)
/// and assigning arrival times; every request gets an unlimited budget.
pub fn schedule(queries: &[Query], n: usize, arrivals: Arrivals, rng: &mut Rng) -> Vec<Request> {
    schedule_budgeted(queries, n, arrivals, Budget::unlimited(), rng)
}

/// Like [`schedule`], but every request carries (a clone of) `budget` —
/// the serving driver passes it through to the decoding method, which
/// enforces it mid-strategy.
pub fn schedule_budgeted(
    queries: &[Query],
    n: usize,
    arrivals: Arrivals,
    budget: Budget,
    rng: &mut Rng,
) -> Vec<Request> {
    assert!(!queries.is_empty(), "no queries to schedule");
    let mut t = 0.0f64;
    (0..n)
        .map(|seq| {
            let query = rng.choice(queries).clone();
            t += arrival_gap_s(arrivals, rng, seq) * 1e3;
            let arrival_ms = t; // Closed gaps are all 0 ⇒ arrival 0
            Request {
                query,
                arrival_ms,
                seq,
                budget: budget.clone(),
            }
        })
        .collect()
}

/// Like [`schedule_budgeted`], but each request's budget is drawn from
/// a weighted mix of `(weight, Budget)` arms — e.g. 30% tight deadline /
/// 30% loose / 40% unlimited. Weights need not sum to 1; they are
/// normalized by [`Rng::weighted`]. Draws are deterministic in the rng
/// seed, like everything else in the schedule.
pub fn schedule_mixed(
    queries: &[Query],
    n: usize,
    arrivals: Arrivals,
    mix: &[(f64, Budget)],
    rng: &mut Rng,
) -> Vec<Request> {
    assert!(!mix.is_empty(), "empty budget mix");
    let weights: Vec<f64> = mix.iter().map(|(w, _)| *w).collect();
    let mut reqs = schedule_budgeted(queries, n, arrivals, Budget::unlimited(), rng);
    for r in &mut reqs {
        r.budget = mix[rng.weighted(&weights)].1.clone();
    }
    reqs
}

/// Parse one budget spec — `unlimited` or `d<deadline_ms>`,
/// `t<max_tokens>`, or both (`d500t256`). The grammar shared by
/// `--budget-mix` arms and `--chain-budget`.
pub fn parse_budget_spec(spec: &str) -> Result<Budget> {
    let bad = |why: &str| {
        Error::Config(format!(
            "bad budget spec '{spec}' ({why}); expected \
             unlimited | d<ms> | t<tokens> | d<ms>t<tokens>"
        ))
    };
    let spec = spec.trim();
    if spec == "unlimited" {
        return Ok(Budget::unlimited());
    }
    let mut budget = Budget::unlimited();
    // d<ms> first (optional), then t<tokens> (optional) — at least one
    // must be present
    let mut rest = spec;
    if let Some(tail) = rest.strip_prefix('d') {
        let (num, after) = match tail.find(|c: char| !c.is_ascii_digit() && c != '.') {
            Some(i) => tail.split_at(i),
            None => (tail, ""),
        };
        let ms: f64 = num.parse().map_err(|_| bad("bad deadline"))?;
        if ms <= 0.0 {
            // `--deadline-ms 0` means "no deadline" on the
            // single-budget path; a spec that wants that must say
            // `unlimited`, not smuggle in an instantly-spent budget
            return Err(bad("deadline must be > 0 (use 'unlimited')"));
        }
        budget = budget.with_deadline_ms(ms);
        rest = after;
    }
    if let Some(tail) = rest.strip_prefix('t') {
        let toks: usize = tail.parse().map_err(|_| bad("bad token cap"))?;
        if toks == 0 {
            return Err(bad("token cap must be > 0 (use 'unlimited')"));
        }
        budget = budget.with_max_tokens(toks);
        rest = "";
    }
    if budget.is_unlimited() || !rest.is_empty() {
        return Err(bad("unrecognized spec"));
    }
    Ok(budget)
}

/// Parse a `--budget-mix` CLI spec into weighted arms:
/// comma-separated `weight:spec` entries with [`parse_budget_spec`]
/// grammar per arm.
///
/// Example: `30:d500,30:d5000,40:unlimited`.
pub fn parse_budget_mix(s: &str) -> Result<Vec<(f64, Budget)>> {
    let bad = |entry: &str, why: &str| {
        Error::Config(format!(
            "bad --budget-mix entry '{entry}' ({why}); expected \
             'weight:spec' with spec = unlimited | d<ms> | t<tokens> | d<ms>t<tokens>, \
             e.g. 30:d500,30:d5000,40:unlimited"
        ))
    };
    let mut mix = Vec::new();
    for entry in s.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (weight, spec) = entry
            .split_once(':')
            .ok_or_else(|| bad(entry, "missing ':'"))?;
        let weight: f64 = weight
            .trim()
            .parse()
            .map_err(|_| bad(entry, "weight is not a number"))?;
        if weight.is_nan() || weight <= 0.0 {
            return Err(bad(entry, "weight must be positive"));
        }
        let budget = parse_budget_spec(spec).map_err(|e| match e {
            Error::Config(why) => Error::Config(format!("in --budget-mix entry '{entry}': {why}")),
            other => other,
        })?;
        mix.push((weight, budget));
    }
    if mix.is_empty() {
        return Err(Error::Config("empty --budget-mix".into()));
    }
    Ok(mix)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queries() -> Vec<Query> {
        (0..5)
            .map(|i| Query {
                id: format!("q{i}"),
                query: format!("Q:1+{i}=?\n"),
                answer: "1".into(),
                k: 2,
            })
            .collect()
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut rng = Rng::new(3, 0);
        let reqs = schedule(&queries(), 2000, Arrivals::Poisson { rate: 10.0 }, &mut rng);
        let total_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / total_s;
        assert!((rate - 10.0).abs() < 1.0, "empirical rate {rate}");
        // arrivals sorted
        assert!(reqs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn closed_loop_all_zero() {
        let mut rng = Rng::new(3, 0);
        let reqs = schedule(&queries(), 10, Arrivals::Closed, &mut rng);
        assert!(reqs.iter().all(|r| r.arrival_ms == 0.0));
        assert!(reqs.iter().all(|r| r.budget.is_unlimited()));
        assert_eq!(reqs.len(), 10);
    }

    #[test]
    fn mixed_budgets_sample_every_arm() {
        let mut rng = Rng::new(11, 0);
        let mix = vec![
            (0.3, Budget::unlimited().with_deadline_ms(100.0)),
            (0.3, Budget::unlimited().with_deadline_ms(5000.0)),
            (0.4, Budget::unlimited()),
        ];
        let reqs = schedule_mixed(&queries(), 300, Arrivals::Closed, &mix, &mut rng);
        assert_eq!(reqs.len(), 300);
        let tight = reqs
            .iter()
            .filter(|r| r.budget.deadline_ms == Some(100.0))
            .count();
        let loose = reqs
            .iter()
            .filter(|r| r.budget.deadline_ms == Some(5000.0))
            .count();
        let unlimited = reqs.iter().filter(|r| r.budget.is_unlimited()).count();
        assert_eq!(tight + loose + unlimited, 300);
        // every arm is hit, roughly by weight (±15 points of slack at
        // n=300 keeps this deterministic-seed test honest, not flaky)
        for (count, expect) in [(tight, 90.0), (loose, 90.0), (unlimited, 120.0)] {
            assert!(
                (count as f64 - expect).abs() < 45.0,
                "arm count {count} far from expectation {expect}"
            );
        }
    }

    #[test]
    fn mixed_budgets_deterministic_in_seed() {
        let mix = vec![
            (1.0, Budget::unlimited().with_deadline_ms(50.0)),
            (1.0, Budget::unlimited().with_max_tokens(64)),
        ];
        let seq = |seed| {
            let mut rng = Rng::new(seed, 0);
            schedule_mixed(&queries(), 40, Arrivals::Closed, &mix, &mut rng)
                .iter()
                .map(|r| r.budget.deadline_ms.is_some())
                .collect::<Vec<_>>()
        };
        assert_eq!(seq(3), seq(3));
        assert_ne!(seq(3), seq(4), "different seeds should differ somewhere");
    }

    #[test]
    fn budget_mix_spec_parses() {
        let mix = parse_budget_mix("30:d500,30:d5000t256,40:unlimited").unwrap();
        assert_eq!(mix.len(), 3);
        assert_eq!(mix[0].0, 30.0);
        assert_eq!(mix[0].1.deadline_ms, Some(500.0));
        assert_eq!(mix[0].1.max_tokens, None);
        assert_eq!(mix[1].1.deadline_ms, Some(5000.0));
        assert_eq!(mix[1].1.max_tokens, Some(256));
        assert!(mix[2].1.is_unlimited());
        // token-only arm and fractional weights/deadlines
        let mix = parse_budget_mix("0.5:t128, 1.5:d2.5").unwrap();
        assert_eq!(mix[0].1.max_tokens, Some(128));
        assert_eq!(mix[1].1.deadline_ms, Some(2.5));
    }

    #[test]
    fn budget_mix_spec_rejects_malformed() {
        for bad in [
            "",
            "30",
            "30:",
            ":d500",
            "x:d500",
            "30:q500",
            "30:d",
            "30:t",
            "30:d500x",
            "-1:d500",
            "0:unlimited",
            // zero limits are instantly-exhausted budgets, not
            // "unlimited" as on the --deadline-ms/--max-tokens path
            "30:d0",
            "30:t0",
            "30:d0t8",
        ] {
            assert!(parse_budget_mix(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn budgets_attach_to_every_request() {
        let mut rng = Rng::new(3, 0);
        let b = Budget::unlimited().with_deadline_ms(100.0).with_max_tokens(64);
        let reqs = schedule_budgeted(&queries(), 5, Arrivals::Closed, b, &mut rng);
        assert!(reqs
            .iter()
            .all(|r| r.budget.deadline_ms == Some(100.0) && r.budget.max_tokens == Some(64)));
    }

    #[test]
    fn budget_spec_parses_standalone() {
        assert!(parse_budget_spec("unlimited").unwrap().is_unlimited());
        let b = parse_budget_spec(" d250t96 ").unwrap();
        assert_eq!(b.deadline_ms, Some(250.0));
        assert_eq!(b.max_tokens, Some(96));
        for bad in ["", "q5", "d", "t", "d0", "t0", "d5x"] {
            assert!(parse_budget_spec(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn gamma_rate_roughly_matches_and_is_burstier() {
        let mut rng = Rng::new(9, 0);
        let arrivals = Arrivals::Gamma {
            rate: 10.0,
            shape: 0.5,
        };
        let reqs = schedule(&queries(), 4000, arrivals, &mut rng);
        let total_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 4000.0 / total_s;
        assert!((rate - 10.0).abs() < 1.0, "empirical rate {rate}");
        // shape 0.5 ⇒ squared coefficient of variation of gaps ≈ 1/shape
        // = 2, well above Poisson's 1 — the whole point of the knob
        let gaps: Vec<f64> = reqs.windows(2).map(|w| w[1].arrival_ms - w[0].arrival_ms).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!(scv > 1.4, "gamma(0.5) gaps should be over-dispersed, scv {scv}");
    }

    #[test]
    fn on_off_inserts_idle_gaps_every_burst() {
        let mut rng = Rng::new(5, 0);
        let arrivals = Arrivals::OnOff {
            rate: 1000.0,
            burst: 4,
            idle_s: 1.0,
        };
        let reqs = schedule(&queries(), 20, arrivals, &mut rng);
        for w in reqs.windows(2) {
            let gap_ms = w[1].arrival_ms - w[0].arrival_ms;
            if w[1].seq % 4 == 0 {
                assert!(gap_ms >= 1000.0, "burst boundary gap {gap_ms} too small");
            } else {
                // in-burst gaps are exponential(1000/s) — overwhelmingly
                // below the 1 s idle period
                assert!(gap_ms < 1000.0, "in-burst gap {gap_ms} absorbed an idle");
            }
        }
    }

    #[test]
    fn prop_schedules_are_pure_functions_of_seed() {
        use crate::testkit::{forall, prop_assert};
        let mix = vec![
            (0.5, Budget::unlimited().with_deadline_ms(200.0)),
            (0.3, Budget::unlimited().with_max_tokens(96)),
            (0.2, Budget::unlimited()),
        ];
        let qs = queries();
        forall(
            "schedules are pure functions of seed",
            40,
            |rng| (rng.next_u64(), rng.below(3) as usize),
            |&(seed, kind)| {
                let arrivals = match kind {
                    0 => Arrivals::Poisson { rate: 40.0 },
                    1 => Arrivals::Gamma {
                        rate: 40.0,
                        shape: 0.5,
                    },
                    _ => Arrivals::OnOff {
                        rate: 200.0,
                        burst: 5,
                        idle_s: 0.05,
                    },
                };
                let run = || {
                    let mut rng = Rng::new(seed, 0x5E7E);
                    schedule_mixed(&qs, 30, arrivals, &mix, &mut rng)
                        .into_iter()
                        .map(|r| {
                            (
                                r.query.id,
                                r.arrival_ms.to_bits(),
                                r.budget.deadline_ms.map(f64::to_bits),
                                r.budget.max_tokens,
                            )
                        })
                        .collect::<Vec<_>>()
                };
                prop_assert(run() == run(), "same seed must replay bit-identically")
            },
        );
    }
}
