//! Load generation for the serving driver: open-loop Poisson arrivals
//! (the standard serving-benchmark model) or closed-loop back-to-back.
//! Each request carries a per-request [`Budget`] that the decoding
//! method enforces mid-strategy — either one budget cloned for all
//! requests ([`schedule_budgeted`]) or sampled per request from a
//! weighted **budget mix** ([`schedule_mixed`]), so serving runs and
//! benches exercise heterogeneous budgets (tight-deadline traffic
//! interleaved with unlimited) the way real fleets see them.

use crate::data::Query;
use crate::error::{Error, Result};
use crate::strategies::Budget;
use crate::util::rng::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Open loop: exponential inter-arrival gaps at `rate` req/s.
    Poisson { rate: f64 },
    /// Closed loop: next request issues as soon as a worker frees up.
    Closed,
}

/// A scheduled request.
#[derive(Debug, Clone)]
pub struct Request {
    pub query: Query,
    /// Offset from run start, ms (0 for closed-loop).
    pub arrival_ms: f64,
    pub seq: usize,
    /// Per-request execution budget, enforced inside the strategy.
    pub budget: Budget,
}

/// Build a request schedule by sampling `n` queries (with replacement)
/// and assigning arrival times; every request gets an unlimited budget.
pub fn schedule(queries: &[Query], n: usize, arrivals: Arrivals, rng: &mut Rng) -> Vec<Request> {
    schedule_budgeted(queries, n, arrivals, Budget::unlimited(), rng)
}

/// Like [`schedule`], but every request carries (a clone of) `budget` —
/// the serving driver passes it through to the decoding method, which
/// enforces it mid-strategy.
pub fn schedule_budgeted(
    queries: &[Query],
    n: usize,
    arrivals: Arrivals,
    budget: Budget,
    rng: &mut Rng,
) -> Vec<Request> {
    assert!(!queries.is_empty(), "no queries to schedule");
    let mut t = 0.0f64;
    (0..n)
        .map(|seq| {
            let query = rng.choice(queries).clone();
            let arrival_ms = match arrivals {
                Arrivals::Poisson { rate } => {
                    t += rng.exponential(rate) * 1e3;
                    t
                }
                Arrivals::Closed => 0.0,
            };
            Request {
                query,
                arrival_ms,
                seq,
                budget: budget.clone(),
            }
        })
        .collect()
}

/// Like [`schedule_budgeted`], but each request's budget is drawn from
/// a weighted mix of `(weight, Budget)` arms — e.g. 30% tight deadline /
/// 30% loose / 40% unlimited. Weights need not sum to 1; they are
/// normalized by [`Rng::weighted`]. Draws are deterministic in the rng
/// seed, like everything else in the schedule.
pub fn schedule_mixed(
    queries: &[Query],
    n: usize,
    arrivals: Arrivals,
    mix: &[(f64, Budget)],
    rng: &mut Rng,
) -> Vec<Request> {
    assert!(!mix.is_empty(), "empty budget mix");
    let weights: Vec<f64> = mix.iter().map(|(w, _)| *w).collect();
    let mut reqs = schedule_budgeted(queries, n, arrivals, Budget::unlimited(), rng);
    for r in &mut reqs {
        r.budget = mix[rng.weighted(&weights)].1.clone();
    }
    reqs
}

/// Parse a `--budget-mix` CLI spec into weighted arms:
/// comma-separated `weight:spec` entries where `spec` is `unlimited`
/// or `d<deadline_ms>`, `t<max_tokens>`, or both (`d500t256`).
///
/// Example: `30:d500,30:d5000,40:unlimited`.
pub fn parse_budget_mix(s: &str) -> Result<Vec<(f64, Budget)>> {
    let bad = |entry: &str, why: &str| {
        Error::Config(format!(
            "bad --budget-mix entry '{entry}' ({why}); expected \
             'weight:spec' with spec = unlimited | d<ms> | t<tokens> | d<ms>t<tokens>, \
             e.g. 30:d500,30:d5000,40:unlimited"
        ))
    };
    let mut mix = Vec::new();
    for entry in s.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (weight, spec) = entry
            .split_once(':')
            .ok_or_else(|| bad(entry, "missing ':'"))?;
        let weight: f64 = weight
            .trim()
            .parse()
            .map_err(|_| bad(entry, "weight is not a number"))?;
        if weight.is_nan() || weight <= 0.0 {
            return Err(bad(entry, "weight must be positive"));
        }
        let spec = spec.trim();
        let budget = if spec == "unlimited" {
            Budget::unlimited()
        } else {
            let mut budget = Budget::unlimited();
            // d<ms> first (optional), then t<tokens> (optional) — at
            // least one must be present
            let mut rest = spec;
            if let Some(tail) = rest.strip_prefix('d') {
                let (num, after) = match tail.find(|c: char| !c.is_ascii_digit() && c != '.') {
                    Some(i) => tail.split_at(i),
                    None => (tail, ""),
                };
                let ms: f64 = num.parse().map_err(|_| bad(entry, "bad deadline"))?;
                if ms <= 0.0 {
                    // `--deadline-ms 0` means "no deadline" on the
                    // single-budget path; a mix arm that wants that
                    // must say `unlimited`, not smuggle in an
                    // instantly-spent budget
                    return Err(bad(entry, "deadline must be > 0 (use 'unlimited')"));
                }
                budget = budget.with_deadline_ms(ms);
                rest = after;
            }
            if let Some(tail) = rest.strip_prefix('t') {
                let toks: usize = tail.parse().map_err(|_| bad(entry, "bad token cap"))?;
                if toks == 0 {
                    return Err(bad(entry, "token cap must be > 0 (use 'unlimited')"));
                }
                budget = budget.with_max_tokens(toks);
                rest = "";
            }
            if budget.is_unlimited() || !rest.is_empty() {
                return Err(bad(entry, "unrecognized spec"));
            }
            budget
        };
        mix.push((weight, budget));
    }
    if mix.is_empty() {
        return Err(Error::Config("empty --budget-mix".into()));
    }
    Ok(mix)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queries() -> Vec<Query> {
        (0..5)
            .map(|i| Query {
                id: format!("q{i}"),
                query: format!("Q:1+{i}=?\n"),
                answer: "1".into(),
                k: 2,
            })
            .collect()
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut rng = Rng::new(3, 0);
        let reqs = schedule(&queries(), 2000, Arrivals::Poisson { rate: 10.0 }, &mut rng);
        let total_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / total_s;
        assert!((rate - 10.0).abs() < 1.0, "empirical rate {rate}");
        // arrivals sorted
        assert!(reqs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn closed_loop_all_zero() {
        let mut rng = Rng::new(3, 0);
        let reqs = schedule(&queries(), 10, Arrivals::Closed, &mut rng);
        assert!(reqs.iter().all(|r| r.arrival_ms == 0.0));
        assert!(reqs.iter().all(|r| r.budget.is_unlimited()));
        assert_eq!(reqs.len(), 10);
    }

    #[test]
    fn mixed_budgets_sample_every_arm() {
        let mut rng = Rng::new(11, 0);
        let mix = vec![
            (0.3, Budget::unlimited().with_deadline_ms(100.0)),
            (0.3, Budget::unlimited().with_deadline_ms(5000.0)),
            (0.4, Budget::unlimited()),
        ];
        let reqs = schedule_mixed(&queries(), 300, Arrivals::Closed, &mix, &mut rng);
        assert_eq!(reqs.len(), 300);
        let tight = reqs
            .iter()
            .filter(|r| r.budget.deadline_ms == Some(100.0))
            .count();
        let loose = reqs
            .iter()
            .filter(|r| r.budget.deadline_ms == Some(5000.0))
            .count();
        let unlimited = reqs.iter().filter(|r| r.budget.is_unlimited()).count();
        assert_eq!(tight + loose + unlimited, 300);
        // every arm is hit, roughly by weight (±15 points of slack at
        // n=300 keeps this deterministic-seed test honest, not flaky)
        for (count, expect) in [(tight, 90.0), (loose, 90.0), (unlimited, 120.0)] {
            assert!(
                (count as f64 - expect).abs() < 45.0,
                "arm count {count} far from expectation {expect}"
            );
        }
    }

    #[test]
    fn mixed_budgets_deterministic_in_seed() {
        let mix = vec![
            (1.0, Budget::unlimited().with_deadline_ms(50.0)),
            (1.0, Budget::unlimited().with_max_tokens(64)),
        ];
        let seq = |seed| {
            let mut rng = Rng::new(seed, 0);
            schedule_mixed(&queries(), 40, Arrivals::Closed, &mix, &mut rng)
                .iter()
                .map(|r| r.budget.deadline_ms.is_some())
                .collect::<Vec<_>>()
        };
        assert_eq!(seq(3), seq(3));
        assert_ne!(seq(3), seq(4), "different seeds should differ somewhere");
    }

    #[test]
    fn budget_mix_spec_parses() {
        let mix = parse_budget_mix("30:d500,30:d5000t256,40:unlimited").unwrap();
        assert_eq!(mix.len(), 3);
        assert_eq!(mix[0].0, 30.0);
        assert_eq!(mix[0].1.deadline_ms, Some(500.0));
        assert_eq!(mix[0].1.max_tokens, None);
        assert_eq!(mix[1].1.deadline_ms, Some(5000.0));
        assert_eq!(mix[1].1.max_tokens, Some(256));
        assert!(mix[2].1.is_unlimited());
        // token-only arm and fractional weights/deadlines
        let mix = parse_budget_mix("0.5:t128, 1.5:d2.5").unwrap();
        assert_eq!(mix[0].1.max_tokens, Some(128));
        assert_eq!(mix[1].1.deadline_ms, Some(2.5));
    }

    #[test]
    fn budget_mix_spec_rejects_malformed() {
        for bad in [
            "",
            "30",
            "30:",
            ":d500",
            "x:d500",
            "30:q500",
            "30:d",
            "30:t",
            "30:d500x",
            "-1:d500",
            "0:unlimited",
            // zero limits are instantly-exhausted budgets, not
            // "unlimited" as on the --deadline-ms/--max-tokens path
            "30:d0",
            "30:t0",
            "30:d0t8",
        ] {
            assert!(parse_budget_mix(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn budgets_attach_to_every_request() {
        let mut rng = Rng::new(3, 0);
        let b = Budget::unlimited().with_deadline_ms(100.0).with_max_tokens(64);
        let reqs = schedule_budgeted(&queries(), 5, Arrivals::Closed, b, &mut rng);
        assert!(reqs
            .iter()
            .all(|r| r.budget.deadline_ms == Some(100.0) && r.budget.max_tokens == Some(64)));
    }
}
