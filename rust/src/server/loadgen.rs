//! Load generation for the serving driver: open-loop Poisson arrivals
//! (the standard serving-benchmark model) or closed-loop back-to-back.
//! Each request carries a per-request [`Budget`] that the decoding
//! method enforces mid-strategy.

use crate::data::Query;
use crate::strategies::Budget;
use crate::util::rng::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Open loop: exponential inter-arrival gaps at `rate` req/s.
    Poisson { rate: f64 },
    /// Closed loop: next request issues as soon as a worker frees up.
    Closed,
}

/// A scheduled request.
#[derive(Debug, Clone)]
pub struct Request {
    pub query: Query,
    /// Offset from run start, ms (0 for closed-loop).
    pub arrival_ms: f64,
    pub seq: usize,
    /// Per-request execution budget, enforced inside the strategy.
    pub budget: Budget,
}

/// Build a request schedule by sampling `n` queries (with replacement)
/// and assigning arrival times; every request gets an unlimited budget.
pub fn schedule(queries: &[Query], n: usize, arrivals: Arrivals, rng: &mut Rng) -> Vec<Request> {
    schedule_budgeted(queries, n, arrivals, Budget::unlimited(), rng)
}

/// Like [`schedule`], but every request carries (a clone of) `budget` —
/// the serving driver passes it through to the decoding method, which
/// enforces it mid-strategy.
pub fn schedule_budgeted(
    queries: &[Query],
    n: usize,
    arrivals: Arrivals,
    budget: Budget,
    rng: &mut Rng,
) -> Vec<Request> {
    assert!(!queries.is_empty(), "no queries to schedule");
    let mut t = 0.0f64;
    (0..n)
        .map(|seq| {
            let query = rng.choice(queries).clone();
            let arrival_ms = match arrivals {
                Arrivals::Poisson { rate } => {
                    t += rng.exponential(rate) * 1e3;
                    t
                }
                Arrivals::Closed => 0.0,
            };
            Request {
                query,
                arrival_ms,
                seq,
                budget: budget.clone(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queries() -> Vec<Query> {
        (0..5)
            .map(|i| Query {
                id: format!("q{i}"),
                query: format!("Q:1+{i}=?\n"),
                answer: "1".into(),
                k: 2,
            })
            .collect()
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut rng = Rng::new(3, 0);
        let reqs = schedule(&queries(), 2000, Arrivals::Poisson { rate: 10.0 }, &mut rng);
        let total_s = reqs.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / total_s;
        assert!((rate - 10.0).abs() < 1.0, "empirical rate {rate}");
        // arrivals sorted
        assert!(reqs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn closed_loop_all_zero() {
        let mut rng = Rng::new(3, 0);
        let reqs = schedule(&queries(), 10, Arrivals::Closed, &mut rng);
        assert!(reqs.iter().all(|r| r.arrival_ms == 0.0));
        assert!(reqs.iter().all(|r| r.budget.is_unlimited()));
        assert_eq!(reqs.len(), 10);
    }

    #[test]
    fn budgets_attach_to_every_request() {
        let mut rng = Rng::new(3, 0);
        let b = Budget::unlimited().with_deadline_ms(100.0).with_max_tokens(64);
        let reqs = schedule_budgeted(&queries(), 5, Arrivals::Closed, b, &mut rng);
        assert!(reqs
            .iter()
            .all(|r| r.budget.deadline_ms == Some(100.0) && r.budget.max_tokens == Some(64)));
    }
}
