//! The serving driver: a continuation event loop pulling scheduled
//! requests through the router and the strategy stepper, with
//! end-to-end latency accounting.
//!
//! This is the deployment shape of the paper's system: requests arrive,
//! the router picks `s*(x)` under the operator's (λ_T, λ_L) *and* the
//! request's budget (deadline-infeasible strategies are excluded via the
//! budget-bucket cost model), and the request is admitted into the
//! continuation executor ([`Stepper`]) as a resumable step machine —
//! not a thread. One pump thread multiplexes every in-flight strategy:
//! concurrent requests' generation/scoring rounds are submitted to the
//! engine together (so the scheduler coalesces them into shared
//! bucket-shaped calls), budgets are enforced all the way down to
//! *mid-call* engine preemption, and when a request finishes with
//! leftover budget the [`EvenShareReallocator`] grants it to
//! still-running requests between steps — the paper's per-query
//! allocation, made online. `concurrency` (the old `workers` knob)
//! bounds how many machines are in flight at once; admission stays
//! strictly in schedule order.
//!
//! The driver reports accuracy / tokens / latency percentiles /
//! throughput plus budget-enforcement fractions, preemption counts,
//! realized-vs-predicted latency, and the stepper's reallocation
//! counters.

use crate::error::Result;
use crate::metrics::Histogram;
use crate::router::{EvenShareReallocator, Lambdas, Router};
use crate::server::loadgen::Request;
use crate::strategies::stepper::{Progress, Stepper, Ticket};
use crate::strategies::{Executor, Strategy};
use crate::util::json::Value;
use crate::util::stats;
use crate::log_info;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Routing mode for the driver.
pub enum Mode {
    /// Query-adaptive routing (the paper's system).
    Adaptive(Router, Lambdas),
    /// Fixed strategy baseline.
    Static(Strategy),
}

/// Per-request record.
#[derive(Debug, Clone)]
pub struct Served {
    pub query_id: String,
    pub strategy: String,
    /// Strategy chosen by the adaptive router (vs a static baseline).
    pub routed: bool,
    pub correct: bool,
    pub tokens: usize,
    /// The request's budget ran out mid-strategy.
    pub budget_exhausted: bool,
    /// The engine preempted a generation call mid-decode for this
    /// request (deadline, cancel, or token cap).
    pub preempted: bool,
    /// The strategy finished before its configured work (early-stop vote
    /// decided, deadline-aware round truncation).
    pub stopped_early: bool,
    /// Router-predicted strategy latency for this request (budget-bucket
    /// cost model), when adaptively routed — compared against the
    /// realized `service_ms` in the report.
    pub predicted_ms: Option<f64>,
    /// Strategy execution time (ms).
    pub service_ms: f64,
    /// Queue wait + execution (ms) — what the user experiences.
    pub e2e_ms: f64,
}

/// Pre-compile every executable a strategy set can touch by running each
/// strategy once on a throwaway query. Without this, the first live
/// requests pay seconds of lazy XLA compilation (measured: e2e p50
/// 12.6s → 0.4s for the adaptive mix on this testbed).
pub fn warmup(executor: &Executor, strategies: &[Strategy], query: &str) -> Result<()> {
    let t0 = Instant::now();
    for s in strategies {
        let _ = executor.run(s, query)?;
    }
    log_info!(
        "serve warmup: {} strategies in {:.1}s",
        strategies.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Route one request: pick its strategy (and predicted latency when
/// adaptive) under the request's budget.
fn route(
    executor: &Executor,
    mode: &Mode,
    req: &Request,
) -> Result<(Strategy, bool, Option<f64>)> {
    Ok(match mode {
        Mode::Adaptive(router, lambdas) => {
            // budget-aware selection: the budget-bucket cost table prices
            // each strategy under this request's deadline, and strategies
            // that cannot meet it are excluded when an alternative can
            let score =
                router.select_budgeted(&executor.engine, &req.query.query, *lambdas, &req.budget)?;
            (score.strategy, true, Some(score.cost.latency_ms))
        }
        Mode::Static(s) => (s.clone(), false, None),
    })
}

/// Run the driver over a schedule. `concurrency` bounds the number of
/// in-flight step machines (the budget the old thread-per-worker pool
/// expressed as thread count); requests are admitted strictly in
/// schedule order, when due *and* when a slot is free — so queue wait
/// still shows up in `e2e_ms`. The whole run is pumped by this one
/// thread: routing happens at admission, strategy rounds interleave
/// through the stepper, and finished requests' leftover budgets are
/// reallocated to running ones between steps.
pub fn run(
    executor: &Executor,
    mode: &Mode,
    requests: Vec<Request>,
    concurrency: usize,
) -> Result<ServeReport> {
    let n = requests.len();
    let cap = concurrency.max(1);
    let start = Instant::now();
    let mut stepper =
        Stepper::new(executor.clone()).with_reallocator(Box::new(EvenShareReallocator));
    // (routed, predicted_ms) captured at admission, indexed by seq tag
    let mut admitted_meta: Vec<(bool, Option<f64>)> = vec![(false, None); n];
    let mut served: Vec<Served> = Vec::with_capacity(n);
    let mut next = 0usize;

    // Record completions as soon as an advance produced them, so
    // `e2e_ms` is stamped at actual completion — not after the next
    // admission's (blocking, possibly engine-bound) routing calls.
    let drain = |stepper: &mut Stepper,
                 served: &mut Vec<Served>,
                 meta: &[(bool, Option<f64>)]| {
        for c in stepper.drain_completed() {
            let idx = c.tag as usize;
            let req = &requests[idx];
            let (routed, predicted_ms) = meta[idx];
            let done_ms = start.elapsed().as_secs_f64() * 1e3;
            served.push(Served {
                query_id: req.query.id.clone(),
                strategy: c.strategy_id,
                routed,
                correct: c.outcome.is_correct(&req.query.answer),
                tokens: c.outcome.tokens,
                budget_exhausted: c.outcome.budget_exhausted,
                preempted: c.outcome.preempted,
                stopped_early: c.outcome.stopped_early,
                predicted_ms,
                service_ms: c.outcome.latency_ms,
                e2e_ms: done_ms - req.arrival_ms.min(done_ms),
            });
        }
    };

    while served.len() < n {
        let now_ms = start.elapsed().as_secs_f64() * 1e3;
        // Admit due requests into free slots, in schedule order. Each
        // admission's routing is a blocking engine round-trip on this
        // pump thread, so between admissions give in-flight machines a
        // non-blocking advance: arrived replies are harvested and the
        // next rounds (including the just-admitted machine's first
        // step) are submitted, overlapping with the next routing call.
        while next < n && stepper.in_flight() < cap && requests[next].arrival_ms <= now_ms {
            let req = &requests[next];
            let (strategy, routed, predicted_ms) = route(executor, mode, req)?;
            admitted_meta[next] = (routed, predicted_ms);
            stepper.admit(Ticket {
                query: req.query.query.clone(),
                strategy,
                budget: req.budget.clone(),
                tag: next as u64,
            })?;
            next += 1;
            stepper.advance(Some(Duration::ZERO))?;
            drain(&mut stepper, &mut served, &admitted_meta);
        }
        if served.len() >= n {
            break;
        }
        if stepper.in_flight() == 0 {
            // Idle with work left: sleep until the next arrival is due.
            let wait_ms = (requests[next].arrival_ms - now_ms).max(0.0);
            if wait_ms > 0.0 {
                std::thread::sleep(Duration::from_micros((wait_ms * 1e3) as u64));
            }
            continue;
        }
        // Pump; if an admission could become due while we wait, cap the
        // wait so arrivals are admitted on time.
        let wait = if next < n && stepper.in_flight() < cap {
            Some(Duration::from_micros(
                ((requests[next].arrival_ms - now_ms).max(0.0) * 1e3) as u64 + 1,
            ))
        } else {
            None
        };
        let _progress: Progress = stepper.advance(wait)?;
        drain(&mut stepper, &mut served, &admitted_meta);
    }

    let wall_s = start.elapsed().as_secs_f64();
    // per-engine utilization + placement counters, when the executor
    // fronts a sharded pool (None on the classic single-engine path)
    let pool = executor.engine.pool_report();
    Ok(ServeReport::new(served, wall_s, stepper.metrics.to_json(), pool))
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    pub served: Vec<Served>,
    pub wall_s: f64,
    /// Continuation-executor counters (steps, submissions, reallocation
    /// grants) captured at the end of the run.
    pub stepper: Value,
    /// Pool placement + per-engine utilization
    /// ([`crate::engine::pool::PoolRouter::report`]) when serving from a
    /// sharded [`crate::engine::pool::EnginePool`] of 2+ engines.
    pub pool: Option<Value>,
}

impl ServeReport {
    fn new(served: Vec<Served>, wall_s: f64, stepper: Value, pool: Option<Value>) -> ServeReport {
        ServeReport {
            served,
            wall_s,
            stepper,
            pool,
        }
    }

    pub fn to_json(&self) -> Value {
        let n = self.served.len().max(1);
        let correct = self.served.iter().filter(|s| s.correct).count();
        let routed = self.served.iter().filter(|s| s.routed).count();
        let exhausted = self.served.iter().filter(|s| s.budget_exhausted).count();
        let preempted = self.served.iter().filter(|s| s.preempted).count();
        let stopped = self.served.iter().filter(|s| s.stopped_early).count();
        let tokens: Vec<f64> = self.served.iter().map(|s| s.tokens as f64).collect();
        // realized-vs-predicted latency over adaptively routed requests
        let pred_pairs: Vec<(f64, f64)> = self
            .served
            .iter()
            .filter_map(|s| s.predicted_ms.map(|p| (p, s.service_ms)))
            .collect();
        let pred_json = if pred_pairs.is_empty() {
            Value::obj().with("n", 0usize)
        } else {
            let abs_err: Vec<f64> = pred_pairs.iter().map(|&(p, r)| (r - p).abs()).collect();
            let ratio: Vec<f64> = pred_pairs
                .iter()
                .map(|&(p, r)| r / p.max(1e-9))
                .collect();
            Value::obj()
                .with("n", pred_pairs.len())
                .with("mean_abs_err_ms", stats::mean(&abs_err))
                .with("mean_realized_over_predicted", stats::mean(&ratio))
        };
        let service = Histogram::new();
        let e2e = Histogram::new();
        for s in &self.served {
            service.record(s.service_ms);
            e2e.record(s.e2e_ms);
        }
        let mut by_strategy: HashMap<&str, usize> = HashMap::new();
        for s in &self.served {
            *by_strategy.entry(s.strategy.as_str()).or_default() += 1;
        }
        let mut strat_json = Value::obj();
        let mut keys: Vec<&&str> = by_strategy.keys().collect();
        keys.sort();
        for k in keys {
            strat_json.set(k, by_strategy[*k]);
        }
        let mut v = Value::obj()
            .with("requests", self.served.len())
            .with("wall_s", self.wall_s)
            .with("throughput_rps", self.served.len() as f64 / self.wall_s.max(1e-9))
            .with("accuracy", correct as f64 / n as f64)
            .with("avg_tokens", stats::mean(&tokens))
            .with("adaptive_fraction", routed as f64 / n as f64)
            .with("budget_exhausted_fraction", exhausted as f64 / n as f64)
            .with("preempted_count", preempted)
            .with("preempted_fraction", preempted as f64 / n as f64)
            .with("stopped_early_fraction", stopped as f64 / n as f64)
            .with("latency_prediction", pred_json)
            .with("stepper", self.stepper.clone())
            .with("service_ms", service.summary().to_json())
            .with("e2e_ms", e2e.summary().to_json())
            .with("selection", strat_json);
        if let Some(pool) = &self.pool {
            v.set("pool", pool.clone());
        }
        v
    }

    pub fn log_summary(&self, label: &str) {
        let v = self.to_json();
        log_info!(
            "serve[{label}]: {} reqs in {:.1}s ({:.2} rps), acc {:.3}, avg tokens {:.0}, \
             e2e p50 {:.0}ms p95 {:.0}ms, adaptive {:.0}%, budget-hit {:.0}%, preempted {:.0}%, \
             realloc grants {:.0}",
            self.served.len(),
            self.wall_s,
            v.req_f64("throughput_rps").unwrap_or(0.0),
            v.req_f64("accuracy").unwrap_or(0.0),
            v.req_f64("avg_tokens").unwrap_or(0.0),
            v.req("e2e_ms").and_then(|h| h.req_f64("p50")).unwrap_or(0.0),
            v.req("e2e_ms").and_then(|h| h.req_f64("p95")).unwrap_or(0.0),
            100.0 * v.req_f64("adaptive_fraction").unwrap_or(0.0),
            100.0 * v.req_f64("budget_exhausted_fraction").unwrap_or(0.0),
            100.0 * v.req_f64("preempted_fraction").unwrap_or(0.0),
            v.req("stepper")
                .and_then(|s| s.req_f64("realloc_grants"))
                .unwrap_or(0.0),
        );
        if let Some(pool) = &self.pool {
            log_info!(
                "serve[{label}]: pool {} engines, balance ratio {:.2}, placements {:.0} \
                 ({:.0} deadline tiebreaks)",
                pool.req_f64("engines").unwrap_or(0.0),
                pool.req_f64("balance_ratio").unwrap_or(1.0),
                pool.req_f64("placements").unwrap_or(0.0),
                pool.req_f64("deadline_tiebreaks").unwrap_or(0.0),
            );
        }
    }
}
