//! The serving driver: a continuation event loop pulling scheduled
//! requests through the router and the strategy stepper, with
//! end-to-end latency accounting.
//!
//! This is the deployment shape of the paper's system: requests arrive,
//! the router picks `s*(x)` under the operator's (λ_T, λ_L) *and* the
//! request's budget (deadline-infeasible strategies are excluded via the
//! budget-bucket cost model), and the request is admitted into the
//! continuation executor ([`Stepper`]) as a resumable step machine —
//! not a thread. One pump thread multiplexes every in-flight strategy:
//! concurrent requests' generation/scoring rounds are submitted to the
//! engine together (so the scheduler coalesces them into shared
//! bucket-shaped calls), budgets are enforced all the way down to
//! *mid-call* engine preemption, and when a request finishes with
//! leftover budget the [`EvenShareReallocator`] grants it to
//! still-running requests between steps — the paper's per-query
//! allocation, made online. `concurrency` (the old `workers` knob)
//! bounds how many machines are in flight at once; admission stays
//! strictly in schedule order.
//!
//! Agentic chains ([`crate::server::chain`]) are first-class citizens
//! of the same event loop ([`run_traffic`]): a chain's first step is
//! admitted at its arrival like any request, each later step is
//! admitted the moment its predecessor completes (ahead of waiting new
//! arrivals), and every step is routed against its chain's *current*
//! budget slice — re-split by [`crate::router::ChainAllocator`] after
//! each completion, so early cheap steps bank budget for later hard
//! ones.
//!
//! The driver reports accuracy / tokens / latency percentiles /
//! throughput plus budget-enforcement fractions, preemption counts,
//! realized-vs-predicted latency, the stepper's reallocation counters,
//! and (when chains ran) the chain tier's goodput section.

use crate::data::Query;
use crate::error::Result;
use crate::metrics::{ChainMetrics, Histogram};
use crate::router::{EvenShareReallocator, Grant, Lambdas, Router};
use crate::server::chain::{ChainOutcome, ChainSpec, ChainState, ChainStepResult};
use crate::server::loadgen::Request;
use crate::strategies::stepper::{Progress, Stepper, Ticket};
use crate::strategies::{Executor, Strategy};
use crate::util::json::Value;
use crate::util::stats;
use crate::log_info;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Routing mode for the driver.
pub enum Mode {
    /// Query-adaptive routing (the paper's system).
    Adaptive(Router, Lambdas),
    /// Fixed strategy baseline.
    Static(Strategy),
}

/// Per-request record.
#[derive(Debug, Clone)]
pub struct Served {
    pub query_id: String,
    pub strategy: String,
    /// Strategy chosen by the adaptive router (vs a static baseline).
    pub routed: bool,
    pub correct: bool,
    pub tokens: usize,
    /// The request's budget ran out mid-strategy.
    pub budget_exhausted: bool,
    /// The engine preempted a generation call mid-decode for this
    /// request (deadline, cancel, or token cap).
    pub preempted: bool,
    /// The strategy finished before its configured work (early-stop vote
    /// decided, deadline-aware round truncation).
    pub stopped_early: bool,
    /// Router-predicted strategy latency for this request (budget-bucket
    /// cost model), when adaptively routed — compared against the
    /// realized `service_ms` in the report.
    pub predicted_ms: Option<f64>,
    /// Strategy execution time (ms).
    pub service_ms: f64,
    /// Queue wait + execution (ms) — what the user experiences.
    pub e2e_ms: f64,
}

/// Pre-compile every executable a strategy set can touch by running each
/// strategy once on a throwaway query. Without this, the first live
/// requests pay seconds of lazy XLA compilation (measured: e2e p50
/// 12.6s → 0.4s for the adaptive mix on this testbed).
pub fn warmup(executor: &Executor, strategies: &[Strategy], query: &str) -> Result<()> {
    let t0 = Instant::now();
    for s in strategies {
        let _ = executor.run(s, query)?;
    }
    log_info!(
        "serve warmup: {} strategies in {:.1}s",
        strategies.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Route one request: pick its strategy (and predicted latency when
/// adaptive) under the request's budget. Shared with the chain tier,
/// which routes each step against its *current* budget slice.
pub(crate) fn route(
    executor: &Executor,
    mode: &Mode,
    req: &Request,
) -> Result<(Strategy, bool, Option<f64>)> {
    Ok(match mode {
        Mode::Adaptive(router, lambdas) => {
            // budget-aware selection: the budget-bucket cost table prices
            // each strategy under this request's deadline, and strategies
            // that cannot meet it are excluded when an alternative can
            let score =
                router.select_budgeted(&executor.engine, &req.query.query, *lambdas, &req.budget)?;
            (score.strategy, true, Some(score.cost.latency_ms))
        }
        Mode::Static(s) => (s.clone(), false, None),
    })
}

/// Run the driver over a schedule of independent requests. Thin wrapper
/// over [`run_traffic`] with no chains.
pub fn run(
    executor: &Executor,
    mode: &Mode,
    requests: Vec<Request>,
    concurrency: usize,
) -> Result<ServeReport> {
    run_traffic(executor, mode, requests, Vec::new(), concurrency)
}

/// Tag bit marking a stepper ticket as a chain step; the low bits carry
/// `(chain_index << 16) | step_index`.
const CHAIN_TAG: u64 = 1 << 63;

/// Per-step context captured at admission of a chain step, joined back
/// against the stepper completion.
struct PendingStep {
    query: Query,
    routed: bool,
    grant: Grant,
}

/// Driver-side state of one chain.
struct ChainRun {
    /// `Some` while the chain is live; taken at finalization.
    state: Option<ChainState>,
    pending: Option<PendingStep>,
    outcome: Option<ChainOutcome>,
}

/// Fold a finished chain into the run's [`ChainMetrics`].
fn finalize_chain(
    metrics: &ChainMetrics,
    run: &mut ChainRun,
    outcome: ChainOutcome,
    chains_done: &mut usize,
) {
    if outcome.steps_completed() == outcome.steps_total {
        metrics.chains_completed.inc();
    } else {
        metrics.chains_exhausted.inc();
    }
    if outcome.goodput_ok {
        metrics.goodput_ok.inc();
    }
    metrics.realloc_grants.add(outcome.realloc_grants as u64);
    metrics.realloc_us_granted.add((outcome.granted_ms * 1e3) as u64);
    metrics
        .realloc_tokens_granted
        .add(outcome.granted_tokens as u64);
    metrics.e2e.record(outcome.e2e_ms);
    run.outcome = Some(outcome);
    *chains_done += 1;
}

/// Admit the chain's next step: re-split the chain pool against time
/// elapsed since arrival, route the re-seeded step query against its
/// slice, and ticket it into the stepper. If the pool is already spent,
/// the chain is finalized as a partial (`budget_exhausted`) outcome
/// instead — exhaustion can never hang the loop. Returns whether a
/// ticket was admitted.
#[allow(clippy::too_many_arguments)]
fn admit_chain_step(
    executor: &Executor,
    mode: &Mode,
    stepper: &mut Stepper,
    metrics: &ChainMetrics,
    run: &mut ChainRun,
    ci: usize,
    now_ms: f64,
    chains_done: &mut usize,
) -> Result<bool> {
    let elapsed = {
        let state = run.state.as_ref().expect("admit on finalized chain");
        (now_ms - state.spec.arrival_ms).max(0.0)
    };
    if run.state.as_ref().is_some_and(|s| s.exhausted(elapsed)) {
        let state = run.state.take().expect("state checked above");
        finalize_chain(metrics, run, state.into_outcome(elapsed, true), chains_done);
        return Ok(false);
    }
    let state = run.state.as_mut().expect("state checked above");
    let (budget, grant) = state.slice(elapsed);
    let query = state.next_query();
    let req = Request {
        query: query.clone(),
        arrival_ms: state.spec.arrival_ms,
        seq: state.next_step,
        budget: budget.clone(),
    };
    let (strategy, routed, _predicted) = route(executor, mode, &req)?;
    stepper.admit(Ticket {
        query: query.query.clone(),
        strategy,
        budget,
        tag: CHAIN_TAG | ((ci as u64) << 16) | state.next_step as u64,
    })?;
    run.pending = Some(PendingStep {
        query,
        routed,
        grant,
    });
    Ok(true)
}

/// Run the driver over mixed traffic: independent requests plus agentic
/// chains, interleaved through one stepper. `concurrency` bounds the
/// number of in-flight step machines; singles and chain *first* steps
/// are admitted strictly in arrival order, when due and when a slot is
/// free — so queue wait still shows up in `e2e_ms` (and eats into a
/// chain's pool: the allocator's elapsed clock is anchored at chain
/// arrival). A chain's next step is admitted the moment its predecessor
/// completes, ahead of waiting new arrivals: the session already in
/// flight keeps its slot. The whole run is pumped by this one thread:
/// routing happens at admission (each chain step routed against its
/// *current*, re-split slice), strategy rounds interleave through the
/// stepper, and finished requests' leftover budgets are reallocated to
/// running ones between steps.
pub fn run_traffic(
    executor: &Executor,
    mode: &Mode,
    singles: Vec<Request>,
    chains: Vec<ChainSpec>,
    concurrency: usize,
) -> Result<ServeReport> {
    let n = singles.len();
    let cap = concurrency.max(1);
    let start = Instant::now();
    let mut stepper =
        Stepper::new(executor.clone()).with_reallocator(Box::new(EvenShareReallocator));
    // (routed, predicted_ms) captured at admission, indexed by seq tag
    let mut admitted_meta: Vec<(bool, Option<f64>)> = vec![(false, None); n];
    let mut served: Vec<Served> = Vec::with_capacity(n);
    let chain_metrics = ChainMetrics::new();
    let chain_arrivals: Vec<f64> = chains.iter().map(|c| c.arrival_ms).collect();
    let mut runs: Vec<ChainRun> = chains
        .into_iter()
        .map(|spec| ChainRun {
            state: Some(ChainState::new(spec)),
            pending: None,
            outcome: None,
        })
        .collect();
    let total_chains = runs.len();
    let mut next = 0usize; // next single to admit
    let mut next_chain = 0usize; // next chain to first-admit
    let mut chains_done = 0usize;
    // chains whose next step became admissible when the previous one
    // completed — admitted before waiting new arrivals
    let mut ready_chains: Vec<usize> = Vec::new();

    // Record completions as soon as an advance produced them, so
    // `e2e_ms` is stamped at actual completion — not after the next
    // admission's (blocking, possibly engine-bound) routing calls.
    // Chain completions fold into their ChainState and queue the
    // chain's next step for admission.
    let drain = |stepper: &mut Stepper,
                 served: &mut Vec<Served>,
                 meta: &[(bool, Option<f64>)],
                 runs: &mut [ChainRun],
                 ready: &mut Vec<usize>,
                 chains_done: &mut usize| {
        for c in stepper.drain_completed() {
            let done_ms = start.elapsed().as_secs_f64() * 1e3;
            if c.tag & CHAIN_TAG != 0 {
                let ci = ((c.tag & !CHAIN_TAG) >> 16) as usize;
                let run = &mut runs[ci];
                let pending = run.pending.take().expect("chain completion without pending");
                let state = run.state.as_mut().expect("chain completion after finalize");
                state.complete_step(ChainStepResult {
                    strategy: c.strategy_id,
                    routed: pending.routed,
                    correct: c.outcome.is_correct(&pending.query.answer),
                    tokens: c.outcome.tokens,
                    budget_exhausted: c.outcome.budget_exhausted,
                    grant: pending.grant,
                    service_ms: c.outcome.latency_ms,
                    answer: c.outcome.answer,
                });
                chain_metrics.steps_completed.inc();
                if state.finished() {
                    let state = run.state.take().expect("state present");
                    let e2e = done_ms - state.spec.arrival_ms.min(done_ms);
                    finalize_chain(
                        &chain_metrics,
                        run,
                        state.into_outcome(e2e, false),
                        chains_done,
                    );
                } else {
                    ready.push(ci);
                }
            } else {
                let idx = c.tag as usize;
                let req = &singles[idx];
                let (routed, predicted_ms) = meta[idx];
                served.push(Served {
                    query_id: req.query.id.clone(),
                    strategy: c.strategy_id,
                    routed,
                    correct: c.outcome.is_correct(&req.query.answer),
                    tokens: c.outcome.tokens,
                    budget_exhausted: c.outcome.budget_exhausted,
                    preempted: c.outcome.preempted,
                    stopped_early: c.outcome.stopped_early,
                    predicted_ms,
                    service_ms: c.outcome.latency_ms,
                    e2e_ms: done_ms - req.arrival_ms.min(done_ms),
                });
            }
        }
    };

    while served.len() < n || chains_done < total_chains {
        let now_ms = start.elapsed().as_secs_f64() * 1e3;
        // In-flight chains' next steps take freed slots first.
        while !ready_chains.is_empty() && stepper.in_flight() < cap {
            let ci = ready_chains.remove(0);
            if admit_chain_step(
                executor,
                mode,
                &mut stepper,
                &chain_metrics,
                &mut runs[ci],
                ci,
                now_ms,
                &mut chains_done,
            )? {
                stepper.advance(Some(Duration::ZERO))?;
                drain(
                    &mut stepper,
                    &mut served,
                    &admitted_meta,
                    &mut runs,
                    &mut ready_chains,
                    &mut chains_done,
                );
            }
        }
        // Admit due arrivals (singles and chain first steps) into free
        // slots, in global arrival order. Each admission's routing is a
        // blocking engine round-trip on this pump thread, so between
        // admissions give in-flight machines a non-blocking advance:
        // arrived replies are harvested and the next rounds (including
        // the just-admitted machine's first step) are submitted,
        // overlapping with the next routing call.
        while stepper.in_flight() < cap {
            let single_due = next < n && singles[next].arrival_ms <= now_ms;
            let chain_due = next_chain < total_chains && chain_arrivals[next_chain] <= now_ms;
            let take_chain = match (single_due, chain_due) {
                (false, false) => break,
                (true, false) => false,
                (false, true) => true,
                (true, true) => chain_arrivals[next_chain] <= singles[next].arrival_ms,
            };
            if take_chain {
                let ci = next_chain;
                next_chain += 1;
                chain_metrics.chains_admitted.inc();
                if !admit_chain_step(
                    executor,
                    mode,
                    &mut stepper,
                    &chain_metrics,
                    &mut runs[ci],
                    ci,
                    now_ms,
                    &mut chains_done,
                )? {
                    continue;
                }
            } else {
                let req = &singles[next];
                let (strategy, routed, predicted_ms) = route(executor, mode, req)?;
                admitted_meta[next] = (routed, predicted_ms);
                stepper.admit(Ticket {
                    query: req.query.query.clone(),
                    strategy,
                    budget: req.budget.clone(),
                    tag: next as u64,
                })?;
                next += 1;
            }
            stepper.advance(Some(Duration::ZERO))?;
            drain(
                &mut stepper,
                &mut served,
                &admitted_meta,
                &mut runs,
                &mut ready_chains,
                &mut chains_done,
            );
        }
        if served.len() >= n && chains_done >= total_chains {
            break;
        }
        let next_arrival = match (next < n, next_chain < total_chains) {
            (true, true) => Some(singles[next].arrival_ms.min(chain_arrivals[next_chain])),
            (true, false) => Some(singles[next].arrival_ms),
            (false, true) => Some(chain_arrivals[next_chain]),
            (false, false) => None,
        };
        if stepper.in_flight() == 0 {
            if !ready_chains.is_empty() {
                // next admission attempt happens at loop top
                continue;
            }
            // Idle with work left: sleep until the next arrival is due.
            match next_arrival {
                Some(a) => {
                    let wait_ms = (a - now_ms).max(0.0);
                    if wait_ms > 0.0 {
                        std::thread::sleep(Duration::from_micros((wait_ms * 1e3) as u64));
                    }
                    continue;
                }
                // nothing in flight, nothing queued, nothing arriving —
                // every item must be terminal
                None => break,
            }
        }
        // Pump; if an admission could become due while we wait, cap the
        // wait so arrivals are admitted on time.
        let wait = match next_arrival {
            Some(a) if stepper.in_flight() < cap => Some(Duration::from_micros(
                ((a - now_ms).max(0.0) * 1e3) as u64 + 1,
            )),
            _ => None,
        };
        let _progress: Progress = stepper.advance(wait)?;
        drain(
            &mut stepper,
            &mut served,
            &admitted_meta,
            &mut runs,
            &mut ready_chains,
            &mut chains_done,
        );
    }

    let wall_s = start.elapsed().as_secs_f64();
    // per-engine utilization + placement counters, when the executor
    // fronts a sharded pool (None on the classic single-engine path)
    let pool = executor.engine.pool_report();
    let chain = (total_chains > 0).then(|| chain_metrics.to_json());
    let chain_outcomes: Vec<ChainOutcome> =
        runs.into_iter().filter_map(|r| r.outcome).collect();
    Ok(ServeReport::new(
        served,
        chain_outcomes,
        wall_s,
        stepper.metrics.to_json(),
        chain,
        pool,
    ))
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    pub served: Vec<Served>,
    /// Per-chain terminal records, in chain index order (empty when the
    /// run carried no chains).
    pub chains: Vec<ChainOutcome>,
    pub wall_s: f64,
    /// Continuation-executor counters (steps, submissions, reallocation
    /// grants) captured at the end of the run.
    pub stepper: Value,
    /// Chain-tier counters ([`ChainMetrics`]) when the run carried
    /// chains: completions, goodput, cross-step realloc grants, chain
    /// e2e percentiles.
    pub chain: Option<Value>,
    /// Pool placement + per-engine utilization
    /// ([`crate::engine::pool::PoolRouter::report`]) when serving from a
    /// sharded [`crate::engine::pool::EnginePool`] of 2+ engines.
    pub pool: Option<Value>,
}

impl ServeReport {
    fn new(
        served: Vec<Served>,
        chains: Vec<ChainOutcome>,
        wall_s: f64,
        stepper: Value,
        chain: Option<Value>,
        pool: Option<Value>,
    ) -> ServeReport {
        ServeReport {
            served,
            chains,
            wall_s,
            stepper,
            chain,
            pool,
        }
    }

    pub fn to_json(&self) -> Value {
        let n = self.served.len().max(1);
        let correct = self.served.iter().filter(|s| s.correct).count();
        let routed = self.served.iter().filter(|s| s.routed).count();
        let exhausted = self.served.iter().filter(|s| s.budget_exhausted).count();
        let preempted = self.served.iter().filter(|s| s.preempted).count();
        let stopped = self.served.iter().filter(|s| s.stopped_early).count();
        let tokens: Vec<f64> = self.served.iter().map(|s| s.tokens as f64).collect();
        // realized-vs-predicted latency over adaptively routed requests
        let pred_pairs: Vec<(f64, f64)> = self
            .served
            .iter()
            .filter_map(|s| s.predicted_ms.map(|p| (p, s.service_ms)))
            .collect();
        let pred_json = if pred_pairs.is_empty() {
            Value::obj().with("n", 0usize)
        } else {
            let abs_err: Vec<f64> = pred_pairs.iter().map(|&(p, r)| (r - p).abs()).collect();
            let ratio: Vec<f64> = pred_pairs
                .iter()
                .map(|&(p, r)| r / p.max(1e-9))
                .collect();
            Value::obj()
                .with("n", pred_pairs.len())
                .with("mean_abs_err_ms", stats::mean(&abs_err))
                .with("mean_realized_over_predicted", stats::mean(&ratio))
        };
        let service = Histogram::new();
        let e2e = Histogram::new();
        for s in &self.served {
            service.record(s.service_ms);
            e2e.record(s.e2e_ms);
        }
        let mut by_strategy: HashMap<&str, usize> = HashMap::new();
        for s in &self.served {
            *by_strategy.entry(s.strategy.as_str()).or_default() += 1;
        }
        let mut strat_json = Value::obj();
        let mut keys: Vec<&&str> = by_strategy.keys().collect();
        keys.sort();
        for k in keys {
            strat_json.set(k, by_strategy[*k]);
        }
        let mut v = Value::obj()
            .with("requests", self.served.len())
            .with("wall_s", self.wall_s)
            .with("throughput_rps", self.served.len() as f64 / self.wall_s.max(1e-9))
            .with("accuracy", correct as f64 / n as f64)
            .with("avg_tokens", stats::mean(&tokens))
            .with("adaptive_fraction", routed as f64 / n as f64)
            .with("budget_exhausted_fraction", exhausted as f64 / n as f64)
            .with("preempted_count", preempted)
            .with("preempted_fraction", preempted as f64 / n as f64)
            .with("stopped_early_fraction", stopped as f64 / n as f64)
            .with("latency_prediction", pred_json)
            .with("stepper", self.stepper.clone())
            .with("service_ms", service.summary().to_json())
            .with("e2e_ms", e2e.summary().to_json())
            .with("selection", strat_json);
        if let Some(chain) = &self.chain {
            v.set("chain", chain.clone());
        }
        if let Some(pool) = &self.pool {
            v.set("pool", pool.clone());
        }
        v
    }

    pub fn log_summary(&self, label: &str) {
        let v = self.to_json();
        log_info!(
            "serve[{label}]: {} reqs in {:.1}s ({:.2} rps), acc {:.3}, avg tokens {:.0}, \
             e2e p50 {:.0}ms p95 {:.0}ms, adaptive {:.0}%, budget-hit {:.0}%, preempted {:.0}%, \
             realloc grants {:.0}",
            self.served.len(),
            self.wall_s,
            v.req_f64("throughput_rps").unwrap_or(0.0),
            v.req_f64("accuracy").unwrap_or(0.0),
            v.req_f64("avg_tokens").unwrap_or(0.0),
            v.req("e2e_ms").and_then(|h| h.req_f64("p50")).unwrap_or(0.0),
            v.req("e2e_ms").and_then(|h| h.req_f64("p95")).unwrap_or(0.0),
            100.0 * v.req_f64("adaptive_fraction").unwrap_or(0.0),
            100.0 * v.req_f64("budget_exhausted_fraction").unwrap_or(0.0),
            100.0 * v.req_f64("preempted_fraction").unwrap_or(0.0),
            v.req("stepper")
                .and_then(|s| s.req_f64("realloc_grants"))
                .unwrap_or(0.0),
        );
        if let Some(chain) = &self.chain {
            log_info!(
                "serve[{label}]: chains {:.0}/{:.0} completed ({:.0} exhausted), goodput {:.3}, \
                 {:.0} cross-step grants ({:.0} tokens, {:.0}ms), chain e2e p50 {:.0}ms",
                chain.req_f64("chains_completed").unwrap_or(0.0),
                chain.req_f64("chains_admitted").unwrap_or(0.0),
                chain.req_f64("chains_exhausted").unwrap_or(0.0),
                chain.req_f64("goodput").unwrap_or(0.0),
                chain.req_f64("realloc_grants").unwrap_or(0.0),
                chain.req_f64("realloc_tokens_granted").unwrap_or(0.0),
                chain.req_f64("realloc_ms_granted").unwrap_or(0.0),
                chain
                    .req("e2e_ms")
                    .and_then(|h| h.req_f64("p50"))
                    .unwrap_or(0.0),
            );
        }
        if let Some(pool) = &self.pool {
            log_info!(
                "serve[{label}]: pool {} engines, balance ratio {:.2}, placements {:.0} \
                 ({:.0} deadline tiebreaks)",
                pool.req_f64("engines").unwrap_or(0.0),
                pool.req_f64("balance_ratio").unwrap_or(1.0),
                pool.req_f64("placements").unwrap_or(0.0),
                pool.req_f64("deadline_tiebreaks").unwrap_or(0.0),
            );
        }
    }
}
