//! The serving driver: workers pulling scheduled requests through the
//! router + strategy executor, with end-to-end latency accounting.
//!
//! This is the deployment shape of the paper's system: requests arrive,
//! the router picks `s*(x)` under the operator's (λ_T, λ_L) *and* the
//! request's budget (deadline-infeasible strategies are excluded via the
//! budget-bucket cost model), the strategy executes against the shared
//! engine (whose batcher merges concurrent generation) under the
//! request's [`Budget`] — deadlines are enforced all the way down to
//! *mid-call* engine preemption — and the driver reports accuracy /
//! tokens / latency percentiles / throughput plus budget-enforcement
//! fractions, preemption counts and realized-vs-predicted latency.

use crate::error::Result;
use crate::metrics::Histogram;
use crate::router::{Lambdas, Router};
use crate::server::loadgen::Request;
use crate::strategies::{Executor, Strategy};
use crate::util::json::Value;
use crate::util::stats;
use crate::log_info;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Routing mode for the driver.
pub enum Mode {
    /// Query-adaptive routing (the paper's system).
    Adaptive(Router, Lambdas),
    /// Fixed strategy baseline.
    Static(Strategy),
}

/// Per-request record.
#[derive(Debug, Clone)]
pub struct Served {
    pub query_id: String,
    pub strategy: String,
    /// Strategy chosen by the adaptive router (vs a static baseline).
    pub routed: bool,
    pub correct: bool,
    pub tokens: usize,
    /// The request's budget ran out mid-strategy.
    pub budget_exhausted: bool,
    /// The engine preempted a generation call mid-decode for this
    /// request (deadline, cancel, or token cap).
    pub preempted: bool,
    /// The strategy finished before its configured work (early-stop vote
    /// decided, deadline-aware round truncation).
    pub stopped_early: bool,
    /// Router-predicted strategy latency for this request (budget-bucket
    /// cost model), when adaptively routed — compared against the
    /// realized `service_ms` in the report.
    pub predicted_ms: Option<f64>,
    /// Strategy execution time (ms).
    pub service_ms: f64,
    /// Queue wait + execution (ms) — what the user experiences.
    pub e2e_ms: f64,
}

/// Pre-compile every executable a strategy set can touch by running each
/// strategy once on a throwaway query. Without this, the first live
/// requests pay seconds of lazy XLA compilation (measured: e2e p50
/// 12.6s → 0.4s for the adaptive mix on this testbed).
pub fn warmup(executor: &Executor, strategies: &[Strategy], query: &str) -> Result<()> {
    let t0 = Instant::now();
    for s in strategies {
        let _ = executor.run(s, query)?;
    }
    log_info!(
        "serve warmup: {} strategies in {:.1}s",
        strategies.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Run the driver over a schedule. `workers` controls concurrency (the
/// engine's scheduler coalesces concurrent generate *and* PRM/embed
/// calls). The schedule is shared read-only (`Arc<Vec<_>>`); workers
/// claim indices through one atomic cursor and accumulate their own
/// result vectors — the serve hot path touches no shared lock.
pub fn run(
    executor: &Executor,
    mode: &Mode,
    requests: Vec<Request>,
    workers: usize,
) -> Result<ServeReport> {
    let n = requests.len();
    let start = Instant::now();
    let queue: Arc<Vec<Request>> = Arc::new(requests);
    let next_seq = Arc::new(AtomicUsize::new(0));
    let mut served: Vec<Served> = Vec::with_capacity(n);

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let queue = queue.clone();
            let next_seq = next_seq.clone();
            let executor = executor.clone();
            let mode_ref = &*mode;
            handles.push(scope.spawn(move || -> Result<Vec<Served>> {
                let mut mine = Vec::new();
                loop {
                    let idx = next_seq.fetch_add(1, Ordering::SeqCst);
                    let req = match queue.get(idx) {
                        Some(r) => r,
                        None => return Ok(mine),
                    };
                    // open-loop: wait for the arrival time
                    let now_ms = start.elapsed().as_secs_f64() * 1e3;
                    if req.arrival_ms > now_ms {
                        std::thread::sleep(Duration::from_micros(
                            ((req.arrival_ms - now_ms) * 1e3) as u64,
                        ));
                    }
                    let arrived = start.elapsed().as_secs_f64() * 1e3;
                    let mut one = serve_one(&executor, mode_ref, req)?;
                    let done = start.elapsed().as_secs_f64() * 1e3;
                    one.e2e_ms = done - req.arrival_ms.min(arrived);
                    mine.push(one);
                }
            }));
        }
        for h in handles {
            served.extend(h.join().expect("worker panicked")?);
        }
        Ok(())
    })?;

    let wall_s = start.elapsed().as_secs_f64();
    Ok(ServeReport::new(served, wall_s))
}

fn serve_one(executor: &Executor, mode: &Mode, req: &Request) -> Result<Served> {
    let (strategy, routed, predicted_ms) = match mode {
        Mode::Adaptive(router, lambdas) => {
            // budget-aware selection: the budget-bucket cost table prices
            // each strategy under this request's deadline, and strategies
            // that cannot meet it are excluded when an alternative can
            let score =
                router.select_budgeted(&executor.engine, &req.query.query, *lambdas, &req.budget)?;
            (score.strategy, true, Some(score.cost.latency_ms))
        }
        Mode::Static(s) => (s.clone(), false, None),
    };
    let outcome = executor.run_budgeted(&strategy, &req.query.query, req.budget.clone())?;
    Ok(Served {
        query_id: req.query.id.clone(),
        strategy: strategy.id(),
        routed,
        correct: outcome.is_correct(&req.query.answer),
        tokens: outcome.tokens,
        budget_exhausted: outcome.budget_exhausted,
        preempted: outcome.preempted,
        stopped_early: outcome.stopped_early,
        predicted_ms,
        service_ms: outcome.latency_ms,
        e2e_ms: outcome.latency_ms, // overwritten by the driver
    })
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    pub served: Vec<Served>,
    pub wall_s: f64,
}

impl ServeReport {
    fn new(served: Vec<Served>, wall_s: f64) -> ServeReport {
        ServeReport { served, wall_s }
    }

    pub fn to_json(&self) -> Value {
        let n = self.served.len().max(1);
        let correct = self.served.iter().filter(|s| s.correct).count();
        let routed = self.served.iter().filter(|s| s.routed).count();
        let exhausted = self.served.iter().filter(|s| s.budget_exhausted).count();
        let preempted = self.served.iter().filter(|s| s.preempted).count();
        let stopped = self.served.iter().filter(|s| s.stopped_early).count();
        let tokens: Vec<f64> = self.served.iter().map(|s| s.tokens as f64).collect();
        // realized-vs-predicted latency over adaptively routed requests
        let pred_pairs: Vec<(f64, f64)> = self
            .served
            .iter()
            .filter_map(|s| s.predicted_ms.map(|p| (p, s.service_ms)))
            .collect();
        let pred_json = if pred_pairs.is_empty() {
            Value::obj().with("n", 0usize)
        } else {
            let abs_err: Vec<f64> = pred_pairs.iter().map(|&(p, r)| (r - p).abs()).collect();
            let ratio: Vec<f64> = pred_pairs
                .iter()
                .map(|&(p, r)| r / p.max(1e-9))
                .collect();
            Value::obj()
                .with("n", pred_pairs.len())
                .with("mean_abs_err_ms", stats::mean(&abs_err))
                .with("mean_realized_over_predicted", stats::mean(&ratio))
        };
        let service = Histogram::new();
        let e2e = Histogram::new();
        for s in &self.served {
            service.record(s.service_ms);
            e2e.record(s.e2e_ms);
        }
        let mut by_strategy: HashMap<&str, usize> = HashMap::new();
        for s in &self.served {
            *by_strategy.entry(s.strategy.as_str()).or_default() += 1;
        }
        let mut strat_json = Value::obj();
        let mut keys: Vec<&&str> = by_strategy.keys().collect();
        keys.sort();
        for k in keys {
            strat_json.set(k, by_strategy[*k]);
        }
        Value::obj()
            .with("requests", self.served.len())
            .with("wall_s", self.wall_s)
            .with("throughput_rps", self.served.len() as f64 / self.wall_s.max(1e-9))
            .with("accuracy", correct as f64 / n as f64)
            .with("avg_tokens", stats::mean(&tokens))
            .with("adaptive_fraction", routed as f64 / n as f64)
            .with("budget_exhausted_fraction", exhausted as f64 / n as f64)
            .with("preempted_count", preempted)
            .with("preempted_fraction", preempted as f64 / n as f64)
            .with("stopped_early_fraction", stopped as f64 / n as f64)
            .with("latency_prediction", pred_json)
            .with("service_ms", service.summary().to_json())
            .with("e2e_ms", e2e.summary().to_json())
            .with("selection", strat_json)
    }

    pub fn log_summary(&self, label: &str) {
        let v = self.to_json();
        log_info!(
            "serve[{label}]: {} reqs in {:.1}s ({:.2} rps), acc {:.3}, avg tokens {:.0}, \
             e2e p50 {:.0}ms p95 {:.0}ms, adaptive {:.0}%, budget-hit {:.0}%, preempted {:.0}%",
            self.served.len(),
            self.wall_s,
            v.req_f64("throughput_rps").unwrap_or(0.0),
            v.req_f64("accuracy").unwrap_or(0.0),
            v.req_f64("avg_tokens").unwrap_or(0.0),
            v.req("e2e_ms").and_then(|h| h.req_f64("p50")).unwrap_or(0.0),
            v.req("e2e_ms").and_then(|h| h.req_f64("p95")).unwrap_or(0.0),
            100.0 * v.req_f64("adaptive_fraction").unwrap_or(0.0),
            100.0 * v.req_f64("budget_exhausted_fraction").unwrap_or(0.0),
            100.0 * v.req_f64("preempted_fraction").unwrap_or(0.0),
        );
    }
}
