//! CLI command implementations — the wiring between config, engine,
//! strategies, probe pipeline and figures.

use crate::cli::Args;
use crate::config::{BackendKind, Config};
use crate::costmodel::CostModel;
use crate::data::Splits;
use crate::engine::{EmbedKind, Engine, EngineHandle, EnginePool};
use crate::error::{Error, Result};
use crate::figures::{self, EvalTable};
use crate::matrix::{self, Matrix};
use crate::probe::{train::build_rows, train::embed_queries, CalibratedProbe, FeatureBuilder,
                   ProbeCheckpoint};
use crate::router::{Lambdas, Router};
use crate::server::chain::{self, ChainSpec};
use crate::server::driver::{self, Mode};
use crate::server::loadgen::{self, Arrivals};
use crate::strategies::{Budget, Executor, Strategy};
use crate::tokenizer::Tokenizer;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::log_info;
use std::path::{Path, PathBuf};

const COMMON_VALUES: &[&str] = &["config", "artifacts", "results"];

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => Config::from_file(Path::new(path))?,
        None => Config::default(),
    };
    if let Some(a) = args.opt_str("artifacts") {
        cfg.paths.artifacts = a.into();
    }
    if let Some(r) = args.opt_str("results") {
        cfg.paths.results = r.into();
    }
    Ok(cfg)
}

fn matrix_path(cfg: &Config, split: &str) -> PathBuf {
    cfg.paths.results.join(format!("matrix_{split}.jsonl"))
}

fn probe_stem(cfg: &Config, kind: EmbedKind) -> PathBuf {
    let name = match kind {
        EmbedKind::Pool => "probe_pool",
        EmbedKind::Small => "probe_small",
    };
    cfg.paths.results.join(name)
}

fn make_executor(
    cfg: &Config,
    handle: EngineHandle,
    clock: crate::util::clock::SharedClock,
) -> Executor {
    let mut ex = Executor::new(handle, clock, cfg.engine.temperature);
    ex.beam_max_rounds = cfg.space.beam_max_rounds;
    ex
}

fn feature_builder(handle: &EngineHandle) -> Result<FeatureBuilder> {
    let info = handle.info()?;
    // features = d_model + strategy scalars + method one-hot + query len;
    // the non-embedding width is registry-driven (see FeatureBuilder).
    let d_model = info
        .req("shapes")
        .ok()
        .and_then(|s| s.get("probe_features"))
        .and_then(Value::as_usize)
        .and_then(|f| f.checked_sub(FeatureBuilder::aux_dim()))
        .ok_or_else(|| {
            Error::internal(
                "engine info missing probe_features (or artifacts predate the \
                 current decoding-method registry — rerun `make artifacts`)",
            )
        })?;
    Ok(FeatureBuilder::new(d_model, 10))
}

// ---------------------------------------------------------------------
// collect
// ---------------------------------------------------------------------

pub fn cmd_collect(raw: &[String]) -> Result<()> {
    let values: Vec<&str> = [COMMON_VALUES, &["split", "repeats"]].concat();
    let args = Args::parse(raw, &values, &["sim"])?;
    let mut cfg = load_config(&args)?;
    if args.flag("sim") {
        cfg.engine.sim_clock = true;
    }
    let engine = Engine::start(&cfg)?;
    let executor = make_executor(&cfg, engine.handle(), engine.clock.clone());
    let splits = Splits::load(&cfg.paths().data_dir())?;
    let strategies = Strategy::enumerate(&cfg.space);

    let which = args.str_or("split", "all");
    let selected: Vec<&str> = match which {
        "all" => vec!["train", "calib", "test"],
        s => vec![s],
    };
    for split in selected {
        let queries = splits.by_name(split)?;
        let repeats = args.usize_or(
            "repeats",
            if split == "train" {
                cfg.collect.repeats_train
            } else {
                cfg.collect.repeats_eval
            },
        )?;
        matrix::collect(
            &executor,
            queries,
            split,
            &strategies,
            repeats,
            &matrix_path(&cfg, split),
        )?;
    }
    log_info!("collect done; engine info: {}", engine.handle().info()?.dumps());
    Ok(())
}

// ---------------------------------------------------------------------
// train-probe
// ---------------------------------------------------------------------

pub fn cmd_train_probe(raw: &[String]) -> Result<()> {
    let values: Vec<&str> = [COMMON_VALUES, &["embedding", "epochs"]].concat();
    let args = Args::parse(raw, &values, &[])?;
    let mut cfg = load_config(&args)?;
    if let Some(e) = args.opt_str("epochs") {
        cfg.probe.epochs = e
            .parse()
            .map_err(|_| Error::Config("--epochs must be an integer".into()))?;
    }
    let engine = Engine::start(&cfg)?;
    let splits = Splits::load(&cfg.paths().data_dir())?;
    let train_matrix = require_matrix(&cfg, "train")?;
    let calib_matrix = require_matrix(&cfg, "calib")?;
    let fb = feature_builder(&engine.handle())?;

    let kinds: Vec<EmbedKind> = match args.str_or("embedding", "both") {
        "pool" => vec![EmbedKind::Pool],
        "small" => vec![EmbedKind::Small],
        "both" => vec![EmbedKind::Pool, EmbedKind::Small],
        other => return Err(Error::Config(format!("unknown embedding '{other}'"))),
    };
    for kind in kinds {
        let (probe, report) = crate::probe::train_probe(
            &engine.handle(),
            &train_matrix,
            &calib_matrix,
            &splits.train,
            &splits.calib,
            &fb,
            kind,
            &cfg.probe,
            cfg.seed,
        )?;
        let stem = probe_stem(&cfg, kind);
        ProbeCheckpoint::save(&probe, &stem)?;
        // user-supplied --results can produce a stem with no final path
        // component (e.g. `--results ..`); that's a bad artifact path,
        // not a panic
        let file_name = stem.file_name().ok_or_else(|| {
            Error::Artifact(format!(
                "probe checkpoint stem '{}' has no file name — check --results",
                stem.display()
            ))
        })?;
        std::fs::write(
            stem.with_file_name(format!("{}_report.json", file_name.to_string_lossy())),
            report.pretty(),
        )?;
        log_info!("saved probe checkpoint {}", stem.display());
    }

    // cost model (train-split means) — shared by routing and figures
    let cm = CostModel::fit(&train_matrix);
    std::fs::write(
        cfg.paths.results.join("cost_model.json"),
        cm.to_json().pretty(),
    )?;
    log_info!("saved cost model ({} strategies)", cm.len());
    Ok(())
}

fn require_matrix(cfg: &Config, split: &str) -> Result<Matrix> {
    let path = matrix_path(cfg, split);
    let m = Matrix::load(&path)?;
    if m.is_empty() {
        return Err(Error::artifact(format!(
            "matrix {} is missing or empty — run `ttc collect` first",
            path.display()
        )));
    }
    Ok(m)
}

// ---------------------------------------------------------------------
// figures
// ---------------------------------------------------------------------

/// Build the dense test-split table for one probe/embedding.
pub fn build_eval_table(
    cfg: &Config,
    engine: &Engine,
    probe: &CalibratedProbe,
    test_matrix: &Matrix,
    splits: &Splits,
    costs: &CostModel,
) -> Result<EvalTable> {
    probe.install(&engine.handle())?;
    let fb = feature_builder(&engine.handle())?;
    let tokenizer = Tokenizer::new();
    let strategies = Strategy::enumerate(&cfg.space);
    let embs = embed_queries(&engine.handle(), &tokenizer, probe.embed_kind, &splits.test)?;

    let mut probs = Vec::with_capacity(splits.test.len());
    for q in &splits.test {
        let emb = &embs[&q.id];
        let qlen = tokenizer.encode(&q.query)?.len();
        let feats: Vec<Vec<f32>> = strategies.iter().map(|s| fb.build(emb, s, qlen)).collect();
        probs.push(probe.predict(&engine.handle(), feats)?);
    }
    EvalTable::new(splits.test.to_vec(), strategies, test_matrix, probs, costs)
}

pub fn cmd_figures(raw: &[String]) -> Result<()> {
    let values: Vec<&str> = [COMMON_VALUES, &["fig"]].concat();
    let args = Args::parse(raw, &values, &[])?;
    let cfg = load_config(&args)?;
    let engine = Engine::start(&cfg)?;
    let splits = Splits::load(&cfg.paths().data_dir())?;
    let test_matrix = require_matrix(&cfg, "test")?;
    let calib_matrix = require_matrix(&cfg, "calib")?;
    let train_matrix = require_matrix(&cfg, "train")?;
    let costs = CostModel::fit(&train_matrix);

    let probe_pool = ProbeCheckpoint::load(&probe_stem(&cfg, EmbedKind::Pool))?;
    let table_pool = build_eval_table(&cfg, &engine, &probe_pool, &test_matrix, &splits, &costs)?;

    let which = args.str_or("fig", "all");
    let dir = cfg.paths.results.join("figures");
    std::fs::create_dir_all(&dir)?;
    let want = |id: &str| which == "all" || which == id;
    let mut emitted = Vec::new();

    if want("1a") {
        figures::sweeps::fig1(&table_pool, &cfg.sweep, 'a', &dir.join("fig1a.csv"))?;
        emitted.push("1a");
    }
    if want("1b") {
        figures::sweeps::fig1(&table_pool, &cfg.sweep, 'b', &dir.join("fig1b.csv"))?;
        emitted.push("1b");
    }
    if want("2") {
        figures::sweeps::fig2(&table_pool, &cfg.sweep, &dir.join("fig2.csv"))?;
        emitted.push("2");
    }
    if want("3") {
        // calibration pairs on the calib split with the pool probe
        probe_pool.install(&engine.handle())?;
        let fb = feature_builder(&engine.handle())?;
        let tokenizer = Tokenizer::new();
        let calib_emb = embed_queries(
            &engine.handle(),
            &tokenizer,
            probe_pool.embed_kind,
            &splits.calib,
        )?;
        let (feats, labels) = build_rows(&calib_matrix, &splits.calib, &calib_emb, &fb, &tokenizer)?;
        let logits = engine.handle().probe_fwd(feats)?;
        let pairs: Vec<(f64, f64)> = logits
            .iter()
            .zip(&labels)
            .map(|(&z, &y)| (probe_pool.platt.prob(z as f64), y as f64))
            .collect();
        let (_, ece) = figures::calibration::fig3(&pairs, 10, &dir.join("fig3.csv"))?;
        log_info!("fig3: post-Platt ECE = {ece:.4}");
        emitted.push("3");
    }
    if want("4") {
        figures::methods::fig4(&table_pool, &dir.join("fig4.csv"))?;
        emitted.push("4");
    }
    if want("5") || want("6") {
        let probe_small = ProbeCheckpoint::load(&probe_stem(&cfg, EmbedKind::Small))?;
        let table_small =
            build_eval_table(&cfg, &engine, &probe_small, &test_matrix, &splits, &costs)?;
        if want("5") {
            figures::sweeps::fig1(&table_small, &cfg.sweep, 'a', &dir.join("fig5.csv"))?;
            emitted.push("5");
        }
        if want("6") {
            figures::sweeps::fig1(&table_small, &cfg.sweep, 'b', &dir.join("fig6.csv"))?;
            emitted.push("6");
        }
    }
    if want("7") {
        figures::sweeps::fig78(&table_pool, &cfg.sweep, 7, &dir.join("fig7.csv"))?;
        emitted.push("7");
    }
    if want("8") {
        figures::sweeps::fig78(&table_pool, &cfg.sweep, 8, &dir.join("fig8.csv"))?;
        emitted.push("8");
    }
    if want("9") {
        figures::beam::fig9(&table_pool, &cfg.sweep, &dir.join("fig9.csv"))?;
        emitted.push("9");
    }
    if which == "all" {
        write_summary(&cfg, &table_pool, &dir)?;
    }
    log_info!("figures emitted: {emitted:?} -> {}", dir.display());
    Ok(())
}

/// SUMMARY.md: headline comparisons for EXPERIMENTS.md.
fn write_summary(cfg: &Config, table: &EvalTable, dir: &Path) -> Result<()> {
    use std::fmt::Write as _;
    let mut md = String::new();
    writeln!(md, "# Figure summary (auto-generated by `ttc figures`)\n").unwrap();
    writeln!(md, "Test queries: {}\n", table.n_queries()).unwrap();
    writeln!(md, "## Static strategies\n").unwrap();
    writeln!(md, "| strategy | accuracy | tokens | latency ms |").unwrap();
    writeln!(md, "|---|---|---|---|").unwrap();
    for (s, strat) in table.strategies.iter().enumerate() {
        let (a, t, l) = table.static_point(s);
        writeln!(md, "| {} | {a:.3} | {t:.0} | {l:.0} |", strat.id()).unwrap();
    }
    writeln!(md, "\n## Adaptive frontier (λ_L = 0, λ_T swept)\n").unwrap();
    writeln!(md, "| λ_T | accuracy | tokens | latency ms |").unwrap();
    writeln!(md, "|---|---|---|---|").unwrap();
    for &lt in &cfg.sweep.lambda_t {
        let (a, t, l, _) =
            figures::adaptive_point(table, Lambdas::new(lt, 0.0), figures::CostSource::Model);
        writeln!(md, "| {lt:.2e} | {a:.3} | {t:.0} | {l:.0} |").unwrap();
    }
    std::fs::write(dir.join("SUMMARY.md"), md)?;
    Ok(())
}

// ---------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------

/// Assemble the adaptive routing mode: probe checkpoint + cost model +
/// feature builder. Fails when the trained assets are missing.
fn adaptive_mode(cfg: &Config, args: &Args, handle: &EngineHandle) -> Result<Mode> {
    let kind = match args.str_or("embedding", "pool") {
        "small" => EmbedKind::Small,
        _ => EmbedKind::Pool,
    };
    let probe = ProbeCheckpoint::load(&probe_stem(cfg, kind))?;
    probe.install(handle)?;
    let costs = CostModel::from_json(&crate::util::json::parse(
        &std::fs::read_to_string(cfg.paths.results.join("cost_model.json"))
            .map_err(|e| Error::artifact(format!("missing cost_model.json ({e}) — run train-probe")))?,
    )?)?;
    if costs.bucket_edges().is_empty() {
        log_info!(
            "serve: legacy cost_model.json without budget buckets — deadline \
             routing falls back to unbudgeted means (rerun train-probe)"
        );
    } else {
        log_info!(
            "serve: budget-bucket cost model ({} strategies x {} deadline buckets)",
            costs.len(),
            costs.bucket_edges().len()
        );
    }
    let fb = feature_builder(handle)?;
    let router = Router::new(Strategy::enumerate(&cfg.space), probe, costs, fb);
    let lambdas = Lambdas::new(
        args.f64_or("lambda-t", 1e-4)?,
        args.f64_or("lambda-l", 1e-5)?,
    );
    log_info!(
        "serve: adaptive routing with λ_T={} λ_L={}",
        lambdas.token,
        lambdas.latency
    );
    Ok(Mode::Adaptive(router, lambdas))
}

/// Shared `--cache` / `--cache-entries` / `--cache-shards` handling for
/// `serve` and `engine-serve`: the cross-request cache tier
/// (`docs/caching.md`), default-off. `--cache-entries`/`--cache-shards`
/// imply `--cache`.
fn apply_cache_args(args: &Args, cfg: &mut Config) -> Result<()> {
    if args.flag("cache")
        || args.opt_str("cache-entries").is_some()
        || args.opt_str("cache-shards").is_some()
    {
        cfg.engine.cache.enabled = true;
    }
    cfg.engine.cache.max_entries = args.usize_or("cache-entries", cfg.engine.cache.max_entries)?;
    cfg.engine.cache.shards = args.usize_or("cache-shards", cfg.engine.cache.shards)?;
    Ok(())
}

/// Parse `--arrivals poisson | gamma:<shape> | onoff:<burst>:<idle_s>`
/// into an open-loop arrival process at `rate` req/s (see
/// [`Arrivals`]). Gamma shape < 1 is burstier than Poisson; on-off
/// inserts an idle gap after every `burst` arrivals.
fn parse_arrivals(spec: &str, rate: f64) -> Result<Arrivals> {
    let bad = || {
        Error::Config(format!(
            "bad --arrivals '{spec}'; expected poisson | gamma:<shape> | onoff:<burst>:<idle_s>"
        ))
    };
    let mut parts = spec.split(':');
    match parts.next() {
        Some("poisson") => {
            if parts.next().is_some() {
                return Err(bad());
            }
            Ok(Arrivals::Poisson { rate })
        }
        Some("gamma") => {
            let shape: f64 = parts
                .next()
                .ok_or_else(bad)?
                .parse()
                .map_err(|_| bad())?;
            if parts.next().is_some() || !shape.is_finite() || shape <= 0.0 {
                return Err(bad());
            }
            Ok(Arrivals::Gamma { rate, shape })
        }
        Some("onoff") => {
            let burst: usize = parts
                .next()
                .ok_or_else(bad)?
                .parse()
                .map_err(|_| bad())?;
            let idle_s: f64 = parts
                .next()
                .ok_or_else(bad)?
                .parse()
                .map_err(|_| bad())?;
            if parts.next().is_some() || burst == 0 || !idle_s.is_finite() || idle_s <= 0.0 {
                return Err(bad());
            }
            Ok(Arrivals::OnOff {
                rate,
                burst,
                idle_s,
            })
        }
        _ => Err(bad()),
    }
}

pub fn cmd_serve(raw: &[String]) -> Result<()> {
    let values: Vec<&str> = [
        COMMON_VALUES,
        &[
            "rate", "requests", "workers", "lambda-t", "lambda-l", "strategy", "embedding",
            "deadline-ms", "max-tokens", "budget-mix", "engines", "backend", "remote",
            "wire-codec", "cache-entries", "cache-shards", "arrivals", "chains",
            "chain-budget", "trace",
        ],
    ]
    .concat();
    let args = Args::parse(raw, &values, &["sim", "closed", "no-warmup", "cache"])?;
    let mut cfg = load_config(&args)?;
    if args.flag("sim") {
        cfg.engine.sim_clock = true;
    }
    apply_cache_args(&args, &mut cfg)?;
    if let Some(b) = args.opt_str("backend") {
        cfg.engine.backend = BackendKind::parse(b)?;
    }
    cfg.engine.engines = args.usize_or("engines", cfg.engine.engines)?;
    if let Some(c) = args.opt_str("wire-codec") {
        cfg.engine.wire_codec = crate::config::WireCodec::parse(c)?;
    }
    if let Some(remote) = args.opt_str("remote") {
        // --remote host:port[,host:port...] shards the engine pool over
        // a `ttc engine-serve` fleet; slots aimed at the same host share
        // one multiplexed connection
        cfg.engine.backend = BackendKind::Remote;
        cfg.engine.remote_addrs = remote
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if cfg.engine.remote_addrs.is_empty() {
            return Err(Error::Config(
                "--remote needs host:port[,host:port...]".into(),
            ));
        }
        if args.opt_str("engines").is_none() {
            cfg.engine.engines = cfg.engine.remote_addrs.len();
        }
    }
    if cfg.engine.backend == BackendKind::Sim && !cfg.engine.sim_clock {
        // the sim backend computes device calls in microseconds; its
        // latency semantics come from the sim clock's cost model
        log_info!("serve: sim backend — enabling the sim clock for modeled latencies");
        cfg.engine.sim_clock = true;
    }
    let pool = EnginePool::start(&cfg)?;
    let handle = pool.handle();
    log_info!(
        "serve: {} engine(s), {} backend",
        pool.engines(),
        cfg.engine.backend.as_str()
    );
    let executor = make_executor(&cfg, handle.clone(), pool.clock.clone());
    // the sim backend needs no artifacts; synthesize query splits when
    // the data directory is absent so a fresh checkout can serve
    let splits = match Splits::load(&cfg.paths().data_dir()) {
        Ok(s) => s,
        // sim and remote backends need no local artifacts
        Err(e) if cfg.engine.backend != BackendKind::Device => {
            log_info!("serve: no data splits ({e}); synthesizing sim queries");
            Splits::synthesize(cfg.seed)
        }
        Err(e) => return Err(e),
    };

    let mode = match args.opt_str("strategy") {
        Some(id) => {
            let s = Strategy::parse(id)
                .ok_or_else(|| Error::Config(format!("bad strategy id '{id}'")))?;
            log_info!("serve: static strategy {}", s.id());
            Mode::Static(s)
        }
        None => match adaptive_mode(&cfg, &args, &handle) {
            Ok(mode) => mode,
            Err(e) if cfg.engine.backend != BackendKind::Device => {
                // sim/remote backends exist to run engine-full without
                // local trained artifacts; don't let missing probe/cost
                // files kill the run — serve a static baseline instead
                log_info!(
                    "serve: adaptive routing unavailable ({e}); {} backend falls back \
                     to static majority_vote@4 (pass --strategy to choose)",
                    cfg.engine.backend.as_str()
                );
                Mode::Static(Strategy::mv(4))
            }
            Err(e) => return Err(e),
        },
    };

    if !args.flag("no-warmup") {
        let strategies = match &mode {
            Mode::Static(s) => vec![s.clone()],
            Mode::Adaptive(router, _) => router.strategies.clone(),
        };
        driver::warmup(&executor, &strategies, &splits.test[0].query)?;
    }

    let workers = args.usize_or("workers", 4)?;
    if args.flag("closed") && args.opt_str("arrivals").is_some() {
        return Err(Error::Config(
            "--closed replaces --arrivals; pass one or the other".into(),
        ));
    }
    let arrivals = if args.flag("closed") {
        Arrivals::Closed
    } else {
        parse_arrivals(args.str_or("arrivals", "poisson"), args.f64_or("rate", 1.0)?)?
    };
    let mut rng = Rng::new(cfg.seed, 0x5E7E);
    // agentic chains (docs/chains.md): --trace replays an exact chain
    // schedule from a JSON file; --chains N samples heavy-tailed
    // synthetic sessions, each under one --chain-budget pool
    let chains: Vec<ChainSpec> = if let Some(path) = args.opt_str("trace") {
        if args.opt_str("budget-mix").is_some()
            || args.opt_str("chains").is_some()
            || args.opt_str("chain-budget").is_some()
        {
            return Err(Error::Config(
                "--trace replays an exact chain schedule; it replaces \
                 --budget-mix/--chains/--chain-budget — pass one or the other"
                    .into(),
            ));
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read trace '{path}': {e}")))?;
        let chains = chain::parse_trace(&text)?;
        log_info!("serve: trace replay of {} chain(s) from {path}", chains.len());
        chains
    } else if let Some(n_chains) = args.opt_str("chains") {
        let n_chains: usize = n_chains
            .parse()
            .map_err(|_| Error::Config(format!("bad --chains '{n_chains}'")))?;
        let spec = args.str_or("chain-budget", "d8000t1200");
        let chain_budget = loadgen::parse_budget_spec(spec)?;
        log_info!("serve: {n_chains} chain(s), chain budget {spec}");
        chain::sample_chains(n_chains, &chain_budget, arrivals, &mut rng)
    } else if args.opt_str("chain-budget").is_some() {
        return Err(Error::Config(
            "--chain-budget needs --chains N (or use --trace)".into(),
        ));
    } else {
        Vec::new()
    };
    // trace replay is chains-only unless --requests is passed explicitly
    let default_requests = if args.opt_str("trace").is_some() { 0 } else { 32 };
    let n = args.usize_or("requests", default_requests)?;
    // per-request budgets, enforced mid-strategy by the decoding method:
    // one cloned budget (--deadline-ms/--max-tokens) or a weighted
    // heterogeneous mix (--budget-mix "30:d500,30:d5000,40:unlimited")
    let schedule = if let Some(mix_spec) = args.opt_str("budget-mix") {
        if args.opt_str("deadline-ms").is_some() || args.opt_str("max-tokens").is_some() {
            return Err(Error::Config(
                "--budget-mix replaces --deadline-ms/--max-tokens; pass one or the other"
                    .into(),
            ));
        }
        let mix = loadgen::parse_budget_mix(mix_spec)?;
        log_info!("serve: budget mix with {} arms ({mix_spec})", mix.len());
        loadgen::schedule_mixed(&splits.test, n, arrivals, &mix, &mut rng)
    } else {
        let mut budget = Budget::unlimited();
        let deadline_ms = args.f64_or("deadline-ms", 0.0)?;
        if deadline_ms > 0.0 {
            budget = budget.with_deadline_ms(deadline_ms);
        }
        let max_tokens = args.usize_or("max-tokens", 0)?;
        if max_tokens > 0 {
            budget = budget.with_max_tokens(max_tokens);
        }
        if !budget.is_unlimited() {
            log_info!(
                "serve: per-request budget deadline_ms={deadline_ms} max_tokens={max_tokens}"
            );
        }
        loadgen::schedule_budgeted(&splits.test, n, arrivals, budget, &mut rng)
    };
    let report = driver::run_traffic(&executor, &mode, schedule, chains, workers)?;
    report.log_summary("test");
    std::fs::create_dir_all(&cfg.paths.results)?;
    std::fs::write(
        cfg.paths.results.join("serve_report.json"),
        report.to_json().pretty(),
    )?;
    println!("{}", report.to_json().pretty());
    Ok(())
}

// ---------------------------------------------------------------------
// engine-serve
// ---------------------------------------------------------------------

/// `ttc engine-serve`: expose a local engine fleet (device or sim) over
/// TCP for remote `ttc serve --remote` clients — see `docs/remote.md`.
pub fn cmd_engine_serve(raw: &[String]) -> Result<()> {
    let values: Vec<&str> = [
        COMMON_VALUES,
        &["addr", "backend", "engines", "wire-codec", "cache-entries", "cache-shards"],
    ]
    .concat();
    let args = Args::parse(raw, &values, &["sim", "cache"])?;
    let mut cfg = load_config(&args)?;
    if args.flag("sim") {
        cfg.engine.backend = BackendKind::Sim;
    }
    apply_cache_args(&args, &mut cfg)?;
    if let Some(b) = args.opt_str("backend") {
        cfg.engine.backend = BackendKind::parse(b)?;
    }
    if cfg.engine.backend == BackendKind::Remote {
        return Err(Error::Config(
            "engine-serve executes work locally; --backend must be 'device' or 'sim' \
             (chaining remote tiers is not supported)"
                .into(),
        ));
    }
    cfg.engine.engines = args.usize_or("engines", cfg.engine.engines)?;
    if let Some(c) = args.opt_str("wire-codec") {
        cfg.engine.wire_codec = crate::config::WireCodec::parse(c)?;
    }
    if cfg.engine.backend == BackendKind::Sim && !cfg.engine.sim_clock {
        // same rule as serve: the sim backend's latency semantics come
        // from the sim clock's cost model
        log_info!("engine-serve: sim backend — enabling the sim clock for modeled latencies");
        cfg.engine.sim_clock = true;
    }
    let addr = args.str_or("addr", "127.0.0.1:7070");
    let server = crate::net::TcpEngineServer::bind(&cfg, addr)?;
    log_info!(
        "engine-serve: {} engine(s), {} backend, listening on {}",
        cfg.engine.engines.max(1),
        cfg.engine.backend.as_str(),
        server.local_addr()
    );
    // the accept loop runs on its own thread; serve until killed
    loop {
        std::thread::park();
    }
}

// ---------------------------------------------------------------------
// pipeline + info
// ---------------------------------------------------------------------

pub fn cmd_pipeline(raw: &[String]) -> Result<()> {
    let values: Vec<&str> = [COMMON_VALUES, &["out"]].concat();
    let args = Args::parse(raw, &values, &["quick"])?;
    let mut base: Vec<String> = vec![];
    if let Some(c) = args.opt_str("config") {
        base.extend(["--config".into(), c.into()]);
    }
    if let Some(a) = args.opt_str("artifacts") {
        base.extend(["--artifacts".into(), a.into()]);
    }
    let results = args
        .opt_str("out")
        .or(args.opt_str("results"))
        .unwrap_or("results");
    base.extend(["--results".into(), results.into()]);

    let mut collect_args = vec!["collect".to_string()];
    collect_args.extend(base.clone());
    if args.flag("quick") {
        collect_args.extend(["--repeats".into(), "1".into()]);
    }
    cmd_collect(&collect_args)?;

    let mut probe_args = vec!["train-probe".to_string()];
    probe_args.extend(base.clone());
    cmd_train_probe(&probe_args)?;

    let mut fig_args = vec!["figures".to_string()];
    fig_args.extend(base);
    cmd_figures(&fig_args)?;
    Ok(())
}

pub fn cmd_info(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, COMMON_VALUES, &[])?;
    let cfg = load_config(&args)?;
    let index = crate::runtime::ArtifactIndex::load(&cfg.paths.artifacts)?;
    println!(
        "artifacts: {} ({} executables)",
        cfg.paths.artifacts.display(),
        index.executables.len()
    );
    println!("meta: {}", index.meta.dumps());
    let engine = Engine::start(&cfg)?;
    println!("engine: {}", engine.handle().info()?.pretty());
    Ok(())
}
